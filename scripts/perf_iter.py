"""§Perf hillclimbing harness: re-lower one cell with config overrides
and report the three roofline terms, so each hypothesis→change→measure
iteration is one command.

    PYTHONPATH=src python scripts/perf_iter.py --arch qwen1.5-110b \
        --shape train_4k --set attn_chunk=2048 --set logit_chunk=2048 \
        --tag h2_bigger_chunks

Results append to results/perf_iters.jsonl.
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json
import time


def parse_override(s: str):
    k, v = s.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("true", "false"):
        return k, v == "true"
    return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig field override, e.g. attn_chunk=2048; "
                         "ssm.* fields via ssm.chunk=256")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--full-memory", action="store_true",
                    help="also run the full-depth compile for "
                         "memory_analysis (slower)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.dryrun import estimate_cost, _depth_config
    from repro.launch.cells import build_cell, lower_cell
    from repro.models.common import SHAPES
    from repro.roofline import roofline_from_numbers, roofline_terms

    cfg = get_config(args.arch)
    ssm_over = {}
    for s in args.set:
        k, v = parse_override(s)
        if k.startswith("ssm."):
            ssm_over[k[4:]] = v
        else:
            cfg = dataclasses.replace(cfg, **{k: v})
    if ssm_over:
        cfg = dataclasses.replace(cfg,
                                  ssm=dataclasses.replace(cfg.ssm, **ssm_over))

    mesh = make_production_mesh()
    t0 = time.time()
    numbers = estimate_cost(args.arch, args.shape, mesh, cfg)
    roof = roofline_from_numbers(
        numbers, arch=args.arch, shape_name=args.shape, mesh_name="16x16",
        n_devices=mesh.size, cfg=cfg, shape=SHAPES[args.shape],
        note=f"perf_iter tag={args.tag}")
    rec = roof.to_dict()
    rec["tag"] = args.tag
    rec["overrides"] = args.set
    rec["wall_seconds"] = time.time() - t0
    if args.full_memory:
        cell = build_cell(args.arch, args.shape, mesh, cfg=cfg)
        compiled = lower_cell(cell, mesh).compile()
        ma = compiled.memory_analysis()
        rec["bytes_per_dev_argument"] = float(ma.argument_size_in_bytes)
        rec["bytes_per_dev_temp"] = float(ma.temp_size_in_bytes)
    print(roofline_terms(roof))
    print(f"  coll detail: {numbers['coll']['by_op']}")
    os.makedirs("results", exist_ok=True)
    with open("results/perf_iters.jsonl", "a") as f:
        f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
