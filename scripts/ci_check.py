#!/usr/bin/env python
"""Run the exact CI matrix locally (.github/workflows/ci.yml) and exit
nonzero on any failure, so a builder can run the same gate before
pushing:

    python scripts/ci_check.py            # full matrix
    python scripts/ci_check.py --fast     # skip the chaos/slow lane
    python scripts/ci_check.py --only tier1,bench
    python scripts/ci_check.py bench-diff # lanes as positional args too

Lanes:
  hygiene    fail on tracked bytecode artifacts (__pycache__ / *.pyc)
  compile    byte-compile src/benchmarks/examples/scripts/tests
  lint       PYTHONPATH=src python -m repro.lint --check
             (contract rules R001-R006 + the suppression budget)
  fed        PYTHONPATH=src pytest -q -m "fed and not chaos and not slow"
  svc        PYTHONPATH=src pytest -q -m "svc and not chaos and not slow"
  catalog    PYTHONPATH=src pytest -q
             -m "catalog and not chaos and not slow"
  obs        PYTHONPATH=src pytest -q -m "obs and not chaos and not slow"
  tier1      PYTHONPATH=src pytest -x -q
             -m "not chaos and not slow and not fed and not svc and not catalog and not obs"
  degraded   PYTHONPATH=src pytest -q tests/test_degraded_scenarios.py
             -m "chaos or fed"  (health plane: brownout / death / failover)
  chaos      PYTHONPATH=src pytest -q -m "chaos or slow"
  bench      PYTHONPATH=src python -m benchmarks.run --quick
  bench-diff quick-run the guarded suites into a temp dir and compare
             against the committed BENCH_*.json baselines
             (benchmarks.diff); nonzero exit on regression
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: mirrors the CI "No tracked bytecode artifacts" step
_HYGIENE_SNIPPET = (
    "import re, subprocess, sys\n"
    "files = subprocess.run(['git', 'ls-files'], capture_output=True,\n"
    "                       text=True, check=True).stdout.splitlines()\n"
    "bad = [f for f in files if re.search(r'(^|/)__pycache__/|\\.py[cod]$', f)]\n"
    "print('\\n'.join(bad))\n"
    "sys.exit(1 if bad else 0)\n")

#: mirrors the CI "Bench regression gate" step: fresh quick-mode run of
#: the guarded suites into a temp dir, then benchmarks.diff against the
#: committed baselines in the repo root
_BENCH_DIFF_SNIPPET = (
    "import subprocess, sys, tempfile\n"
    "with tempfile.TemporaryDirectory() as tmp:\n"
    "    rc = subprocess.run([sys.executable, '-m', 'benchmarks.run',\n"
    "                         '--quick', '--only', 'perfile,federation,obs',\n"
    "                         '--out', tmp],\n"
    "                        stdout=subprocess.DEVNULL).returncode\n"
    "    if rc:\n"
    "        sys.exit(rc)\n"
    "    sys.exit(subprocess.run([sys.executable, '-m',\n"
    "                             'benchmarks.diff',\n"
    "                             '--current-dir', tmp]).returncode)\n")

LANES: dict[str, list[str]] = {
    "hygiene": [sys.executable, "-c", _HYGIENE_SNIPPET],
    "compile": [sys.executable, "-m", "compileall", "-q",
                "src", "benchmarks", "examples", "scripts", "tests"],
    # contract linter before any test lane: a clock/charge/lock/health
    # violation fails fast with a file:line, not a flaky test later
    "lint": [sys.executable, "-m", "repro.lint", "--check"],
    # the federation suite runs as its own tier-1 step (mirrors CI);
    # its chaos-grade scenario carries both marks and lands in "chaos"
    "fed": [sys.executable, "-m", "pytest", "-q",
            "-m", "fed and not chaos and not slow"],
    # service plane: StatusBus streams + digest etag, its own lane so a
    # regression is named in the log (the three PR-7 bug regressions
    # are deliberately unmarked and run in tier1)
    "svc": [sys.executable, "-m", "pytest", "-q",
            "-m", "svc and not chaos and not slow"],
    # replica catalog: dedupe, eviction, staleness — its chaos-grade
    # fan-out scenario carries both marks and lands in "chaos"
    "catalog": [sys.executable, "-m", "pytest", "-q",
                "-m", "catalog and not chaos and not slow"],
    # observability plane: tracer spans, metrics registry, time-budget
    # attribution — its own lane so a trace/budget regression is named
    "obs": [sys.executable, "-m", "pytest", "-q",
            "-m", "obs and not chaos and not slow"],
    "tier1": [sys.executable, "-m", "pytest", "-x", "-q",
              "-m", "not chaos and not slow and not fed and not svc "
                    "and not catalog and not obs"],
    # mirrors the CI chaos job's named degraded-mode step (health plane)
    "degraded": [sys.executable, "-m", "pytest", "-q",
                 "tests/test_degraded_scenarios.py",
                 "-m", "chaos or fed"],
    "chaos": [sys.executable, "-m", "pytest", "-q",
              "-m", "chaos or slow"],
    "bench": [sys.executable, "-m", "benchmarks.run", "--quick"],
    "bench-diff": [sys.executable, "-c", _BENCH_DIFF_SNIPPET],
}


def run_lane(name: str, cmd: list[str]) -> bool:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.setdefault("REPRO_TIME_SCALE", "0.0")
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    print(f"=== {name}: {' '.join(cmd)}", flush=True)
    t0 = time.monotonic()
    proc = subprocess.run(cmd, cwd=REPO, env=env)
    dt = time.monotonic() - t0
    status = "ok" if proc.returncode == 0 else f"FAILED rc={proc.returncode}"
    print(f"=== {name}: {status} ({dt:.0f}s)", flush=True)
    return proc.returncode == 0


def main() -> int:
    ap = argparse.ArgumentParser(
        description="mirror the CI matrix locally; nonzero exit on failure")
    ap.add_argument("--fast", action="store_true",
                    help="skip the chaos/slow lane")
    ap.add_argument("--only", default=None,
                    help="comma-separated lane subset: "
                         + ",".join(LANES))
    ap.add_argument("lanes", nargs="*",
                    help="lane names as positional args "
                         "(same as --only)")
    args = ap.parse_args()
    wanted = list(LANES)
    if args.only or args.lanes:
        wanted = (args.only.split(",") if args.only else []) + args.lanes
        unknown = [w for w in wanted if w not in LANES]
        if unknown:
            print(f"unknown lane(s): {','.join(unknown)}", file=sys.stderr)
            return 2
    if args.fast and "chaos" in wanted:
        wanted.remove("chaos")
    failed = [name for name in wanted if not run_lane(name, LANES[name])]
    if failed:
        print(f"\nCI check FAILED: {', '.join(failed)}")
        return 1
    print(f"\nCI check passed: {', '.join(wanted)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
