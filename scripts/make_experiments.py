"""Regenerate the §Dry-run / §Roofline tables in EXPERIMENTS.md from
results/*.json.  Run after (re-)running the dry-run sweep:

    PYTHONPATH=src python scripts/make_experiments.py
"""

from __future__ import annotations

import glob
import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

ARCH_ORDER = [
    "jamba-1.5-large-398b", "dbrx-132b", "granite-moe-1b-a400m",
    "granite-20b", "h2o-danube-3-4b", "qwen1.5-110b", "qwen1.5-0.5b",
    "whisper-medium", "rwkv6-7b", "llava-next-mistral-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
SKIPPED_LONG = {"dbrx-132b", "granite-moe-1b-a400m", "granite-20b",
                "qwen1.5-110b", "qwen1.5-0.5b", "whisper-medium"}


def load() -> dict:
    recs = {}
    for p in glob.glob(os.path.join(RESULTS, "dryrun_*.json")):
        with open(p) as f:
            r = json.load(f)
        key = (r.get("arch"), r.get("shape"), r.get("mesh"))
        recs[key] = r
    return recs


def fmt_bytes(b):
    return f"{b / 1024**3:.2f}"


def dryrun_table(recs) -> str:
    rows = ["| arch | shape | mesh | status | bytes/dev (args+temps GB) | "
            "flops/dev (raw) | collective bytes/dev | compile s |",
            "|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            if shape == "long_500k" and arch in SKIPPED_LONG:
                rows.append(f"| {arch} | {shape} | — | SKIP (full attention;"
                            f" DESIGN.md §3) | — | — | — | — |")
                continue
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    rows.append(f"| {arch} | {shape} | {mesh} | PENDING | — |"
                                f" — | — | — |")
                elif not r.get("ok"):
                    rows.append(f"| {arch} | {shape} | {mesh} | FAIL: "
                                f"{r.get('error', '')[:60]} | — | — | — | — |")
                else:
                    gb = (r["bytes_per_dev_argument"]
                          + r["bytes_per_dev_temp"]) / 1024**3
                    raw = r.get("raw_cost", {})
                    rows.append(
                        f"| {arch} | {shape} | {mesh} | OK | {gb:.2f} | "
                        f"{raw.get('flops', 0):.2e} | "
                        f"{raw.get('coll_total', 0):.2e} | "
                        f"{r.get('compile_seconds', 0):.0f} |")
    return "\n".join(rows)


def roofline_table(recs) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | MODEL_FLOPS | useful ratio | roofline frac | "
            "what would move the dominant term |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, "16x16"))
            if r is None or not r.get("ok"):
                continue
            if r.get("note", "").startswith("raw"):
                suffix = " (raw)"
            else:
                suffix = ""
            hint = _hint(r)
            rows.append(
                f"| {arch} | {shape} | {r['t_compute']:.3f} | "
                f"{r['t_memory']:.3f} | {r['t_collective']:.3f} | "
                f"{r['bottleneck']}{suffix} | {r['model_flops_global']:.2e} | "
                f"{r['useful_ratio']:.2f} | {r['peak_fraction']:.3f} | "
                f"{hint} |")
    return "\n".join(rows)


def _hint(r) -> str:
    b = r["bottleneck"]
    kind = r.get("kind", "")
    if b == "memory":
        if kind == "decode":
            return ("cache traffic dominates: quantize KV cache / shard "
                    "deeper / batch more requests per step")
        return ("fuse elementwise chains + bf16 intermediates; on TPU the "
                "flash/ssm Pallas kernels keep these tiles in VMEM")
    if b == "collective":
        return ("overlap param all-gathers with compute; shrink TP degree "
                "or switch collectives to bf16")
    return "increase per-device batch or arithmetic intensity"


def main():
    recs = load()
    ok = sum(1 for r in recs.values() if r.get("ok"))
    out = [
        "<!-- AUTO-GENERATED dry-run/roofline tables "
        "(scripts/make_experiments.py) -->",
        f"\n### Dry-run status: {ok}/{len(recs)} compiled cells\n",
        dryrun_table(recs),
        "\n### Single-pod roofline baselines (16x16, 256 chips)\n",
        roofline_table(recs),
    ]
    path = os.path.join(RESULTS, "tables.md")
    with open(path, "w") as f:
        f.write("\n".join(out) + "\n")
    print(f"wrote {path} ({ok} ok cells)")


if __name__ == "__main__":
    main()
