"""Paper §7 / Figs. 19-21: strong end-to-end integrity checking ON vs
OFF (checksum at source, re-read + checksum at destination).  The
overhead should be visible but modest, and smaller when the Connector
sits near the storage (§8.2)."""

from __future__ import annotations

import tempfile

from repro.core import TransferOptions

from .common import (MB, QUICK, emit, make_env, seed_local_files,
                     split_dataset, transfer_model_seconds, Endpoint)

N_FILES = 4 if QUICK else 8
FILE_MB = 8 if QUICK else 16   # paper: c x 300 MB files


def run() -> dict:
    out = {}
    for provider in (["wasabi"] if QUICK else ["wasabi", "s3", "gcs"]):
        with tempfile.TemporaryDirectory() as tmp:
            env = make_env(tmp, virtual=True)
            storage, conn = env.cloud(provider, "local")
            for integrity in (False, True):
                parts = split_dataset(N_FILES * FILE_MB * MB, N_FILES)
                src = seed_local_files(env, f"i{provider}{integrity}", parts)
                t = transfer_model_seconds(
                    env, Endpoint(env.local, src),
                    Endpoint(conn, f"bkt/i{integrity}", conn.name),
                    TransferOptions(concurrency=1, parallelism=4,
                                    integrity=integrity))
                out[(provider, integrity)] = t
                emit(f"integrity.{provider}."
                     f"{'on' if integrity else 'off'}", t, "")
                storage.blobs._objs.clear()
            ratio = out[(provider, True)] / out[(provider, False)]
            emit(f"integrity.{provider}.overhead", 0.0, f"x{ratio:.2f}")

            # §8.2: with integrity ON, near-storage placement avoids the
            # WAN re-read — compare conn-local vs conn-cloud
            if provider in ("s3", "gcs") or QUICK:
                conn_cloud = type(conn)(storage, placement="cloud",
                                        clock=env.clock)
                env.creds.register(conn_cloud.name,
                                   env.creds.lookup(conn.name))
                parts = split_dataset(N_FILES * FILE_MB * MB, N_FILES)
                src = seed_local_files(env, f"ic{provider}", parts)
                t_cloud = transfer_model_seconds(
                    env, Endpoint(env.local, src),
                    Endpoint(conn_cloud, "bkt/ic", conn_cloud.name),
                    TransferOptions(concurrency=1, parallelism=4,
                                    integrity=True))
                emit(f"integrity.{provider}.conn-cloud.on", t_cloud,
                     f"vs conn-local x{out[(provider, True)] / t_cloud:.2f}")
                storage.blobs._objs.clear()

                # beyond-paper: server-side checksum (no re-read at all)
                conn_ss = type(conn)(storage, placement="local",
                                     clock=env.clock, server_checksum=True)
                env.creds.register(conn_ss.name, env.creds.lookup(conn.name))
                src = seed_local_files(env, f"is{provider}", parts)
                t_ss = transfer_model_seconds(
                    env, Endpoint(env.local, src),
                    Endpoint(conn_ss, "bkt/is", conn_ss.name),
                    TransferOptions(concurrency=1, parallelism=4,
                                    integrity=True))
                out[(provider, "server")] = t_ss
                emit(f"integrity.{provider}.server-side.on", t_ss,
                     f"x{t_ss / out[(provider, False)]:.2f} vs OFF "
                     f"(re-read eliminated)")
                storage.blobs._objs.clear()
    return out


if __name__ == "__main__":
    run()
