"""Paper §6.5 / Figs. 17-18: inter-cloud transfers (S3 <-> GCS).

Fig 17: third-party Connector transfer with DTNs in-cloud vs at the
user's site (the paper measures ~2x from in-cloud placement).
Fig 18: vs a MultCloud-like relay client (sequential, through an
intermediate point, one file at a time)."""

from __future__ import annotations

import tempfile

from repro.core import TransferOptions

from .common import (MB, QUICK, emit, make_env, seed_bucket, split_dataset,
                     timed, transfer_model_seconds, Endpoint)

N_FILES = 16 if QUICK else 50       # paper Fig 18: 50 files / 1 GB
TOTAL_MB = 32 if QUICK else 96


def run() -> dict:
    out = {}
    with tempfile.TemporaryDirectory() as tmp:
        env = make_env(tmp, virtual=True)
        s3, s3_cloud = env.cloud("s3", "cloud")
        gcs, gcs_cloud = env.cloud("gcs", "cloud")
        s3_local = type(s3_cloud)(s3, placement="local", clock=env.clock)
        gcs_local = type(gcs_cloud)(gcs, placement="local", clock=env.clock)
        env.creds.register(s3_local.name, env.creds.lookup(s3_cloud.name))
        env.creds.register(gcs_local.name, env.creds.lookup(gcs_cloud.name))

        parts = split_dataset(TOTAL_MB * MB, N_FILES)

        # Connector, DTNs in-cloud (best practice §8.1)
        seed_bucket(s3, "src", parts)
        t_cloud = transfer_model_seconds(
            env, Endpoint(s3_cloud, "src", s3_cloud.name),
            Endpoint(gcs_cloud, "dstc", gcs_cloud.name),
            TransferOptions(concurrency=1, parallelism=4))
        out["conn-cloud"] = t_cloud
        emit("intercloud.s3_to_gcs.conn-cloud", t_cloud,
             f"{TOTAL_MB / t_cloud:.0f}MB/s")

        # Connector, DTNs at the user's site (data crosses WAN twice)
        gcs.blobs._objs.clear()
        t_local = transfer_model_seconds(
            env, Endpoint(s3_local, "src", s3_local.name),
            Endpoint(gcs_local, "dstl", gcs_local.name),
            TransferOptions(concurrency=1, parallelism=4))
        out["conn-local"] = t_local
        emit("intercloud.s3_to_gcs.conn-local", t_local,
             f"{TOTAL_MB / t_local:.0f}MB/s; in-cloud is "
             f"x{t_local / t_cloud:.2f} faster (paper: ~2x)")

        # MultCloud-like relay: download to site then upload, one file
        # at a time, no restart/integrity machinery
        gcs.blobs._objs.clear()
        s3_native = env.native(s3)
        gcs_native = env.native(gcs)

        def relay():
            s3_native.login()
            gcs_native.login()
            for i in range(N_FILES):
                data = s3_native.download_bytes(f"src/f{i:04d}.bin")
                gcs_native.upload_bytes(data, f"dstm/f{i:04d}.bin")

        t_mult = timed(relay, env)
        out["multcloud"] = t_mult
        emit("intercloud.s3_to_gcs.multcloud-like", t_mult,
             f"{TOTAL_MB / t_mult:.0f}MB/s; Connector (cc=1) is "
             f"x{t_mult / t_cloud:.2f} faster (paper Fig 18: Connector "
             f"wins in all cases)")
    return out


if __name__ == "__main__":
    run()
