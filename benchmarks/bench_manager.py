"""Control-plane benchmarks: fleet goodput/fairness, dispatch cost, and
the online-refit convergence curve.

The paper's managed service earns its keep by running *many* transfers
concurrently (§2.1); this bench drives a :class:`TransferManager` fleet
over an emulated S3 route and reports, per task count:

* aggregate goodput (total bytes / modeled makespan) — should rise with
  the task count until the worker budget saturates, then flatten;
* Jain's fairness index over per-task goodput,
  ``J = (sum r)^2 / (n * sum r^2)`` — 1.0 means every task (and hence
  every tenant, since tasks alternate tenants) got an equal share.

Uses the real (scaled) clock so concurrent tasks genuinely overlap —
virtual-clock accounting cannot observe overlap (see common.py).

Two further control-plane measurements ride along:

* ``manager.dispatch.pick5k`` — scheduler pick cost draining a
  5000-submission queue (guards the lazy-deletion heap: the old
  sorted+remove+heapify pick was O(n log n) *per dispatch*);
* ``manager.refit.*`` — the closed-loop refit curve: a 30-task fleet
  submitted against a deliberately miscalibrated route model, median
  |prediction error| per completion window.  Charge-accounted per-task
  model time (exact under concurrency) is what makes these
  observations fit-worthy; the curve must fall once auto-refit fires.

Emits: ``manager.fleet.nNN`` rows with ``goodput=... jain=...``, plus
the dispatch and refit rows above.
"""

from __future__ import annotations

import statistics
import tempfile
import time

from repro.core import (Advisor, Credential, Endpoint, PerfModel, Route,
                        RouteCandidate, TransferManager, TransferOptions)
from repro.core.clock import Clock

from .common import MB, QUICK, emit, make_env, seed_local_files, \
    split_dataset

TASK_COUNTS = (1, 4) if QUICK else (1, 2, 4, 8)
FILES_PER_TASK = 6 if QUICK else 12
FILE_KB = 16
MAX_WORKERS = 4
TENANTS = ("alice", "bob")
#: a larger-than-default scale keeps modeled (parallelizable) latency
#: well above the interpreter's fixed per-task CPU cost, which the GIL
#: serializes and which would otherwise read as false non-scaling
BENCH_SCALE = 0.1
#: Drive-profile per-call latency (180 ms model) makes the workload
#: latency-dominated, so task overlap — the thing the control plane
#: buys — is what the measurement sees, not GIL-bound byte shuffling.
PROVIDER = "drive"
OVERRIDES = {"quota_rate": 10_000, "quota_burst": 100_000,
             "consistency_delay": 0.0}


def _jain(rates: list[float]) -> float:
    if not rates:
        return 0.0
    total = sum(rates)
    sq = sum(r * r for r in rates)
    return (total * total) / (len(rates) * sq) if sq > 0 else 1.0


#: dispatch micro-benchmark: queue depth + the wall-clock guard.  The
#: pre-lazy-heap scheduler took O(n log n) per pick — a 5k drain was
#: minutes; the lazy-deletion heap drains it in well under the bound.
DISPATCH_QUEUE = 5000
DISPATCH_BOUND_S = 2.0

REFIT_TASKS = 30
REFIT_EVERY = 5
REFIT_WINDOW = 6


def bench_dispatch() -> dict:
    """Drain a 5k-submission queue through the scheduler (no data plane:
    submissions are enqueued directly and picks activated inline), and
    fail the suite if dispatch cost regresses past the bound."""
    from repro.core.manager import _Submission
    from repro.core.transfer import TransferTask
    from repro.connectors import MemoryConnector

    with tempfile.TemporaryDirectory() as tmp:
        mgr = TransferManager(
            max_workers=DISPATCH_QUEUE + 1, per_endpoint_cap=None,
            share_sessions=False, marker_root=f"{tmp}/markers",
            clock=Clock(scale=0.0))
        conn = MemoryConnector()
        with mgr._lock:
            for i in range(DISPATCH_QUEUE):
                sub = _Submission(
                    TransferTask(f"d{i}"),
                    Endpoint(conn, "a", f"src{i % 16}"),
                    Endpoint(conn, "b", f"dst{i % 16}"),
                    TransferOptions(), f"tenant{i % 8}",
                    priority=i % 5, seq=next(mgr._seq))
                mgr._enqueue_locked(sub)
        t0 = time.perf_counter()
        picked = 0
        with mgr._lock:
            while True:
                sub = mgr._pick_locked()
                if sub is None:
                    break
                mgr._activate_locked(sub)
                picked += 1
        dt = time.perf_counter() - t0
        assert picked == DISPATCH_QUEUE, f"only {picked} picks drained"
        assert dt < DISPATCH_BOUND_S, \
            f"dispatch regressed: {dt:.2f}s to drain {picked} submissions"
        emit("manager.dispatch.pick5k", dt / picked,
             f"total={dt * 1e3:.0f}ms n={picked}")
        return {"total_s": dt, "per_pick_us": dt / picked * 1e6}


def bench_refit() -> dict:
    """Refit-convergence curve: 30 tasks routed by a model whose seed
    fit is ~100x off; the manager auto-refits every REFIT_EVERY
    completions from charge-accounted observations and re-predicts the
    still-queued tail.  Pure accounting (scale 0): per-task model time
    is exact under overlap, so no wall clock is needed."""
    with tempfile.TemporaryDirectory() as tmp:
        env = make_env(tmp, virtual=True)
        _, conn = env.cloud("drive", "local", quota_rate=10_000,
                            quota_burst=100_000, consistency_delay=0.0)
        # seed model: per-file overhead two orders of magnitude off
        seed = PerfModel(route="drive", t0=20.0, alpha=1e9 / 40e6,
                         bytes_total=int(1e9))
        advisor = Advisor([Route("drive", seed, max_concurrency=1)])
        manager = TransferManager(service=env.service, advisor=advisor,
                                  max_workers=4, per_endpoint_cap=None,
                                  refit_every=REFIT_EVERY)
        opts = TransferOptions(startup_cost=0.0)
        tasks = []
        for i in range(REFIT_TASKS):
            n_files = 4 + 4 * (i % 3)
            parts = split_dataset(n_files * 2048, n_files)
            src = seed_local_files(env, f"refit{i}", parts)
            tasks.append(manager.submit(
                candidates=[RouteCandidate(
                    "drive", Endpoint(env.local, src),
                    Endpoint(conn, f"bkt/refit{i}"))],
                options=opts, task_id=f"refit-{i}",
                n_files=n_files, nbytes=n_files * 2048))
        ok = manager.wait_all(timeout=600)
        assert ok, "refit fleet did not finish"
        for t in tasks:
            assert t.status == t.SUCCEEDED, t.events[-3:]
        n_refits = manager.metrics.refits.get("drive", 0)
        assert n_refits >= 1, "auto-refit never fired over 30 completions"

        log = list(manager.metrics.prediction_log)  # completion order
        out = {"refits": n_refits, "windows": []}
        for w in range(0, len(log), REFIT_WINDOW):
            rows = log[w:w + REFIT_WINDOW]
            med = statistics.median(
                abs(p - a) / max(a, 1e-9) for _, _, p, a in rows)
            gens = sorted({g for _, g, _, _ in rows})
            out["windows"].append(med)
            emit(f"manager.refit.w{w // REFIT_WINDOW}", med,
                 f"median_rel_err={med:.3f} gens={gens} n={len(rows)}")
        first, last = out["windows"][0], out["windows"][-1]
        assert last < first, \
            f"refit did not converge: median err {last:.3f} !< {first:.3f}"
        pre = manager.prediction_error(generation=0)
        post = manager.prediction_error(min_generation=1)
        assert post is not None and post < pre, (pre, post)
        emit("manager.refit.curve", 0.0,
             f"first={first:.3f} last={last:.3f} pre={pre:.3f} "
             f"post={post:.3f} refits={n_refits}")
        out["pre"], out["post"] = pre, post
        manager.shutdown(wait=False)
        return out


def run() -> dict:
    out = {}
    per_task_bytes = FILES_PER_TASK * FILE_KB * 1024
    for n_tasks in TASK_COUNTS:
        with tempfile.TemporaryDirectory() as tmp:
            env = make_env(tmp, scale=BENCH_SCALE)
            # one destination endpoint per task: the fleet story is the
            # manager keeping many *endpoints* busy at once (a single
            # endpoint's shared link/quota would cap aggregate goodput
            # regardless of task count)
            conns = []
            for i in range(n_tasks):
                tenant = TENANTS[i % len(TENANTS)]
                _, conn = env.cloud(PROVIDER, "local", **OVERRIDES)
                env.creds.register(f"dst-{i}", Credential(
                    conn.credential_scheme, {"identity": tenant}))
                conns.append(conn)
            manager = TransferManager(service=env.service,
                                      max_workers=MAX_WORKERS,
                                      per_endpoint_cap=None)
            parts = split_dataset(per_task_bytes, FILES_PER_TASK)
            srcs = [seed_local_files(env, f"fleet{i}", parts)
                    for i in range(n_tasks)]
            # per-file path (no coalescing): every file pays the full
            # modeled admission latency, the regime where concurrent
            # tasks show their overlap
            opts = TransferOptions(concurrency=2, startup_cost=0.0,
                                   coalesce_threshold=0)
            t0 = time.monotonic()
            # tenant passed explicitly: the source endpoints carry no
            # credential, so identity() alone would pool every task
            # into one anonymous queue and bypass the fair scheduler
            tasks = [manager.submit(
                Endpoint(env.local, srcs[i]),
                Endpoint(conns[i], f"bkt/fleet{i}", f"dst-{i}"),
                opts, task_id=f"fleet-{n_tasks}-{i}",
                tenant=TENANTS[i % len(TENANTS)])
                for i in range(n_tasks)]
            ok = manager.wait_all(timeout=600)
            makespan = (time.monotonic() - t0) / BENCH_SCALE
            assert ok, "fleet did not finish"
            for t in tasks:
                assert t.status == t.SUCCEEDED, t.events[-3:]
            rates = [t.stats.bytes_done / max(t.stats.wall_seconds / BENCH_SCALE,
                                              1e-9)
                     for t in tasks]
            goodput = n_tasks * per_task_bytes / max(makespan, 1e-9) / MB
            jain = _jain(rates)
            out[n_tasks] = {"model_s": makespan, "goodput_mb_s": goodput,
                            "jain": jain,
                            "peak_active": manager.metrics.peak_active}
            emit(f"manager.fleet.n{n_tasks:02d}", makespan,
                 f"goodput={goodput:.1f}MB/s jain={jain:.3f} "
                 f"peak_active={manager.metrics.peak_active}")
            manager.shutdown(wait=False)
    base = out[TASK_COUNTS[0]]["goodput_mb_s"]
    top = out[TASK_COUNTS[-1]]["goodput_mb_s"]
    emit("manager.fleet.scaling", 0.0,
         f"x{top / max(base, 1e-9):.2f} goodput at n={TASK_COUNTS[-1]} "
         f"(workers={MAX_WORKERS})")
    return {"fleet": out, "dispatch": bench_dispatch(),
            "refit": bench_refit()}


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
