"""Control-plane benchmark: aggregate goodput and fairness vs task count.

The paper's managed service earns its keep by running *many* transfers
concurrently (§2.1); this bench drives a :class:`TransferManager` fleet
over an emulated S3 route and reports, per task count:

* aggregate goodput (total bytes / modeled makespan) — should rise with
  the task count until the worker budget saturates, then flatten;
* Jain's fairness index over per-task goodput,
  ``J = (sum r)^2 / (n * sum r^2)`` — 1.0 means every task (and hence
  every tenant, since tasks alternate tenants) got an equal share.

Uses the real (scaled) clock so concurrent tasks genuinely overlap —
virtual-clock accounting cannot observe overlap (see common.py).

Emits: ``manager.fleet.nNN`` rows with ``goodput=... jain=...``.
"""

from __future__ import annotations

import tempfile
import time

from repro.core import (Credential, Endpoint, TransferManager,
                        TransferOptions)

from .common import MB, QUICK, emit, make_env, seed_local_files, \
    split_dataset

TASK_COUNTS = (1, 4) if QUICK else (1, 2, 4, 8)
FILES_PER_TASK = 6 if QUICK else 12
FILE_KB = 16
MAX_WORKERS = 4
TENANTS = ("alice", "bob")
#: a larger-than-default scale keeps modeled (parallelizable) latency
#: well above the interpreter's fixed per-task CPU cost, which the GIL
#: serializes and which would otherwise read as false non-scaling
BENCH_SCALE = 0.1
#: Drive-profile per-call latency (180 ms model) makes the workload
#: latency-dominated, so task overlap — the thing the control plane
#: buys — is what the measurement sees, not GIL-bound byte shuffling.
PROVIDER = "drive"
OVERRIDES = {"quota_rate": 10_000, "quota_burst": 100_000,
             "consistency_delay": 0.0}


def _jain(rates: list[float]) -> float:
    if not rates:
        return 0.0
    total = sum(rates)
    sq = sum(r * r for r in rates)
    return (total * total) / (len(rates) * sq) if sq > 0 else 1.0


def run() -> dict:
    out = {}
    per_task_bytes = FILES_PER_TASK * FILE_KB * 1024
    for n_tasks in TASK_COUNTS:
        with tempfile.TemporaryDirectory() as tmp:
            env = make_env(tmp, scale=BENCH_SCALE)
            # one destination endpoint per task: the fleet story is the
            # manager keeping many *endpoints* busy at once (a single
            # endpoint's shared link/quota would cap aggregate goodput
            # regardless of task count)
            conns = []
            for i in range(n_tasks):
                tenant = TENANTS[i % len(TENANTS)]
                _, conn = env.cloud(PROVIDER, "local", **OVERRIDES)
                env.creds.register(f"dst-{i}", Credential(
                    conn.credential_scheme, {"identity": tenant}))
                conns.append(conn)
            manager = TransferManager(service=env.service,
                                      max_workers=MAX_WORKERS,
                                      per_endpoint_cap=None)
            parts = split_dataset(per_task_bytes, FILES_PER_TASK)
            srcs = [seed_local_files(env, f"fleet{i}", parts)
                    for i in range(n_tasks)]
            # per-file path (no coalescing): every file pays the full
            # modeled admission latency, the regime where concurrent
            # tasks show their overlap
            opts = TransferOptions(concurrency=2, startup_cost=0.0,
                                   coalesce_threshold=0)
            t0 = time.monotonic()
            # tenant passed explicitly: the source endpoints carry no
            # credential, so identity() alone would pool every task
            # into one anonymous queue and bypass the fair scheduler
            tasks = [manager.submit(
                Endpoint(env.local, srcs[i]),
                Endpoint(conns[i], f"bkt/fleet{i}", f"dst-{i}"),
                opts, task_id=f"fleet-{n_tasks}-{i}",
                tenant=TENANTS[i % len(TENANTS)])
                for i in range(n_tasks)]
            ok = manager.wait_all(timeout=600)
            makespan = (time.monotonic() - t0) / BENCH_SCALE
            assert ok, "fleet did not finish"
            for t in tasks:
                assert t.status == t.SUCCEEDED, t.events[-3:]
            rates = [t.stats.bytes_done / max(t.stats.wall_seconds / BENCH_SCALE,
                                              1e-9)
                     for t in tasks]
            goodput = n_tasks * per_task_bytes / max(makespan, 1e-9) / MB
            jain = _jain(rates)
            out[n_tasks] = {"model_s": makespan, "goodput_mb_s": goodput,
                            "jain": jain,
                            "peak_active": manager.metrics.peak_active}
            emit(f"manager.fleet.n{n_tasks:02d}", makespan,
                 f"goodput={goodput:.1f}MB/s jain={jain:.3f} "
                 f"peak_active={manager.metrics.peak_active}")
            manager.shutdown(wait=False)
    base = out[TASK_COUNTS[0]]["goodput_mb_s"]
    top = out[TASK_COUNTS[-1]]["goodput_mb_s"]
    emit("manager.fleet.scaling", 0.0,
         f"x{top / max(base, 1e-9):.2f} goodput at n={TASK_COUNTS[-1]} "
         f"(workers={MAX_WORKERS})")
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
