"""Shared harness for the paper-reproduction benchmarks.

The cloud/WAN environment is emulated (offline container) with model
constants scaled by ``REPRO_BENCH_SCALE`` (default 0.02: a 50 s model
transfer takes 1 s of wall clock).  All benchmarks measure *wall clock*
around the scaled emulation, then report model seconds (wall / scale),
so numbers are comparable to the paper's qualitative behaviour.

Dataset sizes are scaled ~20x down from the paper (5 GB -> 256 MB,
1 GB -> 64 MB) to keep the suite fast; per-file-overhead phenomena are
size-independent, which is the point of the paper's model.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass

import numpy as np

from repro.core import (Credential, CredentialStore, Endpoint,
                        TransferOptions, TransferService)
from repro.core.clock import Clock
from repro.connectors import (MemoryConnector, ObjectStoreConnector,
                              PosixConnector, make_cloud)
from repro.connectors.cloud import NativeClient, PROFILES

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "0.02"))
QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"

MB = 1024 * 1024
DATASET_LARGE = 64 * MB if QUICK else 256 * MB   # paper: 5 GB
DATASET_SMALL = 16 * MB if QUICK else 64 * MB    # paper: 1 GB


@dataclass
class Env:
    clock: Clock
    tmpdir: str
    local: PosixConnector
    creds: CredentialStore
    service: TransferService
    virtual: bool = False

    def cloud(self, provider: str, placement: str = "local", **overrides):
        storage = make_cloud(provider, clock=self.clock, **overrides)
        conn = ObjectStoreConnector(storage, placement=placement,
                                    clock=self.clock)
        self.creds.register(conn.name, Credential(conn.credential_scheme, {}))
        return storage, conn

    def native(self, storage) -> NativeClient:
        return NativeClient(storage, clock=self.clock)

    def endpoint(self, conn, path):
        return Endpoint(conn, path, conn.name if hasattr(conn, "name")
                        else "local")


def make_env(tmpdir: str, scale: float | None = None,
             virtual: bool = False) -> Env:
    """``virtual=True``: scale=0 (no real sleeping) and measurements read
    the virtual clock — exact for concurrency-1 workloads (the paper's
    §5 regression setting), since all modeled waits are sequential.
    Concurrency sweeps need ``virtual=False`` (real overlap, wall clock).
    """
    clock = Clock(scale=0.0 if virtual else (SCALE if scale is None
                                             else scale))
    local = PosixConnector(os.path.join(tmpdir, "site"))
    creds = CredentialStore()
    service = TransferService(credential_store=creds,
                              marker_root=os.path.join(tmpdir, "markers"),
                              clock=clock)
    return Env(clock=clock, tmpdir=tmpdir, local=local, creds=creds,
               service=service, virtual=virtual)


_payload_cache: dict[int, bytes] = {}


def payload(nbytes: int) -> bytes:
    if nbytes not in _payload_cache:
        _payload_cache[nbytes] = np.random.default_rng(0).bytes(nbytes)
    return _payload_cache[nbytes]


def split_dataset(total: int, n_files: int) -> list[bytes]:
    per = total // n_files
    blob = payload(total)
    return [blob[i * per:(i + 1) * per] for i in range(n_files)]


def seed_local_files(env: Env, name: str, parts: list[bytes]) -> str:
    root = os.path.join(env.tmpdir, "site", name)
    os.makedirs(root, exist_ok=True)
    for i, part in enumerate(parts):
        with open(os.path.join(root, f"f{i:04d}.bin"), "wb") as f:
            f.write(part)
    return name


def seed_bucket(storage, prefix: str, parts: list[bytes]) -> None:
    for i, part in enumerate(parts):
        storage.blobs.put(f"{prefix}/f{i:04d}.bin", part)


def timed(fn, env: Env | None = None) -> float:
    """Model seconds: virtual-clock delta in virtual mode, else
    wall / scale."""
    if env is not None and env.virtual:
        v0 = env.clock.virtual_elapsed
        fn()
        return env.clock.virtual_elapsed - v0
    t0 = time.monotonic()
    fn()
    wall = time.monotonic() - t0
    scale = env.clock.scale if env is not None else SCALE
    return wall / max(scale, 1e-9)


def transfer_model_seconds(env: Env, src: Endpoint, dst: Endpoint,
                           options: TransferOptions) -> float:
    def go():
        task = env.service.submit(src, dst, options, sync=True)
        assert task.status == task.SUCCEEDED, task.events[-5:]

    return timed(go, env)


def native_upload_seconds(env: Env, client: NativeClient, parts: list[bytes],
                          prefix: str, concurrency: int = 1) -> float:
    import threading

    def go():
        client.login()
        if concurrency == 1:
            for i, part in enumerate(parts):
                client.upload_bytes(part, f"{prefix}/f{i:04d}.bin")
            return
        idx = list(range(len(parts)))
        lock = threading.Lock()

        def worker():
            while True:
                with lock:
                    if not idx:
                        return
                    i = idx.pop(0)
                client.upload_bytes(parts[i], f"{prefix}/f{i:04d}.bin")

        ts = [threading.Thread(target=worker) for _ in range(concurrency)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()

    return timed(go, env)


def native_download_seconds(env: Env, client: NativeClient, keys: list[str],
                            concurrency: int = 1) -> float:
    import threading

    def go():
        client.login()
        if concurrency == 1:
            for k in keys:
                client.download_bytes(k)
            return
        idx = list(range(len(keys)))
        lock = threading.Lock()

        def worker():
            while True:
                with lock:
                    if not idx:
                        return
                    i = idx.pop(0)
                client.download_bytes(keys[i])

        ts = [threading.Thread(target=worker) for _ in range(concurrency)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()

    return timed(go, env)


def emit(name: str, model_seconds: float, derived: str = "") -> None:
    """The runner's required CSV: name,us_per_call,derived."""
    print(f"{name},{model_seconds * 1e6:.0f},{derived}")


def batched_route(route: str) -> str:
    """Map a bench_perfile route key to its batched-data-plane
    counterpart (single owner of the '+batch' naming scheme)."""
    return route.replace("/up", "+batch/up").replace("/down", "+batch/down")
