"""Observability-plane benchmark: what does watching the fleet cost?

Tracing is only free to adopt if it is near-free to run.  The headline
number is the fraction of the fleet's CPU budget the observability
plane consumes, estimated as **measured unit cost x measured count**:

* a traced fleet run reports exactly how many spans were recorded, how
  many task bindings were made, and how many metric updates / snapshot
  publishes happened (the tracer and registry keep exact counters);
* tight in-process loops price each primitive — span open/close with
  charges, bind enter/exit, counter/histogram updates, registry
  snapshot — as the *delta* between the enabled and disabled paths
  (the disabled tracer's no-op guards are what un-traced fleets pay);
* overhead_frac = sum(count_i * unit_cost_i) / untraced fleet CPU.

A direct A/B fleet comparison (same fleet, tracer on vs off) was tried
first and is deliberately NOT the gate: on a shared machine both wall
and CPU time of a ~0.2 s fleet run drift several percent between
*adjacent* runs (CPU-frequency scaling, ambient load), an order of
magnitude above the effect being measured, and every pairing/median/
best-of statistic stayed a coin flip at the 5% bar.  The product
estimator has ~0.1% resolution because the noisy quantity (fleet CPU)
only appears in the denominator.

The acceptance bar is **<= 5% overhead** (asserted inline);
``obs.goodput_ratio`` (~1/(1+overhead)) is guarded by the bench-diff
gate so a regression that makes spans expensive fails CI.

The traced runs also re-check the capstone invariant on every task:
``TaskStats.time_budget()`` categories must sum to
``actual_model_seconds`` within 1e-6 — instrumentation that got
cheaper by dropping charges is not an improvement.

Quick mode (REPRO_BENCH_QUICK=1) shrinks the fleet; the comparison and
assertions are the same.
"""

from __future__ import annotations

import gc
import tempfile
import time

from repro.connectors import MemoryConnector
from repro.core import (CredentialStore, Endpoint, TransferManager,
                        TransferOptions)
from repro.core.clock import Clock
from repro.obs import MetricsRegistry, Tracer

from .common import QUICK, emit

TASKS = 8 if QUICK else 12
FILES = 32
#: large enough that per-file data-plane work (copy + checksum fold)
#: dominates the constant per-span bookkeeping, as it does on any real
#: route — with trivial payloads the bench would price span cost
#: against ~zero work
FILE_BYTES = 512 * 1024
#: fleet-CPU runs for the denominator (median) and traced runs for the
#: counts + budget-invariant re-check
FLEET_RUNS = 3
#: tight-loop iterations for the unit-cost measurements
UNIT_N = 4000
#: how often the manager publishes a metrics snapshot (completions)
METRICS_EVERY = 4


def _run_fleet(trace_on: bool) -> tuple[float, dict]:
    """One full fleet run; returns (cpu_seconds, info)."""
    src = MemoryConnector()
    dst = MemoryConnector()
    for t in range(TASKS):
        for i in range(FILES):
            src.store.put(f"t{t}/f{i}.bin", b"x" * FILE_BYTES)
    with tempfile.TemporaryDirectory() as tmp:
        clock = Clock(scale=0.0)
        tracer = Tracer(clock=clock, enabled=trace_on)
        mgr = TransferManager(
            credential_store=CredentialStore(), max_workers=4,
            per_endpoint_cap=None, share_sessions=False,
            marker_root=f"{tmp}/markers", clock=clock,
            tracer=tracer, metrics_every=METRICS_EVERY)
        # coalesce_threshold=0 forces the per-file data plane, where a
        # span opens per send/recv — the worst case for tracing cost
        opts = TransferOptions(startup_cost=0.0, concurrency=2,
                               coalesce_threshold=0)
        c0 = time.process_time()
        tasks = [
            mgr.submit(Endpoint(src, f"t{t}", f"src{t}"),
                       Endpoint(dst, f"out/t{t}", f"dst{t}"),
                       opts, task_id=f"obs-{t}",
                       tenant=f"tenant{t % 2}")
            for t in range(TASKS)
        ]
        ok = mgr.wait_all(timeout=300)
        cpu = time.process_time() - c0
        assert ok, "obs bench fleet did not drain"
        info = {"spans": tracer.spans_recorded,
                "spans_dropped": tracer.spans_dropped,
                "binds": tracer.binds}
        for task in tasks:
            assert task.status == task.SUCCEEDED, task.events[-5:]
            budget = task.stats.time_budget()
            err = abs(sum(budget.values())
                      - task.stats.actual_model_seconds)
            assert err < 1e-6, (task.task_id, err, budget)
        if trace_on:
            # the traced fleet must actually have traced something
            assert tracer.spans_recorded > TASKS, tracer.spans_recorded
        mgr.shutdown(wait=False)
    return cpu, info


def _cpu_loop(fn, n: int) -> float:
    """CPU seconds per call of ``fn`` over a tight loop."""
    fn()  # warm
    c0 = time.process_time()
    for _ in range(n):
        fn()
    return (time.process_time() - c0) / n


def _unit_costs() -> dict:
    """Per-primitive CPU cost, enabled minus disabled, priced in this
    very process so machine state matches the fleet runs."""
    clock = Clock(scale=0.0)
    cost: dict = {}
    per_flavour: dict = {}
    for on in (True, False):
        tracer = Tracer(clock=clock, enabled=on)

        def one_span():
            with tracer.span("op", "wire", path="p"):
                clock.sleep(1e-12)  # exercises the sleep charge hook
                clock.sleep(1e-12)

        with tracer.bind("trace-ubench", "ubench"):
            per_flavour[("span", on)] = _cpu_loop(one_span, UNIT_N)

        def one_bind():
            with tracer.bind("trace-ubench", "ubench"):
                pass

        per_flavour[("bind", on)] = _cpu_loop(one_bind, UNIT_N)

    # what the traced fleet pays OVER the untraced one, per op
    cost["span"] = max(0.0, per_flavour[("span", True)]
                       - per_flavour[("span", False)])
    cost["bind"] = max(0.0, per_flavour[("bind", True)]
                       - per_flavour[("bind", False)])

    # metrics primitives have no disabled flavour: untraced fleets keep
    # the registry too, but the per-completion update path only runs a
    # handful of times per task, so its full cost is charged
    reg = MetricsRegistry()
    ctr = reg.counter("tasks_total", "bench")
    hist = reg.histogram("task_model_seconds", "bench")
    cost["metric_update"] = _cpu_loop(
        lambda: ctr.inc(site="s", tenant="t"), UNIT_N)
    cost["metric_observe"] = _cpu_loop(
        lambda: hist.observe(1.25, site="s"), UNIT_N)
    cost["snapshot"] = _cpu_loop(reg.snapshot, max(64, UNIT_N // 16))
    return cost


def run() -> dict:
    gc.collect()
    # traced fleet: exact op counts + the budget invariants; run a few
    # and keep the counts (identical across runs by construction)
    info: dict = {}
    for _ in range(FLEET_RUNS):
        _, info = _run_fleet(trace_on=True)
    # untraced fleet CPU: the denominator the overhead is priced
    # against (median of a few runs rides out ambient drift)
    cpus = sorted(_run_fleet(trace_on=False)[0]
                  for _ in range(FLEET_RUNS))
    fleet_cpu = cpus[len(cpus) // 2]

    cost = _unit_costs()
    # per-task metric traffic: tasks_total.inc + task_seconds.observe
    # + queue_wait.observe, plus a registry snapshot every
    # METRICS_EVERY completions
    metric_updates = TASKS
    metric_observes = 2 * TASKS
    snapshots = TASKS // METRICS_EVERY
    obs_cpu = (info["spans"] * cost["span"]
               + info["binds"] * cost["bind"]
               + metric_updates * cost["metric_update"]
               + metric_observes * cost["metric_observe"]
               + snapshots * cost["snapshot"])
    overhead_frac = obs_cpu / fleet_cpu
    goodput_ratio = 1.0 / (1.0 + overhead_frac)
    spans_per_task = info["spans"] / TASKS

    emit("obs.trace.overhead", overhead_frac,
         f"obs_cpu_ms={obs_cpu * 1e3:.2f} fleet_cpu_s={fleet_cpu:.3f} "
         f"span_us={cost['span'] * 1e6:.2f} "
         f"spans/task={spans_per_task:.0f}")
    assert overhead_frac <= 0.05, (
        f"tracing+metrics overhead {overhead_frac:.1%} exceeds the 5% "
        f"acceptance bar (obs_cpu={obs_cpu * 1e3:.2f}ms "
        f"fleet_cpu={fleet_cpu:.3f}s)")
    return {"goodput_ratio": goodput_ratio,
            "overhead_frac": overhead_frac,
            "fleet_cpu": fleet_cpu,
            "span_cost_us": cost["span"] * 1e6,
            "bind_cost_us": cost["bind"] * 1e6,
            "spans": info["spans"],
            "spans_dropped": info["spans_dropped"],
            "binds": info["binds"],
            "spans_per_task": spans_per_task}


if __name__ == "__main__":
    run()
