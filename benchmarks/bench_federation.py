"""Federation-plane benchmarks: fleet goodput vs site count, and the
control-plane cost of moving a live task between sites.

The paper's third-party orchestrator earns horizontal scale by adding
*control planes*, not data movers; this bench drives a
:class:`~repro.fed.FederatedCoordinator` over 1..N sites (each with its
own worker budget and its own drive-profile destination endpoint) and
reports:

* ``fed.fleet.sNN`` — aggregate goodput as sites are added: each site
  brings workers and endpoints, so goodput should scale with the site
  count until the shared source saturates;
* ``fed.handoff.latency`` — wall-clock cost of a full handoff
  (export + JSON round-trip + import) of a paused mid-flight task,
  measured on the control plane only;
* ``fed.handoff.bytes_saved`` — the fraction of the task the traveled
  hole map spares the new site from re-sending;
* ``fed.spec.roundtrip`` — TransferSpec JSON serialize+parse cost (the
  per-submission wire tax).

Every run ends with ``assert_third_party()``: if the coordinator ever
charged model time, the suite fails.
"""

from __future__ import annotations

import os
import tempfile
import time

from repro.catalog import ReplicaCatalog
from repro.connectors import ObjectStoreConnector, PosixConnector, make_cloud
from repro.core import (Credential, CredentialStore, TransferManager,
                        TransferOptions)
from repro.core.clock import Clock
from repro.fed import FederatedCoordinator, TransferSpec
from repro.sim.scenarios import _HoldSrc, _MeteredSrc

from .common import MB, QUICK, emit, split_dataset

SITE_COUNTS = (1, 2) if QUICK else (1, 2, 4)
TASKS_PER_SITE = 2 if QUICK else 4
FILES_PER_TASK = 6 if QUICK else 12
FILE_KB = 16
WORKERS_PER_SITE = 3
BENCH_SCALE = 0.1  # see bench_manager: latency-dominated, overlap-visible
PROVIDER = "drive"
OVERRIDES = {"quota_rate": 10_000, "quota_burst": 100_000,
             "consistency_delay": 0.0}
KB = 1024


def _build_federation(tmp: str, clock: Clock, n_sites: int,
                      src_factory=None, catalog=None):
    """One coordinator over ``n_sites`` sites: site ``i`` owns its own
    posix source root and its own emulated cloud destination."""
    coord = FederatedCoordinator(placement="owner", catalog=catalog)
    endpoints = {}
    src_conns = []
    for i in range(n_sites):
        src_conn = PosixConnector(os.path.join(tmp, f"site{i}"))
        if src_factory is not None:
            src_conn = src_factory(i, src_conn)
        storage = make_cloud(PROVIDER, clock=clock, **OVERRIDES)
        dst_conn = ObjectStoreConnector(storage, placement="local",
                                        clock=clock)
        endpoints[f"src-s{i}"] = src_conn
        endpoints[f"dst-s{i}"] = dst_conn
        src_conns.append(src_conn)
    for i in range(n_sites):
        creds = CredentialStore()
        for k in range(n_sites):
            creds.register(f"dst-s{k}", Credential(
                endpoints[f"dst-s{k}"].credential_scheme, {}))
        mgr = TransferManager(
            max_workers=WORKERS_PER_SITE, per_endpoint_cap=None,
            credential_store=creds,
            marker_root=os.path.join(tmp, f"markers{i}"), clock=clock)
        coord.register_site(f"s{i}", mgr, endpoints,
                            owns={f"src-s{i}", f"dst-s{i}"})
    return coord, src_conns


def _seed_task_files(tmp: str, site: int, name: str,
                     parts: list[bytes]) -> None:
    root = os.path.join(tmp, f"site{site}", name)
    os.makedirs(root, exist_ok=True)
    for i, part in enumerate(parts):
        with open(os.path.join(root, f"f{i:04d}.bin"), "wb") as f:
            f.write(part)


def bench_goodput() -> dict:
    out = {}
    per_task_bytes = FILES_PER_TASK * FILE_KB * 1024
    parts = split_dataset(per_task_bytes, FILES_PER_TASK)
    opts = TransferOptions(concurrency=2, startup_cost=0.0,
                           coalesce_threshold=0)
    for n_sites in SITE_COUNTS:
        with tempfile.TemporaryDirectory() as tmp:
            clock = Clock(scale=BENCH_SCALE)
            coord, _ = _build_federation(tmp, clock, n_sites)
            n_tasks = TASKS_PER_SITE * n_sites
            specs = []
            for j in range(n_tasks):
                site = j % n_sites
                _seed_task_files(tmp, site, f"fleet{j}", parts)
                specs.append(TransferSpec.new(
                    f"fed-{n_sites}-{j}", f"src-s{site}", f"fleet{j}",
                    f"dst-s{site}", f"bkt/fleet{j}",
                    tenant=("alice", "bob")[j % 2], options=opts,
                    n_files=FILES_PER_TASK, nbytes=per_task_bytes))
            t0 = time.monotonic()
            tasks = [coord.submit(spec.to_json()) for spec in specs]
            ok = coord.wait_all(timeout=600)
            makespan = (time.monotonic() - t0) / BENCH_SCALE
            assert ok, "federated fleet did not finish"
            for t in tasks:
                assert t.status == t.SUCCEEDED, t.events[-3:]
            coord.assert_third_party()
            goodput = n_tasks * per_task_bytes / max(makespan, 1e-9) / MB
            out[n_sites] = {"model_s": makespan,
                            "goodput_mb_s": goodput}
            emit(f"fed.fleet.s{n_sites:02d}", makespan,
                 f"goodput={goodput:.1f}MB/s tasks={n_tasks} "
                 f"workers/site={WORKERS_PER_SITE}")
            coord.shutdown(wait=False)
    base = out[SITE_COUNTS[0]]["goodput_mb_s"]
    top = out[SITE_COUNTS[-1]]["goodput_mb_s"]
    emit("fed.fleet.scaling", 0.0,
         f"x{top / max(base, 1e-9):.2f} goodput at "
         f"{SITE_COUNTS[-1]} sites")
    return out


def bench_handoff() -> dict:
    """Full handoff of a paused mid-flight task: pause+drain excluded
    (data-plane dependent), export -> JSON -> import measured as the
    pure control-plane hop."""
    with tempfile.TemporaryDirectory() as tmp:
        clock = Clock(scale=0.0)
        holds = {}

        def src_factory(i, conn):
            holds[i] = _HoldSrc(conn)
            return holds[i]

        coord, src_conns = _build_federation(tmp, clock, 2,
                                             src_factory=src_factory)
        task_bytes = 4 * MB
        parts = split_dataset(task_bytes, 8)
        _seed_task_files(tmp, 0, "hand0", parts)
        holds[0].arm_hold(["hand0"], 1 * MB)
        spec = TransferSpec.new(
            "handoff-0", "src-s0", "hand0", "dst-s0", "bkt/hand0",
            tenant="alice",
            options=TransferOptions(concurrency=1, startup_cost=0.0,
                                    coalesce_threshold=0,
                                    blocksize=256 * KB),
            n_files=8, nbytes=task_bytes)
        task = coord.submit(spec.to_json())
        assert holds[0].engaged.wait(30), "hold never engaged"
        site_a = coord.sites()["s0"]
        site_a.manager.pause("handoff-0")
        holds[0].release()
        deadline = time.monotonic() + 30
        payload = None
        while payload is None and time.monotonic() < deadline:
            task.wait_idle(0.05)
            payload = site_a.manager.export_state("handoff-0")
        assert payload is not None, "task never drained to exportable"

        # the measured hop: serialize -> wire -> parse -> adopt
        t0 = time.perf_counter()
        traveled = TransferSpec.from_json(
            TransferSpec.from_payload(payload).to_json())
        site_b = coord.sites()["s1"]
        src, dst = site_b.endpoint_pair(traveled)
        task_b = site_b.manager.import_state(traveled.to_payload(),
                                             src, dst)
        dt = time.perf_counter() - t0
        assert task_b.wait(60)
        assert task_b.status == task_b.SUCCEEDED, task_b.events[-3:]
        saved = traveled.done_bytes() / task_bytes
        coord.assert_third_party()
        emit("fed.handoff.latency", dt,
             f"wall_ms={dt * 1e3:.2f} marker_files="
             f"{len(traveled.markers['files'])}")
        emit("fed.handoff.bytes_saved", 0.0,
             f"{saved:.2%} of {task_bytes // MB}MB not re-sent "
             f"({traveled.done_bytes()} bytes traveled as done)")
        coord.shutdown(wait=False)
        return {"latency_s": dt, "bytes_saved_frac": saved}


def bench_fanout() -> dict:
    """Fan-out dedupe through the replica catalog: N identical
    submissions against one federation must collapse to ~1 real
    transfer plus N-1 verified replica reads at the destination.
    Reports bytes-NOT-moved from the source and the catalog hit rate —
    the two columns the CI bench-regression gate guards."""
    n_fanout = 4
    with tempfile.TemporaryDirectory() as tmp:
        clock = Clock(scale=0.0)
        meters = {}

        def src_factory(i, conn):
            meters[i] = _MeteredSrc(conn)
            return meters[i]

        catalog = ReplicaCatalog()
        coord, _ = _build_federation(tmp, clock, 1,
                                     src_factory=src_factory,
                                     catalog=catalog)
        per_task_bytes = FILES_PER_TASK * FILE_KB * 1024
        parts = split_dataset(per_task_bytes, FILES_PER_TASK)
        _seed_task_files(tmp, 0, "fan0", parts)
        # integrity on: the catalog only trusts §7-folded content keys
        opts = TransferOptions(concurrency=2, startup_cost=0.0,
                               coalesce_threshold=0, integrity=True)

        def spec(k: int) -> TransferSpec:
            return TransferSpec.new(
                f"fanout-{k}", "src-s0", "fan0", "dst-s0", f"bkt/fan{k}",
                tenant=("alice", "bob")[k % 2], options=opts,
                n_files=FILES_PER_TASK, nbytes=per_task_bytes)

        # the one real transfer populates the catalog ...
        tasks = [coord.submit(spec(0).to_json())]
        assert coord.wait_all(timeout=600), "fan-out seed did not finish"
        # ... then the fan-out rides it
        tasks += [coord.submit(spec(k).to_json())
                  for k in range(1, n_fanout)]
        assert coord.wait_all(timeout=600), "fan-out did not finish"
        for t in tasks:
            assert t.status == t.SUCCEEDED, t.events[-3:]
        coord.assert_third_party()

        source_bytes = meters[0].sent("fan0")
        naive = n_fanout * per_task_bytes
        moved_ratio = source_bytes / per_task_bytes
        not_moved_frac = (naive - source_bytes) / naive
        hit_rate = catalog.hit_rate()
        emit("fed.fanout.dedupe", 0.0,
             f"moved_ratio={moved_ratio:.3f} hit_rate={hit_rate:.2f} "
             f"bytes_not_moved={not_moved_frac:.2%} of "
             f"{naive // KB}KB nominal")
        coord.shutdown(wait=False)
        return {"n_fanout": n_fanout, "moved_ratio": moved_ratio,
                "hit_rate": hit_rate,
                "bytes_not_moved_frac": not_moved_frac}


def bench_spec_roundtrip() -> dict:
    n = 200 if QUICK else 1000
    markers = {"files": {
        f"data/f{i:03d}.bin": {
            "done": [[0, 65536], [131072, 65536]], "complete": False,
            "digests": {"0:65536": "ab" * 32, "131072:65536": "cd" * 32}}
        for i in range(16)}}
    spec = TransferSpec.new(
        "rt-0", "src-s0", "data", "dst-s0", "out", tenant="alice",
        options=TransferOptions(), n_files=16, nbytes=16 * MB)
    spec.state = "paused"
    spec.markers = markers
    t0 = time.perf_counter()
    for _ in range(n):
        spec = TransferSpec.from_json(spec.to_json())
    dt = (time.perf_counter() - t0) / n
    emit("fed.spec.roundtrip", dt,
         f"us={dt * 1e6:.0f} wire_bytes={len(spec.to_json())}")
    return {"roundtrip_s": dt}


def run() -> dict:
    return {"goodput": bench_goodput(), "handoff": bench_handoff(),
            "fanout": bench_fanout(), "spec": bench_spec_roundtrip()}


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
