"""Framework benchmarks: Connector-backed data-pipeline ingest."""

from __future__ import annotations

import tempfile
import time

from repro.connectors import MemoryConnector
from repro.data import (DataPipelineConfig, ShardedTokenDataset,
                        synthetic_corpus)

from .common import QUICK, emit


def run() -> dict:
    out = {}
    conn = MemoryConnector()
    n_records = 128 if QUICK else 512
    synthetic_corpus(conn, "corpus", vocab_size=32000, seq_len=512,
                     n_records=n_records, records_per_shard=64)

    for mode in ("plain", "prefetch"):
        cfg = DataPipelineConfig(seq_len=512, batch_size=8, prefetch=4)
        ds = ShardedTokenDataset(conn, "corpus", cfg)
        it = ds.prefetching_batches() if mode == "prefetch" else ds.batches()
        n_batches = n_records // 8
        t0 = time.monotonic()
        tok = 0
        for _, b in zip(range(n_batches), it):
            tok += b["tokens"].size
            # simulate a 1 ms train step so prefetch can overlap
            time.sleep(0.001)
        dt = time.monotonic() - t0
        out[mode] = tok / dt
        emit(f"data.ingest.{mode}", dt, f"{tok / dt / 1e6:.2f}M tok/s")
    return out


if __name__ == "__main__":
    run()
