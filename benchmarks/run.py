"""Benchmark runner — one harness per paper table/figure plus framework
benches.  Prints ``name,us_per_call,derived`` CSV rows (us_per_call is
model-microseconds for emulated-transfer benches; see common.py).

When the ``perfile`` suite runs, the fitted models are also written to
``BENCH_perfile.json`` (per route: t0, throughput, rho, and — where the
batched data plane was fitted — t0_batched and the speedup), so the
per-file-overhead trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def _write_perfile_json(models: dict, path: str = "BENCH_perfile.json") -> None:
    """Serialize bench_perfile's fitted models, pairing each route with
    its ``+batch`` counterpart."""
    from .common import batched_route

    out = {}
    for route, m in models.items():
        if "+batch" in route:
            continue
        rec = {"t0": m.t0, "alpha": m.alpha, "throughput": m.throughput,
               "rho": m.rho, "r2": m.r2, "s0": m.s0}
        batched = models.get(batched_route(route))
        if batched is not None:
            rec["t0_batched"] = batched.t0
            rec["rho_batched"] = batched.rho
            rec["t0_speedup"] = (m.t0 / batched.t0
                                 if batched.t0 > 0 else float("inf"))
        out[route] = rec
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"# wrote {path} ({len(out)} routes)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small N / fewer providers")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: perfile,startup,"
                         "throughput,integrity,intercloud,chaos,ckpt,"
                         "data,kernels")
    args = ap.parse_args()
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"

    # import AFTER the env flag so common.py picks it up
    from . import (bench_chaos, bench_ckpt, bench_data, bench_integrity,
                   bench_intercloud, bench_kernels, bench_manager,
                   bench_perfile, bench_startup, bench_throughput)

    suites = {
        "perfile": bench_perfile.run,        # Figs 6-11 + Table 1
        "startup": bench_startup.run,        # Fig 12 (Eq. 6)
        "throughput": bench_throughput.run,  # Figs 13-16
        "intercloud": bench_intercloud.run,  # Figs 17-18
        "integrity": bench_integrity.run,    # Figs 19-21
        "chaos": bench_chaos.run,            # goodput vs fault rate
        "manager": bench_manager.run,        # fleet goodput + fairness
        "ckpt": bench_ckpt.run,              # framework: §8 coalescing
        "data": bench_data.run,              # framework: ingest
        "kernels": bench_kernels.run,        # framework: pallas kernels
    }
    wanted = (args.only.split(",") if args.only else list(suites))
    unknown = [name for name in wanted if name not in suites]
    if unknown:
        print(f"# unknown suite(s): {','.join(unknown)}", file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    t0 = time.monotonic()
    failed: list[str] = []
    for name in wanted:
        print(f"# --- {name} ---", file=sys.stderr)
        try:
            result = suites[name]()
        except Exception:
            # a broken benchmark must fail the scripted run (CI gates on
            # the exit code), not scroll past as a stack trace
            traceback.print_exc()
            failed.append(name)
            continue
        if name == "perfile" and result:
            _write_perfile_json(result)
    print(f"# total wall: {time.monotonic() - t0:.1f}s", file=sys.stderr)
    if failed:
        print(f"# FAILED suites: {','.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
