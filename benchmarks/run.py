"""Benchmark runner — one harness per paper table/figure plus framework
benches.  Prints ``name,us_per_call,derived`` CSV rows (us_per_call is
model-microseconds for emulated-transfer benches; see common.py).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small N / fewer providers")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: perfile,startup,"
                         "throughput,integrity,intercloud,ckpt,data,kernels")
    args = ap.parse_args()
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"

    # import AFTER the env flag so common.py picks it up
    from . import (bench_ckpt, bench_data, bench_integrity,
                   bench_intercloud, bench_kernels, bench_perfile,
                   bench_startup, bench_throughput)

    suites = {
        "perfile": bench_perfile.run,        # Figs 6-11 + Table 1
        "startup": bench_startup.run,        # Fig 12 (Eq. 6)
        "throughput": bench_throughput.run,  # Figs 13-16
        "intercloud": bench_intercloud.run,  # Figs 17-18
        "integrity": bench_integrity.run,    # Figs 19-21
        "ckpt": bench_ckpt.run,              # framework: §8 coalescing
        "data": bench_data.run,              # framework: ingest
        "kernels": bench_kernels.run,        # framework: pallas kernels
    }
    wanted = (args.only.split(",") if args.only else list(suites))
    print("name,us_per_call,derived")
    t0 = time.monotonic()
    for name in wanted:
        print(f"# --- {name} ---", file=sys.stderr)
        suites[name]()
    print(f"# total wall: {time.monotonic() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
