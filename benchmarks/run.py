"""Benchmark runner — one harness per paper table/figure plus framework
benches.  Prints ``name,us_per_call,derived`` CSV rows (us_per_call is
model-microseconds for emulated-transfer benches; see common.py).

Every suite that runs also persists its result dict as
``BENCH_<suite>.json`` (stable name, sorted keys) — the committed
baselines the ``bench-diff`` CI lane compares fresh runs against (see
:mod:`benchmarks.diff`).  ``perfile`` keeps its richer model dump (per
route: t0, throughput, rho, and — where the batched data plane was
fitted — t0_batched and the speedup), so the per-file-overhead
trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
                                            [--out DIR]
"""

from __future__ import annotations

import argparse
import importlib
import json
import math
import os
import sys
import time
import traceback

#: THE suite registry: name -> (module under benchmarks/, one-line why).
#: The CLI help, unknown-suite guard, and default run order all derive
#: from this — adding a bench here is the whole registration.
SUITES: dict[str, tuple[str, str]] = {
    "perfile": ("bench_perfile", "Figs 6-11 + Table 1"),
    "startup": ("bench_startup", "Fig 12 (Eq. 6)"),
    "throughput": ("bench_throughput", "Figs 13-16"),
    "intercloud": ("bench_intercloud", "Figs 17-18"),
    "integrity": ("bench_integrity", "Figs 19-21"),
    "chaos": ("bench_chaos", "goodput vs fault rate"),
    "resilience": ("bench_resilience", "health plane: breakers + failover"),
    "manager": ("bench_manager", "fleet goodput + fairness + refit"),
    "federation": ("bench_federation", "multi-site goodput + handoff"),
    "svc": ("bench_svc", "service plane: streaming status vs polling"),
    "obs": ("bench_obs", "observability plane: tracing+metrics overhead"),
    "ckpt": ("bench_ckpt", "framework: §8 coalescing"),
    "data": ("bench_data", "framework: ingest"),
    "kernels": ("bench_kernels", "framework: pallas kernels"),
}


def _write_perfile_json(models: dict, path: str = "BENCH_perfile.json") -> None:
    """Serialize bench_perfile's fitted models, pairing each route with
    its ``+batch`` counterpart."""
    from .common import batched_route

    out = {}
    for route, m in models.items():
        if "+batch" in route:
            continue
        rec = {"t0": m.t0, "alpha": m.alpha, "throughput": m.throughput,
               "rho": m.rho, "r2": m.r2, "s0": m.s0}
        batched = models.get(batched_route(route))
        if batched is not None:
            rec["t0_batched"] = batched.t0
            rec["rho_batched"] = batched.rho
            rec["t0_speedup"] = (m.t0 / batched.t0
                                 if batched.t0 > 0 else float("inf"))
        out[route] = rec
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"# wrote {path} ({len(out)} routes)", file=sys.stderr)


def _sanitize(value):
    """JSON-clean a suite result: stringify exotic keys/values, keep
    numbers (non-finite floats become strings so json stays strict)."""
    if isinstance(value, dict):
        return {str(k): _sanitize(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_sanitize(v) for v in value]
    if isinstance(value, bool) or value is None or isinstance(value, str):
        return value
    if isinstance(value, int):
        return value
    if isinstance(value, float):
        return value if math.isfinite(value) else str(value)
    return str(value)


def _write_suite_json(name: str, result: dict, out_dir: str) -> None:
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(_sanitize(result), f, indent=1, sort_keys=True)
    print(f"# wrote {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small N / fewer providers")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: " + ",".join(SUITES))
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_<suite>.json baselines "
                         "(default: cwd)")
    args = ap.parse_args()
    wanted = (args.only.split(",") if args.only else list(SUITES))
    unknown = [name for name in wanted if name not in SUITES]
    if unknown:
        print(f"# unknown suite(s): {','.join(unknown)} "
              f"(known: {','.join(SUITES)})", file=sys.stderr)
        sys.exit(2)
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"

    print("name,us_per_call,derived")
    t0 = time.monotonic()
    failed: list[str] = []
    for name in wanted:
        module_name, why = SUITES[name]
        print(f"# --- {name} ({why}) ---", file=sys.stderr)
        try:
            # import AFTER the env flag so common.py picks QUICK up
            module = importlib.import_module(f".{module_name}",
                                             package=__package__)
            result = module.run()
        except Exception:
            # a broken benchmark must fail the scripted run (CI gates on
            # the exit code), not scroll past as a stack trace
            traceback.print_exc()
            failed.append(name)
            continue
        if name == "perfile" and result:
            _write_perfile_json(result,
                                path=os.path.join(args.out,
                                                  "BENCH_perfile.json"))
        elif result:
            _write_suite_json(name, result, args.out)
    print(f"# total wall: {time.monotonic() - t0:.1f}s", file=sys.stderr)
    if failed:
        print(f"# FAILED suites: {','.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
