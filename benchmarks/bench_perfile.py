"""Paper §5 / Figs. 6-11 + Table 1: transfer time vs number of files at
fixed total size; OLS regression -> per-file overhead t0 and network
efficiency alpha; Pearson rho validates linearity.

Each Connector route is fitted twice: once on the per-file path (the
paper's setting) and once with small-file coalescing enabled
(``coalesce_threshold`` + Connector bulk data plane), so the per-file
overhead reduction from batching is tracked as ``t0`` vs ``t0_batched``
per route (see ``BENCH_perfile.json`` emitted by ``benchmarks.run``).
"""

from __future__ import annotations

import tempfile

from repro.core import TransferOptions
from repro.core.perfmodel import fit_perf_model

from .common import (DATASET_LARGE, DATASET_SMALL, MB, QUICK, emit, make_env,
                     seed_bucket, seed_local_files, split_dataset,
                     transfer_model_seconds, native_upload_seconds,
                     native_download_seconds, Endpoint)

N_FILES = [8, 16, 32] if QUICK else [10, 20, 40, 80]

#: provider -> (dataset size, has conn-cloud placement) — mirrors the
#: paper's matrix (Wasabi/Drive/Box have no in-cloud DTN option).
MATRIX = {
    "s3": (DATASET_LARGE, True),
    "wasabi": (DATASET_LARGE, False),
    "gcs": (DATASET_LARGE, True),
    "drive": (DATASET_SMALL, False),
    "box": (DATASET_SMALL, False),
    "ceph": (DATASET_LARGE, True),
}

#: the two data-plane modes fitted per Connector route.  The batched
#: mode raises the coalescing threshold above every per-file size in
#: the sweep so the whole transfer rides the bulk API.
MODES = {
    "": dict(concurrency=1, parallelism=4, coalesce_threshold=0),
    "+batch": dict(concurrency=1, parallelism=4,
                   coalesce_threshold=512 * MB, max_batch_files=256),
}


def _routes_for(env, provider, has_cloud):
    storage, conn_local = env.cloud(provider, "local")
    routes = {"conn-local": (storage, conn_local)}
    if has_cloud:
        conn_cloud = type(conn_local)(storage, placement="cloud",
                                      clock=env.clock)
        env.creds.register(conn_cloud.name, env.creds.lookup(conn_local.name))
        routes["conn-cloud"] = (storage, conn_cloud)
    return storage, routes


def run(full: bool = True) -> dict:
    """Returns {route: PerfModel}; emits one CSV row per fitted model.
    Routes fitted with batching enabled are keyed ``<route>+batch``."""
    providers = list(MATRIX) if full else ["s3", "drive"]
    models = {}
    pearson_rows = []
    # The paper's §5 regression runs at concurrency 1; with a single
    # stream the virtual clock measures the modeled time exactly.
    S0_CONN, S0_NATIVE = 2.3, 0.15   # resolved independently in bench_startup
    for provider in providers:
        total, has_cloud = MATRIX[provider]
        with tempfile.TemporaryDirectory() as tmp:
            env = make_env(tmp, virtual=True)
            storage, routes = _routes_for(env, provider, has_cloud)
            native = env.native(storage)

            # ---------- uploads (local files -> cloud) ----------
            for route_name, (sto, conn) in routes.items():
                for mode, opts in MODES.items():
                    times = []
                    for n in N_FILES:
                        parts = split_dataset(total, n)
                        src = seed_local_files(
                            env, f"up{mode}_{provider}_{n}", parts)
                        t = transfer_model_seconds(
                            env, Endpoint(env.local, src),
                            Endpoint(conn, f"bkt/up{mode}{n}", conn.name),
                            TransferOptions(**opts))
                        times.append(t)
                        sto.blobs._objs.clear()
                    m = fit_perf_model(f"{provider}/{route_name}{mode}/up",
                                       N_FILES, times, total, s0=S0_CONN)
                    models[m.route] = m
                    if not mode:  # Table 1 tracks the paper's setting
                        pearson_rows.append(
                            (f"To {provider} ({route_name})", m.rho))
                    emit(f"perfile.{provider}.{route_name}{mode}.upload",
                         times[-1],
                         f"t0={m.t0:.3f}s R={m.throughput/1e6:.0f}MB/s"
                         f" rho={m.rho:.3f}")
            # native upload
            times = []
            for n in N_FILES:
                parts = split_dataset(total, n)
                t = native_upload_seconds(env, native, parts, f"nu{n}")
                times.append(t)
                storage.blobs._objs.clear()
            m = fit_perf_model(f"{provider}/native/up", N_FILES, times, total,
                               s0=S0_NATIVE)
            models[m.route] = m
            pearson_rows.append((f"To {provider} (native)", m.rho))
            emit(f"perfile.{provider}.native.upload", times[-1],
                 f"t0={m.t0:.3f}s R={m.throughput/1e6:.0f}MB/s rho={m.rho:.3f}")

            # ---------- downloads (cloud -> local files) ----------
            for route_name, (sto, conn) in routes.items():
                for mode, opts in MODES.items():
                    times = []
                    for n in N_FILES:
                        parts = split_dataset(total, n)
                        seed_bucket(sto, f"down{mode}{n}", parts)
                        t = transfer_model_seconds(
                            env, Endpoint(conn, f"down{mode}{n}", conn.name),
                            Endpoint(env.local,
                                     f"dl{mode}_{provider}_{route_name}_{n}"),
                            TransferOptions(**opts))
                        times.append(t)
                    m = fit_perf_model(f"{provider}/{route_name}{mode}/down",
                                       N_FILES, times, total, s0=S0_CONN)
                    models[m.route] = m
                    if not mode:
                        pearson_rows.append(
                            (f"From {provider} ({route_name})", m.rho))
                    emit(f"perfile.{provider}.{route_name}{mode}.download",
                         times[-1],
                         f"t0={m.t0:.3f}s R={m.throughput/1e6:.0f}MB/s"
                         f" rho={m.rho:.3f}")
            # native download
            times = []
            for n in N_FILES:
                parts = split_dataset(total, n)
                seed_bucket(storage, f"nd{n}", parts)
                keys = [f"nd{n}/f{i:04d}.bin" for i in range(n)]
                times.append(native_download_seconds(env, native, keys))
            m = fit_perf_model(f"{provider}/native/down", N_FILES, times,
                               total, s0=S0_NATIVE)
            models[m.route] = m
            pearson_rows.append((f"From {provider} (native)", m.rho))
            emit(f"perfile.{provider}.native.download", times[-1],
                 f"t0={m.t0:.3f}s R={m.throughput/1e6:.0f}MB/s rho={m.rho:.3f}")

    # Table 1 analog: all correlations should be ~1
    min_rho = min(r for _, r in pearson_rows)
    emit("perfile.pearson_table.min_rho", 0.0,
         f"min_rho={min_rho:.3f} over {len(pearson_rows)} routes")
    return models


if __name__ == "__main__":
    run()
