"""Paper §5.4 / Fig. 12: single-file size sweep resolves the transfer
startup cost S0 (Eq. 6): third-party managed transfers pay coordination
cost; two-party native clients pay only login."""

from __future__ import annotations

import tempfile

from repro.core import TransferOptions
from repro.core.perfmodel import fit_startup_cost

from .common import (MB, QUICK, emit, make_env, payload, seed_local_files,
                     timed, transfer_model_seconds, Endpoint)

SIZES_MB = [4, 12, 20, 28] if QUICK else [8, 24, 40, 56, 72]


def run() -> dict:
    out = {}
    with tempfile.TemporaryDirectory() as tmp:
        env = make_env(tmp, virtual=True)
        storage, conn = env.cloud("wasabi", "local")
        native = env.native(storage)

        # managed third-party transfer (Globus role)
        times = []
        for mb in SIZES_MB:
            src = seed_local_files(env, f"s{mb}", [payload(mb * MB)])
            t = transfer_model_seconds(
                env, Endpoint(env.local, f"{src}/f0000.bin"),
                Endpoint(conn, f"b/one{mb}.bin", conn.name),
                TransferOptions(concurrency=1, parallelism=4))
            times.append(t)
            storage.blobs._objs.clear()
        s0, tu = fit_startup_cost([m * MB for m in SIZES_MB], times)
        out["connector"] = s0
        emit("startup.connector.s0", s0,
             f"S0={s0:.2f}s t_u={tu * 1e9:.2f}s/GB (paper: 2.3s)")

        # two-party native API
        times = []
        for mb in SIZES_MB:
            def go():
                native.login()
                native.upload_bytes(payload(mb * MB), f"n/one{mb}.bin")
            times.append(timed(go, env))
            storage.blobs._objs.clear()
        s0n, tun = fit_startup_cost([m * MB for m in SIZES_MB], times)
        out["native"] = s0n
        emit("startup.native.s0", s0n,
             f"S0={s0n:.2f}s t_u={tun * 1e9:.2f}s/GB")
    return out


if __name__ == "__main__":
    run()
