"""Chaos benchmark: goodput degradation vs injected fault rate.

GridFTP's fault-tolerance line of work (and the paper's §4 retry story)
argues that a transfer fabric is judged by its behaviour *under*
failures, not beside them.  This bench sweeps a seed-deterministic
probability of transient faults + rate-limit storms injected through a
:class:`FaultProxyConnector` in front of an emulated S3 Connector and
reports modeled transfer time, goodput, and how many faults the service
absorbed.  Because decisions are hash-seeded, every row is reproducible.

Emits: ``chaos.s3.pXX`` rows — model time plus
``goodput=... faults=... fallbacks=...`` in the derived column.
"""

from __future__ import annotations

import tempfile

from repro.connectors import FaultProxyConnector
from repro.core import Endpoint, FaultSchedule, TransferOptions

from .common import MB, QUICK, emit, make_env, seed_local_files, split_dataset

FAULT_RATES = (0.0, 0.05, 0.2) if QUICK else (0.0, 0.02, 0.05, 0.1, 0.2, 0.4)
N_FILES = 16 if QUICK else 48
FILE_KB = 128


def _schedule(rate: float) -> FaultSchedule:
    sched = FaultSchedule(seed=1234)
    if rate > 0:
        # mid-stream transients on block reads, per-file admission
        # faults, and occasional quota storms — all scaled by the rate
        sched.transient(op="read", prob=rate, times=None)
        sched.transient(op="recv", prob=rate / 2, times=None)
        sched.rate_limit(op="recv*", prob=rate / 4, times=None,
                         retry_after=0.2)
    return sched


def run() -> dict:
    out = {}
    total = N_FILES * FILE_KB * 1024
    for rate in FAULT_RATES:
        with tempfile.TemporaryDirectory() as tmp:
            env = make_env(tmp, virtual=True)
            storage, conn = env.cloud("s3", "local")
            sched = _schedule(rate)
            proxy = FaultProxyConnector(conn, sched, clock=env.clock)
            env.creds.register("chaos-dst",
                               env.creds.lookup(conn.name))
            parts = split_dataset(total, N_FILES)
            src = seed_local_files(env, f"chaos{int(rate * 100):02d}", parts)
            v0 = env.clock.virtual_elapsed
            task = env.service.submit(
                Endpoint(env.local, src),
                Endpoint(proxy, f"bkt/chaos{int(rate * 100):02d}",
                         "chaos-dst"),
                TransferOptions(concurrency=4, startup_cost=0.0,
                                retry_backoff=0.05), sync=True)
            dt = env.clock.virtual_elapsed - v0
            st = task.stats
            goodput = st.bytes_done / max(dt, 1e-9) / MB
            out[rate] = {"model_s": dt, "goodput_mb_s": goodput,
                         "faults": st.faults_retried,
                         "fallbacks": st.batch_fallbacks,
                         "status": task.status}
            emit(f"chaos.s3.p{int(rate * 100):02d}", dt,
                 f"goodput={goodput:.1f}MB/s faults={st.faults_retried} "
                 f"fallbacks={st.batch_fallbacks} "
                 f"status={task.status.lower()}")
    base = out[0.0]["goodput_mb_s"]
    worst = out[max(FAULT_RATES)]["goodput_mb_s"]
    emit("chaos.s3.degradation", 0.0,
         f"x{base / max(worst, 1e-9):.2f} goodput loss at "
         f"p={max(FAULT_RATES):.2f}")
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
