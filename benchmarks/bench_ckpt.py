"""Framework benchmarks: checkpoint save/restore through Connectors.

Shows the paper-motivated object-coalescing win: many tiny tensors as
individual objects vs bundled objects (per-file overhead t0 is the
killer, paper §5) — the checkpoint layer applies §8 best practice by
construction."""

from __future__ import annotations

import tempfile

import numpy as np

import jax.numpy as jnp

from repro.ckpt import restore_checkpoint, save_checkpoint

from .common import QUICK, emit, make_env, timed


def _state(n_small: int, small: int, n_big: int, big: int):
    st = {f"small_{i}": jnp.asarray(np.random.default_rng(i)
                                    .standard_normal(small, np.float32))
          for i in range(n_small)}
    st.update({f"big_{i}": jnp.asarray(np.random.default_rng(100 + i)
                                       .standard_normal(big, np.float32))
               for i in range(n_big)})
    return st


def run() -> dict:
    out = {}
    n_small = 64 if QUICK else 256
    state = _state(n_small, 1024, 2, (1 << 20))
    with tempfile.TemporaryDirectory() as tmp:
        env = make_env(tmp, virtual=True)
        storage, conn = env.cloud("s3", "cloud")

        t_bundled = timed(lambda: save_checkpoint(
            state, conn, "b", 0, credential=env.creds.lookup(conn.name),
            verify=False), env)
        out["bundled"] = t_bundled
        emit("ckpt.save.bundled", t_bundled, f"{n_small} tensors coalesced")

        t_naive = timed(lambda: save_checkpoint(
            state, conn, "n", 0, credential=env.creds.lookup(conn.name),
            bundle_threshold=0, verify=False), env)
        out["naive"] = t_naive
        emit("ckpt.save.per-tensor", t_naive,
             f"coalescing is x{t_naive / max(t_bundled, 1e-9):.2f} faster "
             f"(paper §5 t0 effect)")

        abstract = {k: jnp.zeros(v.shape, v.dtype) for k, v in state.items()}
        t_restore = timed(lambda: restore_checkpoint(
            abstract, conn, "b", step=0,
            credential=env.creds.lookup(conn.name)), env)
        out["restore"] = t_restore
        emit("ckpt.restore.bundled", t_restore, "integrity verified")

        # integrity-checked save (paper §7 post-write verify)
        t_verify = timed(lambda: save_checkpoint(
            state, conn, "v", 0, credential=env.creds.lookup(conn.name),
            verify=True), env)
        out["verified"] = t_verify
        emit("ckpt.save.verified", t_verify,
             f"x{t_verify / max(t_bundled, 1e-9):.2f} vs unverified")
    return out


if __name__ == "__main__":
    run()
