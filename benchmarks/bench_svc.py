"""Service-plane benchmarks: streaming status vs polling at fleet scale.

The paper's managed service is observed by clients that never sit in
the data path; at "millions of users" scale the observation transport
itself becomes the cost.  This bench measures the three claims the
:mod:`repro.svc` StatusBus makes:

* ``svc.fanout`` — publish cost with 10k+ live subscribers (events/sec
  and aggregate deliveries/sec through the bounded rings);
* ``svc.stream.staleness`` / ``svc.poll.staleness`` — p99 staleness in
  *model* seconds for push delivery vs equivalent-freshness polling
  over the same seeded change sequence, plus the digest-recompute wall
  cost the polling fleet would pay;
* ``svc.digest.etag`` — the etag fast path on a *real* busy manager:
  an unchanged queue answers ``digest()`` from cache (hit rate ~= 1.0),
  and the recompute-forced baseline shows what each poll used to cost.

Quick mode (REPRO_BENCH_QUICK=1) shrinks the subscriber fleet and the
event counts; the comparisons and assertions are the same.
"""

from __future__ import annotations

import tempfile
import threading
import time

from repro.connectors import MemoryConnector
from repro.core import (CredentialStore, Endpoint, TransferManager,
                        TransferOptions)
from repro.core.clock import Clock
from repro.svc import StatusBus

from .common import QUICK, emit

SUBSCRIBERS = 2_000 if QUICK else 10_000
FANOUT_EVENTS = 100 if QUICK else 300
#: seeded model-time status-change sequence for the staleness comparison
CHANGES = 40 if QUICK else 80
CHANGE_GAP = 0.5        # model seconds between status changes
POLL_INTERVAL = 2.0     # the polling fleet's equivalent-freshness cadence
#: digest() calls for the etag fast-path / recompute-baseline measurement
DIGEST_READS = 2_000 if QUICK else 20_000
#: busy-manager shape for the digest bench
DIGEST_TASKS = 16


def _p99(xs: list[float]) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(0.99 * len(xs)))]


def bench_fanout() -> dict:
    """Publish FANOUT_EVENTS with SUBSCRIBERS live rings attached."""
    clock = Clock(scale=0.0)
    bus = StatusBus(site_id="bench", clock=clock)
    subs = [bus.subscribe(capacity=4) for _ in range(SUBSCRIBERS)]
    t0 = time.monotonic()
    for i in range(FANOUT_EVENTS):
        bus.publish("progress", task_id=f"t{i % 64}",
                    data={"bytes_done": i})
    wall = time.monotonic() - t0
    deliveries = SUBSCRIBERS * FANOUT_EVENTS
    events_s = FANOUT_EVENTS / max(wall, 1e-9)
    emit("svc.fanout", wall / FANOUT_EVENTS,
         f"subs={SUBSCRIBERS} events_s={events_s:.0f} "
         f"deliveries_s={deliveries / max(wall, 1e-9):.0f}")
    # rings are bounded: every subscriber holds at most its capacity and
    # the dropped counters account for exactly the rest
    s0 = subs[0]
    assert len(s0) + s0.dropped == FANOUT_EVENTS, (len(s0), s0.dropped)
    for s in subs:
        s.close()
    assert bus.subscribers == 0
    return {"events_s": events_s, "wall": wall,
            "deliveries_s": deliveries / max(wall, 1e-9)}


def bench_staleness() -> dict:
    """p99 status staleness, streaming vs equivalent-freshness polling.

    CHANGES status changes land CHANGE_GAP model seconds apart.  A
    streaming subscriber is woken at publish: its staleness is the gap
    between the event's model stamp and the model clock when it drains
    (0 here — the drain happens in the same model instant).  A polling
    client sees a change only at its next POLL_INTERVAL tick, so its
    staleness for a change at ``t`` is ``next_tick(t) - t`` — computed
    exactly from the same seeded change times.  The polling fleet's
    cost is SUBSCRIBERS digests per tick; bench_digest measures what
    each one costs when forced to recompute."""
    clock = Clock(scale=0.0)
    bus = StatusBus(site_id="stale", clock=clock)
    subs = [bus.subscribe(capacity=8) for _ in range(SUBSCRIBERS)]
    stream_stale: list[float] = []
    t0 = time.monotonic()
    for i in range(CHANGES):
        clock.sleep(CHANGE_GAP)
        bus.publish("progress", task_id="fleet", data={"change": i})
        now = clock.virtual_elapsed
        # sample the delivered staleness across the fleet (drain a
        # slice each tick; draining all 10k x 80 would be pure overhead)
        for s in subs[:200]:
            for ev in s.poll():
                stream_stale.append(now - ev.t)
    stream_wall = time.monotonic() - t0
    p99_stream = _p99(stream_stale)

    change_times = [(i + 1) * CHANGE_GAP for i in range(CHANGES)]
    poll_stale = []
    for t in change_times:
        ticks_past = int(t / POLL_INTERVAL)
        next_tick = (ticks_past + 1) * POLL_INTERVAL
        if abs(t - ticks_past * POLL_INTERVAL) < 1e-12:
            next_tick = t  # change landed exactly on a tick
        poll_stale.append(next_tick - t)
    p99_poll = _p99(poll_stale)

    window = CHANGES * CHANGE_GAP
    polls = int(SUBSCRIBERS * window / POLL_INTERVAL)
    emit("svc.stream.staleness", p99_stream,
         f"p99_model_s={p99_stream:.3f} wall_s={stream_wall:.2f} "
         f"samples={len(stream_stale)}")
    emit("svc.poll.staleness", p99_poll,
         f"p99_model_s={p99_poll:.3f} digests_needed={polls}")
    assert p99_stream < p99_poll, (p99_stream, p99_poll)
    for s in subs:
        s.close()
    return {"p99_stream": p99_stream, "p99_poll": p99_poll,
            "polls_needed": polls}


def bench_digest() -> dict:
    """The etag fast path on a real manager held mid-fleet: running
    tasks gated on an Event, a deep queue behind them — the digest is
    non-trivial to rebuild, and the queue is not mutating."""
    gate = threading.Event()

    class GatedMemory(MemoryConnector):
        def recv(self, session, path, channel):
            gate.wait(120)
            return super().recv(session, path, channel)

        def recv_batch(self, session, paths, channel_factory):
            gate.wait(120)
            return super().recv_batch(session, paths, channel_factory)

    src = MemoryConnector()
    for i in range(DIGEST_TASKS):
        src.store.put(f"t{i}/a.bin", b"x" * 4096)
    dst = GatedMemory()
    with tempfile.TemporaryDirectory() as tmp:
        mgr = TransferManager(
            credential_store=CredentialStore(), max_workers=2,
            per_endpoint_cap=None, share_sessions=False,
            marker_root=f"{tmp}/markers", clock=Clock(scale=0.0))
        opts = TransferOptions(startup_cost=0.0, concurrency=1,
                               coalesce_threshold=0)
        for i in range(DIGEST_TASKS):
            mgr.submit(Endpoint(src, f"t{i}", f"src{i}"),
                       Endpoint(dst, f"out/t{i}", f"dst{i}"),
                       opts, task_id=f"dig-{i}")
        h0, m0 = mgr.metrics.digest_hits, mgr.metrics.digest_misses
        t0 = time.monotonic()
        for _ in range(DIGEST_READS):
            mgr.digest()
        hit_wall = time.monotonic() - t0
        hits = mgr.metrics.digest_hits - h0
        misses = mgr.metrics.digest_misses - m0
        hit_rate = hits / max(1, hits + misses)

        t0 = time.monotonic()
        for _ in range(DIGEST_READS):
            mgr.digest(fresh=True)
        miss_wall = time.monotonic() - t0

        gate.set()
        ok = mgr.wait_all(timeout=120)
        assert ok, "gated digest fleet did not drain"
        mgr.shutdown(wait=False)

    per_hit = hit_wall / DIGEST_READS
    per_miss = miss_wall / DIGEST_READS
    speedup = per_miss / max(per_hit, 1e-12)
    emit("svc.digest.etag", per_hit,
         f"hit_rate={hit_rate:.4f} recompute_x={speedup:.1f} "
         f"per_recompute_us={per_miss * 1e6:.1f}")
    assert hit_rate > 0.99, f"etag hit rate {hit_rate:.4f}"
    assert per_hit < per_miss, (per_hit, per_miss)
    return {"hit_rate": hit_rate, "per_hit": per_hit,
            "per_miss": per_miss}


def run() -> dict:
    fanout = bench_fanout()
    stale = bench_staleness()
    dig = bench_digest()
    # the comparison the tentpole is judged by: the streaming plane
    # beats an equivalent-freshness polling fleet on BOTH axes
    poll_cost_wall = stale["polls_needed"] * dig["per_miss"]
    stream_events_s = fanout["events_s"]
    poll_events_s = stale["polls_needed"] / max(poll_cost_wall, 1e-9) \
        if poll_cost_wall else 0.0
    emit("svc.stream_vs_poll", 0.0,
         f"stream_p99={stale['p99_stream']:.3f} "
         f"poll_p99={stale['p99_poll']:.3f} "
         f"poll_fleet_wall_s={poll_cost_wall:.2f} "
         f"etag_hit_rate={dig['hit_rate']:.4f}")
    assert stale["p99_stream"] < stale["p99_poll"]
    # events/sec: per-subscriber status observations the plane can
    # serve — bounded-ring fan-out vs one digest recompute per poll
    assert fanout["deliveries_s"] > poll_events_s, \
        (fanout["deliveries_s"], poll_events_s)
    return {"fanout": fanout, "staleness": stale, "digest": dig,
            "stream_events_s": stream_events_s,
            "poll_events_s": poll_events_s}


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
