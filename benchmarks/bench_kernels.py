"""Kernel micro-benchmarks (interpret mode on CPU — numbers demonstrate
the harness; real performance is the TPU roofline in EXPERIMENTS.md)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.checksum.ops import checksum_digest

from .common import QUICK, emit


def _time(fn, *args, n=3):
    fn(*args)  # warmup/compile
    t0 = time.monotonic()
    for _ in range(n):
        r = fn(*args)
    jax.block_until_ready(r) if hasattr(r, "block_until_ready") else None
    return (time.monotonic() - t0) / n


def run() -> dict:
    out = {}
    B, S, H, KV, dh = 1, 128, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, dh), jnp.float32)
    t = _time(lambda: flash_attention(q, k, v, causal=True, window=None))
    out["flash_attention"] = t
    emit("kernels.flash_attention.interp", t, f"B{B} S{S} H{H} dh{dh}")
    t = _time(lambda: attention_ref(q, k, v, causal=True, window=None))
    emit("kernels.flash_attention.ref", t, "jnp oracle")

    T, Hh, K = 64, 2, 16
    qs = jax.random.normal(ks[0], (1, T, Hh, K)) * 0.5
    ksс = jax.random.normal(ks[1], (1, T, Hh, K)) * 0.5
    vs = jax.random.normal(ks[2], (1, T, Hh, K)) * 0.5
    g = -jnp.exp(jax.random.normal(ks[1], (1, T, Hh, K)) - 1.5)
    t = _time(lambda: ssm_scan(qs, ksс, vs, g, chunk=32, subchunk=8))
    out["ssm_scan"] = t
    emit("kernels.ssm_scan.interp", t, f"T{T} H{Hh} K{K}")

    x = jax.random.normal(ks[2], (1 << 16,), jnp.float32)
    t = _time(lambda: checksum_digest(x, use_pallas=True))
    out["checksum"] = t
    emit("kernels.checksum.interp", t, "64K floats")
    t = _time(lambda: checksum_digest(x, use_pallas=False))
    emit("kernels.checksum.jnp", t, "64K floats")
    return out


if __name__ == "__main__":
    run()
