"""Paper §6 / Figs. 13-16: throughput vs concurrency.  cc files of
fixed size in flight; native clients use cc threads.  Real wall-clock
with genuine thread overlap (scaled emulation)."""

from __future__ import annotations

import tempfile

from repro.core import TransferOptions

from .common import (MB, QUICK, emit, make_env, native_upload_seconds,
                     seed_bucket, seed_local_files, split_dataset,
                     transfer_model_seconds, Endpoint)

CCS = [1, 4, 8] if QUICK else [1, 2, 4, 8, 16]
FILE_MB = 8 if QUICK else 16   # paper: 1 GB per file

PROVIDERS = [("wasabi", False), ("s3", True), ("gcs", True), ("ceph", True)]


def run(providers=None) -> dict:
    results = {}
    matrix = PROVIDERS if providers is None else \
        [p for p in PROVIDERS if p[0] in providers]
    for provider, has_cloud in matrix:
        with tempfile.TemporaryDirectory() as tmp:
            env = make_env(tmp)   # wall-clock mode: real overlap
            storage, conn_local = env.cloud(provider, "local")
            routes = {"conn-local": conn_local}
            if has_cloud:
                conn_cloud = type(conn_local)(storage, placement="cloud",
                                              clock=env.clock)
                env.creds.register(conn_cloud.name,
                                   env.creds.lookup(conn_local.name))
                routes["conn-cloud"] = conn_cloud
            native = env.native(storage)

            for cc in CCS:
                parts = split_dataset(cc * FILE_MB * MB, cc)
                # upload via each route
                for rname, conn in routes.items():
                    src = seed_local_files(env, f"up{provider}{rname}{cc}",
                                           parts)
                    t = transfer_model_seconds(
                        env, Endpoint(env.local, src),
                        Endpoint(conn, f"bkt/{rname}{cc}", conn.name),
                        TransferOptions(concurrency=cc, parallelism=4,
                                        startup_cost=0.0))
                    thr = cc * FILE_MB / t  # MB/s model
                    results[(provider, rname, "up", cc)] = thr
                    emit(f"throughput.{provider}.{rname}.upload.cc{cc}",
                         t, f"{thr:.0f}MB/s")
                    storage.blobs._objs.clear()
                # native with cc threads
                t = native_upload_seconds(env, native, parts, f"nu{cc}",
                                          concurrency=cc)
                thr = cc * FILE_MB / t
                results[(provider, "native", "up", cc)] = thr
                emit(f"throughput.{provider}.native.upload.cc{cc}", t,
                     f"{thr:.0f}MB/s")
                storage.blobs._objs.clear()

            # concurrency scaling sanity: cc=max should beat cc=1 for
            # every route (the paper's headline concurrency effect)
            for rname in list(routes) + ["native"]:
                lo = results[(provider, rname, "up", CCS[0])]
                hi = results[(provider, rname, "up", CCS[-1])]
                emit(f"throughput.{provider}.{rname}.scaling", 0.0,
                     f"x{hi / max(lo, 1e-9):.2f} cc{CCS[0]}->cc{CCS[-1]}")
    return results


if __name__ == "__main__":
    run()
