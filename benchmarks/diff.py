"""Bench-regression gate: compare a fresh quick-mode run against the
committed ``BENCH_<suite>.json`` baselines and exit nonzero when a
guarded metric regressed past its tolerance.

The guard list is deliberately short and names only metrics that are
stable under the model clock (catalog dedupe ratios, fitted-model
quality) plus the headline goodput numbers — each with its own
tolerance, because a timing metric on a shared CI runner deserves more
slack than a deterministic byte count.

    PYTHONPATH=src python -m benchmarks.run --quick --out /tmp/fresh \
        --only perfile,federation
    PYTHONPATH=src python -m benchmarks.diff --current-dir /tmp/fresh

Exit codes: 0 all guards within tolerance, 1 regression (or a guarded
metric vanished), 2 usage/missing baseline file.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import dataclass


@dataclass(frozen=True)
class Guard:
    """One gated metric: ``path`` is a dot-joined key path into the
    suite's ``BENCH_<suite>.json``; ``better`` says which direction is
    good; ``tol`` is the allowed fractional move the *bad* way."""

    suite: str
    path: str
    better: str  # "higher" | "lower"
    tol: float
    note: str = ""


#: the guarded metrics.  Dedupe ratios and model-fit quality are
#: near-deterministic (tight tolerance); goodput is wall-clock derived
#: (looser, but still tight enough to catch a real ~20% regression).
GUARDS: tuple[Guard, ...] = (
    Guard("federation", "fanout.moved_ratio", "lower", 0.05,
          "fan-out must collapse to ~1 real transfer"),
    Guard("federation", "fanout.hit_rate", "higher", 0.10,
          "catalog replica hit rate across the fan-out"),
    Guard("federation", "fanout.bytes_not_moved_frac", "higher", 0.10,
          "source bytes the catalog spared"),
    Guard("federation", "goodput.2.goodput_mb_s", "higher", 0.15,
          "2-site fleet goodput"),
    Guard("perfile", "s3/conn-local/up.rho", "higher", 0.05,
          "Eq. 4 linearity on the reference route"),
    Guard("perfile", "s3/conn-local/up.t0_speedup", "higher", 0.30,
          "batched data plane per-file overhead win"),
    # the ratio sits near 1.0, so a fractional move the bad way IS the
    # tracing overhead itself; 0.10 leaves room for runner noise while
    # the bench's own inline assert holds the 5% acceptance bar
    Guard("obs", "goodput_ratio", "higher", 0.10,
          "tracing+metrics overhead vs disabled tracer"),
)


def _get(tree: dict, path: str):
    node = tree
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def compare(baselines: dict, currents: dict,
            guards: tuple[Guard, ...] = GUARDS) -> list[dict]:
    """Evaluate every guard; returns one row per guard with a
    ``status`` of ``ok`` / ``regressed`` / ``missing`` (metric or suite
    vanished from the fresh run) / ``new`` (no baseline yet — skipped,
    never failed).  ``baselines``/``currents`` map suite name -> loaded
    BENCH json."""
    rows = []
    for g in guards:
        base = _get(baselines.get(g.suite) or {}, g.path)
        cur = _get(currents.get(g.suite) or {}, g.path)
        row = {"suite": g.suite, "metric": g.path, "better": g.better,
               "tol": g.tol, "base": base, "cur": cur, "note": g.note}
        if base is None:
            row["status"] = "new"
        elif cur is None or not isinstance(cur, (int, float)) \
                or isinstance(cur, bool):
            row["status"] = "missing"
        else:
            delta = (cur - base) / abs(base) if base else (
                0.0 if cur == base else float("inf"))
            row["delta"] = delta
            bad = delta < -g.tol if g.better == "higher" else delta > g.tol
            row["status"] = "regressed" if bad else "ok"
        rows.append(row)
    return rows


def format_table(rows: list[dict]) -> str:
    """Readable delta table, one line per guard."""
    out = [f"{'status':10s} {'suite':12s} {'metric':34s} "
           f"{'base':>12s} {'current':>12s} {'delta':>8s}  tol"]
    for r in rows:
        base = f"{r['base']:.4g}" if isinstance(
            r["base"], (int, float)) else "-"
        cur = f"{r['cur']:.4g}" if isinstance(
            r["cur"], (int, float)) else "-"
        delta = f"{r['delta']:+.1%}" if "delta" in r else "-"
        out.append(f"{r['status']:10s} {r['suite']:12s} "
                   f"{r['metric']:34s} {base:>12s} {cur:>12s} "
                   f"{delta:>8s}  ±{r['tol']:.0%} ({r['better']} better)")
    return "\n".join(out)


def load_suites(directory: str, suites) -> dict:
    out = {}
    for name in suites:
        path = os.path.join(directory, f"BENCH_{name}.json")
        if os.path.exists(path):
            with open(path) as f:
                out[name] = json.load(f)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(
        description="compare fresh bench results against committed "
                    "baselines; nonzero exit on regression")
    ap.add_argument("--baseline-dir", default=".",
                    help="directory with committed BENCH_<suite>.json")
    ap.add_argument("--current-dir", required=True,
                    help="directory with the fresh run's baselines")
    args = ap.parse_args()

    suites = sorted({g.suite for g in GUARDS})
    baselines = load_suites(args.baseline_dir, suites)
    currents = load_suites(args.current_dir, suites)
    if not baselines:
        print(f"no BENCH_*.json baselines under {args.baseline_dir} "
              f"for suites {','.join(suites)}", file=sys.stderr)
        return 2

    rows = compare(baselines, currents)
    print(format_table(rows))
    bad = [r for r in rows if r["status"] in ("regressed", "missing")]
    if bad:
        print(f"\nbench-diff FAILED: {len(bad)} guarded metric(s) "
              "regressed or vanished", file=sys.stderr)
        return 1
    print(f"\nbench-diff ok: {len(rows)} guards within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
