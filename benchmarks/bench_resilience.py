"""Resilience benchmark: the health plane under endpoint sickness.

Two questions, both straight from the ISSUE 6 acceptance list:

1. **Goodput vs fault rate, breakers on/off** — the same probabilistic
   fault sweep as bench_chaos, run twice per rate: once with the bare
   retry loop (``health=None``) and once with a shared
   :class:`EndpointHealth` gating every attempt.  The interesting
   columns are the number of *storage-touching* attempts (the retry
   pressure on the sick endpoint) and the goodput of whatever bytes
   still land: breakers should slash the former without collapsing the
   latter at moderate rates.

2. **Time-to-automatic-failover** — the flapping-site degraded scenario
   measured on the model clock: from the moment the coordinator starts
   counting sustained heartbeat misses to the beat that re-homes the
   dark site's work.

Emits ``resilience.*`` rows; seed-deterministic modulo thread timing.
"""

from __future__ import annotations

import tempfile

from repro.connectors import FaultProxyConnector
from repro.core import (Endpoint, EndpointHealth, FaultSchedule,
                        HealthConfig, TransferOptions)
from repro.core.clock import Clock
from repro.sim import ScenarioRunner

from .common import MB, QUICK, emit, make_env, seed_local_files, split_dataset

FAULT_RATES = (0.0, 0.1, 0.3) if QUICK else (0.0, 0.05, 0.1, 0.2, 0.4)
N_FILES = 12 if QUICK else 32
FILE_KB = 128


def _schedule(rate: float) -> FaultSchedule:
    sched = FaultSchedule(seed=4321)
    if rate > 0:
        sched.transient(op="recv*", prob=rate, times=None)
        sched.transient(op="read", prob=rate / 2, times=None)
    return sched


def _sweep_point(rate: float, with_health: bool) -> dict:
    with tempfile.TemporaryDirectory() as tmp:
        env = make_env(tmp, virtual=True)
        _, conn = env.cloud("s3", "local")
        sched = _schedule(rate)
        sched.clock = env.clock
        proxy = FaultProxyConnector(conn, sched, clock=env.clock)
        env.creds.register("sick-dst", env.creds.lookup(conn.name))
        if with_health:
            env.service.health = EndpointHealth(
                HealthConfig(error_threshold=0.5, ewma_alpha=0.4,
                             min_samples=3, cooldown=0.2,
                             retry_budget_rate=2.0,
                             retry_budget_capacity=16.0),
                clock=env.clock)
        parts = split_dataset(N_FILES * FILE_KB * 1024, N_FILES)
        src = seed_local_files(env, f"res{int(rate * 100):02d}", parts)
        v0 = env.clock.virtual_elapsed
        task = env.service.submit(
            Endpoint(env.local, src),
            Endpoint(proxy, f"bkt/res{int(rate * 100):02d}", "sick-dst"),
            TransferOptions(concurrency=4, startup_cost=0.0,
                            retry_backoff=0.05, max_retries=4,
                            unavailable_patience=5.0,
                            coalesce_threshold=0), sync=True)
        dt = env.clock.virtual_elapsed - v0
        st = task.stats
        hp = env.service.health
        return {"model_s": dt,
                "goodput_mb_s": st.bytes_done / max(dt, 1e-9) / MB,
                "attempts": sched.count("transient"),
                "denials": (st.retries_by_kind.get("EndpointUnavailable", 0)
                            if hp is not None else 0),
                "status": task.status}


def run() -> dict:
    out: dict = {"sweep": {}}
    for rate in FAULT_RATES:
        pair = {}
        for label, with_health in (("off", False), ("on", True)):
            row = _sweep_point(rate, with_health)
            pair[label] = row
            emit(f"resilience.p{int(rate * 100):02d}.breakers_{label}",
                 row["model_s"],
                 f"goodput={row['goodput_mb_s']:.1f}MB/s "
                 f"attempts={row['attempts']} denials={row['denials']} "
                 f"status={row['status'].lower()}")
        out["sweep"][rate] = pair
        if rate > 0 and pair["off"]["attempts"]:
            ratio = pair["off"]["attempts"] / max(pair["on"]["attempts"], 1)
            emit(f"resilience.p{int(rate * 100):02d}.attempt_ratio", 0.0,
                 f"x{ratio:.2f} fewer storage attempts with breakers on")

    # time-to-automatic-failover (heartbeat monitor, model clock)
    with tempfile.TemporaryDirectory() as tmp:
        res = ScenarioRunner(tmp, clock=Clock(scale=0.0)).run_degraded(
            "flapping-site", seed=0, strict=True)
        out["failover_model_s"] = res.failover_model_seconds
        emit("resilience.failover", res.failover_model_seconds,
             f"auto_failovers={res.coordinator.metrics.auto_failovers} "
             f"moved={len(res.moved)} ok={res.ok}")

    # breaker recovery latency through a bounded brownout storm
    with tempfile.TemporaryDirectory() as tmp:
        res = ScenarioRunner(tmp, clock=Clock(scale=0.0)).run_degraded(
            "brownout", seed=0, strict=True)
        times = [t for t, ep, _, _ in res.health.transitions
                 if ep == "dst-ep"]
        recovery = (times[-1] - times[0]) if len(times) > 1 else 0.0
        out["brownout_recovery_model_s"] = recovery
        emit("resilience.brownout_recovery", recovery,
             f"transitions={len(res.transitions)} "
             f"probes={res.retries_by_kind.get('HalfOpenProbe', 0)} "
             f"ok={res.ok}")

    # retry-storm suppression: 20-task fleet vs a dead endpoint
    with tempfile.TemporaryDirectory() as tmp:
        res = ScenarioRunner(tmp, clock=Clock(scale=0.0)).run_degraded(
            "death", seed=0, strict=True)
        naive = 20 * 7  # n_tasks * (max_retries + 1)
        out["death_attempts"] = res.attempts
        emit("resilience.death_suppression", 0.0,
             f"attempts={res.attempts} naive={naive} "
             f"x{naive / max(res.attempts, 1):.1f} suppression ok={res.ok}")
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
