"""Property suite: random fault schedules x random trees x random
coalesce thresholds -> the transfer always ends byte-exact or cleanly
failed, never wedged, with the marker journal empty after success.

Uses hypothesis when the container has it (examples capped by the
``tier1`` profile in conftest.py); otherwise falls back to the same
property over a fixed seed sweep, so the suite is deterministic either
way."""

import random
import tempfile

import pytest

from repro.core import FaultSchedule, TransferOptions
from repro.core.clock import Clock
from repro.sim import ScenarioRunner
from repro.sim.scenarios import SRC_ROOT

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KB = 1024

pytestmark = pytest.mark.chaos

PROPERTY_ROUTES = ("posix->memory", "posix->cloud", "cloud->memory")
SIZES = [0, 1, 137, 2 * KB, 40 * KB, 700 * KB]
THRESHOLDS = [0, 4 * KB, 64 * KB, 1024 * KB]


def _random_tree(rng: random.Random) -> dict[str, bytes]:
    files = {}
    for i in range(rng.randint(1, 14)):
        depth = rng.randint(0, 3)
        d = "".join(f"l{rng.randint(0, 2)}/" for _ in range(depth))
        name = rng.choice([f"f{i:02d}.bin", f"ü{i:02d}.bin", f"ф{i:02d}.bin"])
        files[f"{SRC_ROOT}/{d}{name}"] = rng.randbytes(rng.choice(SIZES))
    return files


def _random_schedule(rng: random.Random, integrity: bool) -> FaultSchedule:
    sched = FaultSchedule(seed=rng.randint(0, 2 ** 31))
    kinds = ["transient", "rate_limit", "session_drop", "truncate", "latency"]
    if integrity:
        kinds.append("bit_flip")  # undetectable without integrity checking
    for _ in range(rng.randint(0, 3)):
        kind = rng.choice(kinds)
        at = rng.randint(1, 2)
        times = rng.choice([1, 2])
        if kind == "transient":
            sched.transient(op=rng.choice(["recv*", "read", "send*", "stat"]),
                            at=at, times=times)
        elif kind == "rate_limit":
            sched.rate_limit(op=rng.choice(["recv*", "read"]), at=at,
                             times=times, retry_after=rng.random() * 0.3)
        elif kind == "session_drop":
            sched.session_drop(op=rng.choice(["recv_batch", "send_batch"]),
                               at=at, times=1)
        elif kind == "truncate":
            sched.truncate(after_bytes=rng.choice([100, 5 * KB, 100 * KB]),
                           at=at, times=1)
        elif kind == "latency":
            sched.latency(op="*", delay=rng.random() * 0.5,
                          prob=0.1, times=None)
        else:
            sched.bit_flip(at=at, times=1)
    return sched


def _run_property(seed: int) -> None:
    rng = random.Random(f"chaos-prop|{seed}")
    integrity = rng.random() < 0.4
    sched = _random_schedule(rng, integrity)
    options = TransferOptions(
        startup_cost=0.0, retry_backoff=0.01,
        coalesce_threshold=rng.choice(THRESHOLDS),
        max_batch_files=rng.choice([2, 8, 32]),
        concurrency=rng.choice([1, 2, 4]),
        integrity=integrity,
    )
    route = rng.choice(PROPERTY_ROUTES)
    files = _random_tree(rng)
    with tempfile.TemporaryDirectory() as tmp:
        runner = ScenarioRunner(tmp, clock=Clock(scale=0.0))
        res = runner.run(tree=files, route=route, schedule=sched,
                         proxy=rng.choice(["dst", "both"]),
                         options=options, timeout=120.0)
    assert not res.violations, (
        f"seed={seed} route={route} threshold={options.coalesce_threshold} "
        f"integrity={integrity} rules={[r.kind for r in sched.rules]} "
        f"violations={res.violations} events={res.task.events[-5:]}")
    # never wedged, and terminal status is one of the two clean ends
    assert res.task.status in (res.task.SUCCEEDED, res.task.FAILED)


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=0, max_value=2 ** 32 - 1))
    def test_chaos_random_schedules(seed):
        _run_property(seed)
else:
    @pytest.mark.parametrize("seed", list(range(12)))
    def test_chaos_random_schedules(seed):
        _run_property(seed)
