"""Health-plane unit tests: EndpointHealth breaker state machine,
shared retry budgets, probe-slot discipline, jittered backoff, and the
data/control-plane wiring (ISSUE 6 tentpole).

All breaker tests drive a private model Clock(scale=0) from the test
thread, so every transition sequence is exactly deterministic.  The
budget-bound property uses hypothesis when available (tier1 profile in
conftest.py) and a fixed seed sweep otherwise."""

import random

import pytest

from repro.connectors import FaultProxyConnector, MemoryConnector
from repro.core import (Credential, CredentialStore, Endpoint,
                        EndpointHealth, EndpointUnavailable, FaultSchedule,
                        HealthConfig, TransferManager, TransferOptions,
                        TransferService, TransientError)
from repro.core.clock import Clock
from repro.core.health import CLOSED, HALF_OPEN, OPEN
from repro.core.transfer import _retry_jitter

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KB = 1024


def mk_health(**kw) -> tuple[EndpointHealth, Clock]:
    clock = Clock(scale=0.0)
    return EndpointHealth(HealthConfig(**kw), clock=clock), clock


# ---------------------------------------------------------------------------
# breaker state machine
# ---------------------------------------------------------------------------
def test_breaker_opens_only_after_min_samples():
    hp, _ = mk_health(error_threshold=0.5, ewma_alpha=0.5, min_samples=3)
    hp.record_failure("ep")
    hp.record_failure("ep")           # ewma 0.75 >= 0.5, but samples 2 < 3
    assert hp.state("ep") == CLOSED
    hp.record_failure("ep")
    assert hp.state("ep") == OPEN
    assert hp.transition_names("ep") == ["closed->open"]


def test_open_denies_with_cooldown_hint():
    hp, _ = mk_health(min_samples=1, ewma_alpha=1.0, cooldown=2.0)
    hp.record_failure("ep")
    assert hp.state("ep") == OPEN
    with pytest.raises(EndpointUnavailable) as ei:
        hp.admit("ep")
    assert ei.value.reason == "breaker-open"
    assert ei.value.endpoint_id == "ep"
    assert 0.0 < ei.value.retry_after <= 2.0
    assert hp.denials["ep"] == 1
    # non-mutating queries agree and do not transition anything
    assert not hp.available("ep")
    assert hp.denied("ep") is not None
    assert hp.unavailable() == ["ep"]
    assert hp.transition_names("ep") == ["closed->open"]


def test_half_open_admits_exactly_one_probe_then_closes():
    hp, clock = mk_health(min_samples=1, ewma_alpha=1.0, cooldown=1.0,
                          probe_successes=1)
    hp.record_failure("ep")
    clock.sleep(1.0)                  # cooldown elapsed on the model clock
    assert hp.available("ep")         # the next attempt would be the probe
    t = hp.admit("ep")
    assert t.probe
    assert hp.state("ep") == HALF_OPEN
    with pytest.raises(EndpointUnavailable) as ei:
        hp.admit("ep")                # second attempt: probe slot is taken
    assert ei.value.reason == "probe-in-flight"
    hp.settle(t)                      # probe succeeded
    assert hp.state("ep") == CLOSED
    assert hp.transition_names("ep") == [
        "closed->open", "open->half-open", "half-open->closed"]
    # recovery resets the evidence window: one new failure is not enough
    # to re-open even though ewma_alpha=1.0 (min_samples must re-accrue)
    hp2, clock2 = mk_health(min_samples=2, ewma_alpha=1.0, cooldown=1.0)
    hp2.record_failure("ep")
    hp2.record_failure("ep")
    clock2.sleep(1.0)
    hp2.settle(hp2.admit("ep"))       # probe ok -> closed, fresh window
    hp2.record_failure("ep")          # samples 1 < min_samples 2
    assert hp2.state("ep") == CLOSED


def test_failed_probe_reopens_with_fresh_cooldown():
    hp, clock = mk_health(min_samples=1, ewma_alpha=1.0, cooldown=1.0)
    hp.record_failure("ep")
    clock.sleep(1.0)
    t = hp.admit("ep")
    err = TransientError("probe failed")
    err.endpoint_id = "ep"
    hp.settle(t, err)
    assert hp.state("ep") == OPEN
    with pytest.raises(EndpointUnavailable):      # cooldown restarted
        hp.admit("ep")
    assert hp.transition_names("ep") == [
        "closed->open", "open->half-open", "half-open->open"]


def test_release_frees_probe_slot_without_judging():
    hp, clock = mk_health(min_samples=1, ewma_alpha=1.0, cooldown=1.0)
    hp.record_failure("ep")
    clock.sleep(1.0)
    t = hp.admit("ep")
    before = hp.transition_names("ep")
    hp.release(t)                     # e.g. the attempt was interrupted
    # no outcome was recorded, but the slot is free for the next probe
    assert hp.transition_names("ep") == before
    t2 = hp.admit("ep")
    assert t2.probe


def test_settle_is_idempotent_and_none_safe():
    hp, _ = mk_health(min_samples=10)
    hp.settle(None)                   # admit raised before a ticket existed
    t = hp.admit("ep")
    hp.settle(t)
    hp.settle(t, TransientError("late"))   # second settle must not count
    hp.release(t)
    snap = hp.snapshot()["ep"]
    assert snap["samples"] == 1 and snap["error_rate"] == 0.0


# ---------------------------------------------------------------------------
# retry budget
# ---------------------------------------------------------------------------
def test_budget_exhausts_and_refills_on_model_clock():
    hp, clock = mk_health(min_samples=99, retry_budget_rate=1.0,
                          retry_budget_capacity=2.0)
    hp.settle(hp.admit("ep", retrying=True))       # 1 token
    hp.settle(hp.admit("ep", retrying=True))       # 2nd token
    with pytest.raises(EndpointUnavailable) as ei:
        hp.admit("ep", retrying=True)
    assert ei.value.reason == "retry-budget"
    clock.sleep(1.0)                               # refill 1 token
    hp.settle(hp.admit("ep", retrying=True))


def test_budget_rate_zero_is_a_hard_lifetime_cap():
    hp, clock = mk_health(min_samples=99, retry_budget_rate=0.0,
                          retry_budget_capacity=1.0)
    hp.settle(hp.admit("ep", retrying=True))
    clock.sleep(1000.0)                            # no refill, ever
    with pytest.raises(EndpointUnavailable) as ei:
        hp.admit("ep", retrying=True)
    assert ei.value.reason == "retry-budget"


def test_first_attempt_is_budget_free_and_blame_restricts_charge():
    hp, _ = mk_health(min_samples=99, retry_budget_rate=0.0,
                      retry_budget_capacity=1.0)
    for _ in range(5):                             # first attempts are free
        hp.settle(hp.admit("a", "b", retrying=False))
    assert hp.snapshot()["a"]["tokens"] == 1.0
    # a blamed retry charges ONLY the blamed endpoint's bucket
    hp.settle(hp.admit("a", "b", retrying=True, blame=("b",)))
    snap = hp.snapshot()
    assert snap["a"]["tokens"] == 1.0 and snap["b"]["tokens"] == 0.0


def test_batch_failure_blames_the_named_endpoint_only():
    hp, _ = mk_health(min_samples=1, ewma_alpha=1.0)
    err = TransientError("recv blew up")
    err.endpoint_id = "dst"
    hp.record_failure("src", "dst", error=err)
    assert hp.state("dst") == OPEN
    assert hp.state("src") == CLOSED
    assert hp.error_rate("src") == 0.0


# ---------------------------------------------------------------------------
# deterministic jittered backoff (satellite: retry de-synchronization)
# ---------------------------------------------------------------------------
def test_retry_jitter_is_deterministic_and_spread():
    a = _retry_jitter("task-1", "dir/f.bin", 3)
    assert a == _retry_jitter("task-1", "dir/f.bin", 3)   # pure function
    vals = [_retry_jitter("task-1", f"f{i}.bin", 1) for i in range(32)]
    assert all(0.0 <= v < 1.0 for v in vals)
    assert len(set(vals)) > 16        # batch-mates actually de-synchronize
    assert _retry_jitter("task-1", "f.bin", 1) \
        != _retry_jitter("task-1", "f.bin", 2)


# ---------------------------------------------------------------------------
# data-plane integration: fast-fails vs real attempts
# ---------------------------------------------------------------------------
def test_dead_endpoint_fast_fails_without_burning_attempts(tmp_path):
    clock = Clock(scale=0.0)
    schedule = FaultSchedule(seed=7, clock=clock).dead_endpoint(op="recv*")
    src = MemoryConnector()
    for k in range(3):
        src.store.put(f"data/f{k}.bin", bytes(1 * KB))
    dst = FaultProxyConnector(MemoryConnector(), schedule)
    creds = CredentialStore()
    creds.register("src-ep", Credential("u", {}))
    creds.register("dst-ep", Credential("u", {}))
    hp = EndpointHealth(
        HealthConfig(min_samples=2, ewma_alpha=0.6, cooldown=0.05,
                     retry_budget_rate=0.0, retry_budget_capacity=2.0),
        clock=clock)
    svc = TransferService(credential_store=creds,
                          marker_root=str(tmp_path / "m"),
                          clock=clock, health=hp)
    opt = TransferOptions(startup_cost=0.0, retry_backoff=0.01,
                          concurrency=1, max_retries=3,
                          coalesce_threshold=0, unavailable_patience=0.5)
    task = svc.submit(Endpoint(src, "data", "src-ep"),
                      Endpoint(dst, "out", "dst-ep"), opt,
                      task_id="dead-ep")
    assert task.wait(timeout=120)
    assert task.status == task.FAILED
    kinds = task.stats.retries_by_kind
    # probes and fast-fail denials are counted as DISTINCT kinds, and
    # both are distinct from the real injected faults
    assert kinds.get("EndpointUnavailable", 0) > 0
    assert kinds.get("FaultInjected", 0) > 0
    assert hp.transition_names("dst-ep")[0] == "closed->open"
    # O(budget): storage was touched far fewer times than the naive
    # 3 files * (max_retries+1) = 12
    assert schedule.count("transient") <= 2 + 2 + 2
    # files behind the open breaker give up on patience, not retries —
    # and at least one was denied from its very first attempt (zero
    # admitted attempts: denials never burn max_retries)
    starved = [f for f in task.files
               if f.error and f.error.startswith("endpoint unavailable")]
    assert starved and any(f.attempts == 0 for f in starved)
    assert all(f.attempts <= opt.max_retries + 1 for f in task.files)


def test_manager_liveness_and_digest_with_open_breaker(tmp_path):
    clock = Clock(scale=0.0)
    schedule = FaultSchedule(seed=9, clock=clock).dead_endpoint(op="recv*")
    src = MemoryConnector()
    src.store.put("data/f0.bin", bytes(KB))
    dst = FaultProxyConnector(MemoryConnector(), schedule)
    creds = CredentialStore()
    creds.register("src-ep", Credential("u", {}))
    creds.register("dst-ep", Credential("u", {}))
    hp = EndpointHealth(
        HealthConfig(min_samples=1, ewma_alpha=1.0, cooldown=5.0,
                     retry_budget_rate=0.0, retry_budget_capacity=1.0),
        clock=clock)
    hp.record_failure("dst-ep")       # breaker already open at submit time
    mgr = TransferManager(max_workers=2, credential_store=creds,
                          marker_root=str(tmp_path / "m"), clock=clock,
                          health=hp)
    assert mgr.health is hp
    assert "dst-ep" in mgr.digest()["unavailable_endpoints"]
    opt = TransferOptions(startup_cost=0.0, retry_backoff=0.01,
                          concurrency=1, max_retries=1,
                          coalesce_threshold=0, unavailable_patience=0.2)
    task = mgr.submit(Endpoint(src, "data", "src-ep"),
                      Endpoint(dst, "out", "dst-ep"), opt,
                      task_id="sick-only")
    # nothing else is runnable: the liveness fallback must dispatch the
    # denied task anyway (fast-fail path) rather than wedge the queue
    assert mgr.wait_all(timeout=60)
    assert task.status == task.FAILED
    assert task.stats.retries_by_kind.get("EndpointUnavailable", 0) > 0
    mgr.shutdown(wait=False)


# ---------------------------------------------------------------------------
# property: admitted attempts against a dead endpoint are O(budget)
# ---------------------------------------------------------------------------
def _budget_bound_property(seed: int) -> None:
    rng = random.Random(seed)
    capacity = rng.randint(1, 6)
    cfg = dict(error_threshold=rng.uniform(0.3, 0.7),
               ewma_alpha=rng.uniform(0.3, 0.9),
               min_samples=rng.randint(1, 4),
               cooldown=rng.uniform(0.01, 0.2),
               retry_budget_rate=0.0,
               retry_budget_capacity=float(capacity))
    hp, clock = mk_health(**cfg)
    admitted = 0
    legal = {("closed", "open"), ("open", "half-open"),
             ("half-open", "open"), ("half-open", "closed")}
    for _ in range(200):
        try:
            t = hp.admit("ep", retrying=admitted > 0)
        except EndpointUnavailable as e:
            clock.sleep(max(e.retry_after, 1e-3))
            continue
        admitted += 1
        err = TransientError("always fails")
        err.endpoint_id = "ep"
        hp.settle(t, err)
    # one budget-free first attempt + at most `capacity` funded retries
    assert admitted <= capacity + 1
    # and every breaker transition is a legal state-machine edge
    names = hp.transition_names("ep")
    assert all(tuple(n.split("->")) in legal for n in names)
    prev = "closed"
    for n in names:
        old, new = n.split("->")
        assert old == prev
        prev = new


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=0, max_value=2 ** 32 - 1))
    def test_budget_bound_property(seed):
        _budget_bound_property(seed)
else:
    @pytest.mark.parametrize("seed", list(range(16)))
    def test_budget_bound_property(seed):
        _budget_bound_property(seed)
