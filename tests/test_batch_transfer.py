"""Batched small-file pipeline (paper §5.3.2/§8): the Connector bulk
data plane, the coalescing batch scheduler, restart-marker interaction,
the JSONL marker journal, and the O(1) hot-path structures."""

import json
import os
import random
import threading

import pytest

from repro.core import (Connector, Credential, CredentialStore, Endpoint,
                        FaultInjected, FaultSchedule, TransferOptions,
                        TransferService, checksum_bytes)
from repro.core.clock import Clock, Link
from repro.core.perfmodel import Advisor, PerfModel, Route
from repro.core.transfer import IntervalTracker, MarkerStore, _merge_ranges
from repro.connectors import (MemoryConnector, ObjectStoreConnector,
                              PosixConnector, make_cloud)

MB = 1024 * 1024
KB = 1024


class CountingLink(Link):
    """Zero-cost data link that counts payload bytes, so tests can
    assert exactly how much was (re-)sent."""

    def __init__(self, clock):
        super().__init__("count", rtt=0.0, per_stream_bw=float("inf"),
                         aggregate_bw=float("inf"), clock=clock)
        self.bytes = 0
        self._count_lock = threading.Lock()

    def transmit(self, nbytes, streams=1):
        with self._count_lock:
            self.bytes += nbytes
        super().transmit(nbytes, streams)


def make_service(tmp_path, link=None):
    clock = Clock(scale=0.0)
    creds = CredentialStore()
    kw = {}
    if link is not None:
        kw["data_link_factory"] = lambda s, d: link
    svc = TransferService(credential_store=creds,
                          marker_root=os.path.join(str(tmp_path), "markers"),
                          clock=clock, **kw)
    return svc, creds, clock


def seeded_posix(tmp_path, files, sub="src"):
    root = os.path.join(str(tmp_path), sub)
    conn = PosixConnector(root)
    for name, payload in files.items():
        p = os.path.join(root, name)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "wb") as f:
            f.write(payload)
    return conn


def small_tree(n=12, seed=0):
    rng = random.Random(seed)
    return {f"d/sub{i % 3}/f{i:03d}.bin": rng.randbytes(rng.randint(1, 64 * KB))
            for i in range(n)}


# ---------------------------------------------------------------------------
# many-small-files through each connector family
# ---------------------------------------------------------------------------
def _dst_memory(tmp_path, creds, clock):
    conn = MemoryConnector()
    read = lambda key: conn.store.get(key)
    return conn, "", read


def _dst_posix(tmp_path, creds, clock):
    conn = PosixConnector(os.path.join(str(tmp_path), "dstfs"))
    def read(key):
        with open(os.path.join(str(tmp_path), "dstfs", key), "rb") as f:
            return f.read()
    return conn, "", read


def _dst_cloud_local(tmp_path, creds, clock):
    storage = make_cloud("s3", clock=clock)
    conn = ObjectStoreConnector(storage, placement="local", clock=clock)
    creds.register(conn.name, Credential("s3-keypair", {}))
    return conn, conn.name, lambda key: storage.blobs.get(key)


def _dst_cloud_placed(tmp_path, creds, clock):
    storage = make_cloud("gcs", clock=clock)
    conn = ObjectStoreConnector(storage, placement="cloud", clock=clock)
    creds.register(conn.name, Credential("oauth2-token", {"token": "t"}))
    return conn, conn.name, lambda key: storage.blobs.get(key)


DSTS = {"memory": _dst_memory, "posix": _dst_posix,
        "cloud-local": _dst_cloud_local, "cloud-placed": _dst_cloud_placed}


@pytest.mark.parametrize("dst_kind", sorted(DSTS))
def test_many_small_files_batched(tmp_path, dst_kind):
    svc, creds, clock = make_service(tmp_path)
    files = small_tree(n=20, seed=3)
    src = seeded_posix(tmp_path, files)
    dst, ep_id, read = DSTS[dst_kind](tmp_path, creds, clock)
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "out", ep_id),
                      TransferOptions(concurrency=4, startup_cost=0.0),
                      sync=True)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    assert task.stats.files_done == len(files)
    assert task.stats.bytes_done == task.stats.bytes_total
    for name, payload in files.items():
        assert read("out/" + name[len("d/"):]) == payload


def test_memory_source_batched(tmp_path):
    svc, creds, clock = make_service(tmp_path)
    src = MemoryConnector()
    files = small_tree(n=10, seed=5)
    for name, payload in files.items():
        src.store.put(name, payload)
    dst = PosixConnector(os.path.join(str(tmp_path), "dl"))
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "mirror"),
                      TransferOptions(startup_cost=0.0), sync=True)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    for name, payload in files.items():
        with open(os.path.join(str(tmp_path), "dl", "mirror",
                               name[len("d/"):]), "rb") as f:
            assert f.read() == payload


# ---------------------------------------------------------------------------
# batch + restart markers
# ---------------------------------------------------------------------------
def test_batch_resume_skips_done_ranges(tmp_path):
    clock = Clock(scale=0.0)
    link = CountingLink(clock)
    svc, creds, _ = make_service(tmp_path, link=link)
    payloads = {f"d/f{i}.bin": os.urandom(64 * KB) for i in range(6)}
    src = seeded_posix(tmp_path, payloads)
    dst = MemoryConnector()

    task_id = "batch-resume"
    # f0 fully complete, f1 half done from a prior (killed) run
    state = {"files": {
        "d/f0.bin": {"done": [[0, 64 * KB]], "complete": True},
        "d/f1.bin": {"done": [[0, 32 * KB]], "complete": False},
    }}
    svc.markers.save(task_id, state)
    dst.store.put("out/f0.bin", payloads["d/f0.bin"])
    dst.store.put_range("out/f1.bin", 0, payloads["d/f1.bin"][:32 * KB])

    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "out"),
                      TransferOptions(startup_cost=0.0),
                      task_id=task_id, sync=True)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    # only the holes crossed the data channel: 4 whole files + half of f1
    assert link.bytes == 4 * 64 * KB + 32 * KB
    for name, payload in payloads.items():
        assert dst.store.get("out/" + name[len("d/"):]) == payload
    assert task.stats.bytes_done == task.stats.bytes_total
    assert svc.markers.load(task_id) == {"files": {}}  # cleared on success


def test_batch_resume_prefix_hole_cloud(tmp_path):
    """A resumed upload whose remaining hole is a *prefix* must not be
    single-shot PUT — that would truncate the tail already in storage."""
    svc, creds, clock = make_service(tmp_path)
    payload = os.urandom(48 * KB)
    files = {"d/a.bin": payload, "d/b.bin": os.urandom(8 * KB)}
    src = seeded_posix(tmp_path, files)
    storage = make_cloud("s3", clock=clock)
    dst = ObjectStoreConnector(storage, placement="local", clock=clock)
    creds.register(dst.name, Credential("s3-keypair", {}))
    task_id = "prefix-hole"
    state = {"files": {"d/a.bin": {"done": [[16 * KB, 32 * KB]],
                                   "complete": False}}}
    svc.markers.save(task_id, state)
    storage.blobs.put_range("out/a.bin", 16 * KB, payload[16 * KB:])
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "out", dst.name),
                      TransferOptions(startup_cost=0.0),
                      task_id=task_id, sync=True)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    assert storage.blobs.get("out/a.bin") == payload
    assert storage.blobs.get("out/b.bin") == files["d/b.bin"]


# ---------------------------------------------------------------------------
# property: batched and unbatched transfers are byte-identical
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_batched_equals_unbatched(tmp_path, seed):
    rng = random.Random(seed)
    files = {}
    for i in range(rng.randint(5, 24)):
        depth = rng.randint(0, 2)
        d = "/".join(f"lvl{rng.randint(0, 2)}" for _ in range(depth))
        name = (f"t/{d}/f{i:03d}.bin" if d else f"t/f{i:03d}.bin")
        files[name] = rng.randbytes(rng.choice(
            [0, 1, 37, 4 * KB, 100 * KB, 300 * KB]))
    outcomes = {}
    for mode, threshold in (("batched", 256 * KB), ("unbatched", 0)):
        svc, creds, clock = make_service(os.path.join(str(tmp_path), mode))
        src = seeded_posix(os.path.join(str(tmp_path), mode), files)
        dst = MemoryConnector()
        task = svc.submit(Endpoint(src, "t"), Endpoint(dst, "o"),
                          TransferOptions(coalesce_threshold=threshold,
                                          startup_cost=0.0), sync=True)
        assert task.status == task.SUCCEEDED, task.events[-5:]
        outcomes[mode] = {
            k: (bytes(v), checksum_bytes(bytes(v), "sha256"))
            for k, v in dst.store._objs.items()}
    assert outcomes["batched"] == outcomes["unbatched"]


def test_batched_equals_unbatched_integrity_cloud(tmp_path):
    rng = random.Random(7)
    files = {f"t/f{i:03d}.bin": rng.randbytes(rng.randint(1, 128 * KB))
             for i in range(9)}
    sums = {}
    for mode, threshold in (("batched", 256 * KB), ("unbatched", 0)):
        svc, creds, clock = make_service(os.path.join(str(tmp_path), mode))
        src = seeded_posix(os.path.join(str(tmp_path), mode), files)
        storage = make_cloud("s3", clock=clock)
        dst = ObjectStoreConnector(storage, placement="local", clock=clock)
        creds.register(dst.name, Credential("s3-keypair", {}))
        task = svc.submit(Endpoint(src, "t"), Endpoint(dst, "o", dst.name),
                          TransferOptions(coalesce_threshold=threshold,
                                          integrity=True, startup_cost=0.0),
                          sync=True)
        assert task.status == task.SUCCEEDED, task.events[-5:]
        assert task.stats.integrity_failures == 0
        sums[mode] = {f.src: f.checksum for f in task.files}
        for name, payload in files.items():
            assert storage.blobs.get("o/" + name[len("t/"):]) == payload
    assert sums["batched"] == sums["unbatched"]
    for name, payload in files.items():
        assert sums["batched"][name] == checksum_bytes(payload, "sha256")


# ---------------------------------------------------------------------------
# containment: a fault inside a batch only affects its file
# ---------------------------------------------------------------------------
def test_batch_fault_contained_and_retried(tmp_path):
    svc, creds, clock = make_service(tmp_path)
    files = {f"d/f{i}.bin": os.urandom(16 * KB) for i in range(8)}
    src = seeded_posix(tmp_path, files)
    storage = make_cloud(
        "s3", clock=clock,
        faults=FaultSchedule().transient(op="put", at=1, times=2,
                                         scope="global"))
    dst = ObjectStoreConnector(storage, placement="local", clock=clock)
    creds.register(dst.name, Credential("s3-keypair", {}))
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "out", dst.name),
                      TransferOptions(retry_backoff=0.001, startup_cost=0.0),
                      sync=True)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    assert task.stats.files_done == len(files)
    for name, payload in files.items():
        assert storage.blobs.get("out/" + name[len("d/"):]) == payload


class InflatingPosix(PosixConnector):
    """Reports every file 8 KB larger than it is — models a source file
    that shrank between directory expansion and the data phase."""

    PAD = 8 * KB

    def _inflate(self, info):
        import dataclasses
        if info.is_dir:
            return info
        return dataclasses.replace(info, size=info.size + self.PAD)

    def stat(self, session, path):
        return self._inflate(super().stat(session, path))

    def listdir(self, session, path):
        return [self._inflate(i) for i in super().listdir(session, path)]


def test_shrunk_source_file_does_not_hang(tmp_path):
    """A sender that stops early (planned size > real size) must signal
    completion through finished(None) instead of wedging the recv side
    on claims nobody will fill."""
    svc, creds, clock = make_service(tmp_path)
    files = {f"d/f{i}.bin": os.urandom(16 * KB) for i in range(4)}
    root = os.path.join(str(tmp_path), "src")
    seeded_posix(tmp_path, files)
    src = InflatingPosix(root)
    dst = MemoryConnector()
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "out"),
                      TransferOptions(startup_cost=0.0, max_retries=1,
                                      retry_backoff=0.001))
    assert task.wait(timeout=30), "transfer hung on shrunk source files"
    for name, payload in files.items():
        assert bytes(dst.store.get("out/" + name[len("d/"):])
                     [:len(payload)]) == payload


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------
def test_task_id_resubmit_no_collision(tmp_path):
    svc, creds, clock = make_service(tmp_path)
    payload = os.urandom(8 * KB)
    src = seeded_posix(tmp_path, {"a.bin": payload})
    dst = MemoryConnector()
    opts = TransferOptions(startup_cost=0.0)
    t1 = svc.submit(Endpoint(src, "a.bin"), Endpoint(dst, "a.bin"), opts,
                    sync=True)
    t2 = svc.submit(Endpoint(src, "a.bin"), Endpoint(dst, "a.bin"), opts,
                    sync=True)
    assert t1.task_id != t2.task_id  # same route must not collide
    assert svc.get(t1.task_id) is t1  # first task not overwritten
    assert svc.get(t2.task_id) is t2
    assert t1.status == t1.SUCCEEDED and t2.status == t2.SUCCEEDED


class CorruptingConnector(MemoryConnector):
    """Flips a byte on the first N received files (silent corruption,
    paper §7)."""

    def __init__(self, n_corrupt=1):
        super().__init__()
        self.n_corrupt = n_corrupt
        self._count = 0
        self._corrupt_lock = threading.Lock()

    def recv(self, session, path, channel):
        super().recv(session, path, channel)
        with self._corrupt_lock:
            if self._count < self.n_corrupt:
                self._count += 1
                key = self._key(path)
                data = bytearray(self.store.get(key))
                data[len(data) // 2] ^= 0xFF
                self.store.put(key, bytes(data))


@pytest.mark.parametrize("size", [64 * KB, 3 * MB])
def test_bytes_done_not_overcounted_on_integrity_resend(tmp_path, size):
    """Integrity re-send must un-credit the discarded bytes (the small
    size exercises the batch path, the large one the per-file path —
    both with a second small file so batching actually engages)."""
    svc, creds, clock = make_service(tmp_path)
    files = {"d/x.bin": os.urandom(size), "d/y.bin": os.urandom(32 * KB)}
    src = seeded_posix(tmp_path, files)
    dst = CorruptingConnector(n_corrupt=1)
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "out"),
                      TransferOptions(integrity=True, startup_cost=0.0),
                      sync=True)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    assert task.stats.integrity_failures == 1
    assert task.stats.bytes_done == task.stats.bytes_total  # no over-count
    for name, payload in files.items():
        assert dst.store.get("out/" + name[len("d/"):]) == payload


# ---------------------------------------------------------------------------
# recv_batch per-file fallback + batch-scheduler edge trees
# ---------------------------------------------------------------------------
class NoBatchMemory(MemoryConnector):
    """Memory connector stripped back to the *default* Connector batch
    implementations (per-file fallback loop with contained errors)."""

    send_batch = Connector.send_batch
    recv_batch = Connector.recv_batch


class FlakyRecvMemory(NoBatchMemory):
    """First recv for one path raises a transient fault — exercises the
    default recv_batch's error containment via channel.finished(e)."""

    def __init__(self, flaky_path):
        super().__init__()
        self.flaky_path = flaky_path
        self._failed = False

    def recv(self, session, path, channel):
        if path == self.flaky_path and not self._failed:
            self._failed = True
            raise FaultInjected(f"flaky recv {path}")
        super().recv(session, path, channel)


def test_default_recv_batch_fallback_contains_per_file_fault(tmp_path):
    """The base-class recv_batch (per-file fallback) must contain one
    bad file: batch-mates land, the bad file retries per-file."""
    svc, creds, clock = make_service(tmp_path)
    files = {f"d/f{i}.bin": os.urandom(4 * KB) for i in range(6)}
    src = seeded_posix(tmp_path, files)
    dst = FlakyRecvMemory("out/f3.bin")
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "out"),
                      TransferOptions(startup_cost=0.0, retry_backoff=0.001),
                      sync=True)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    assert dst._failed  # the fault actually fired inside the batch
    assert task.stats.batch_fallbacks >= 1
    assert task.stats.retries_by_kind.get("FaultInjected", 0) >= 1
    for name, payload in files.items():
        assert dst.store.get("out/" + name[len("d/"):]) == payload


def test_default_send_batch_fallback_roundtrip(tmp_path):
    """Source side of the default (per-file) bulk API."""
    svc, creds, clock = make_service(tmp_path)
    src = NoBatchMemory()
    files = {f"d/g{i}.bin": os.urandom(2 * KB) for i in range(5)}
    for name, payload in files.items():
        src.store.put(name, payload)
    dst = PosixConnector(os.path.join(str(tmp_path), "nb"))
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "out"),
                      TransferOptions(startup_cost=0.0), sync=True)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    for name, payload in files.items():
        with open(os.path.join(str(tmp_path), "nb", "out",
                               name[len("d/"):]), "rb") as f:
            assert f.read() == payload


EDGE_TREE = {
    "d/zero.bin": b"",
    "d/sub/zero2.bin": b"",
    "d/ünïcødé/файл.bin": b"unicode payload",
    "d/数据/ファイル 2.bin": b"x" * (3 * KB),
    "d/plain.bin": b"y" * 257,
}


@pytest.mark.parametrize("dst_kind", sorted(DSTS))
def test_zero_byte_and_unicode_through_batch_scheduler(tmp_path, dst_kind):
    """Zero-byte files, empty source dirs, and unicode names must ride
    the coalesced batch path and land byte-exact — including the empty
    objects, which every connector now materializes."""
    svc, creds, clock = make_service(tmp_path)
    src = seeded_posix(tmp_path, EDGE_TREE)
    os.makedirs(os.path.join(str(tmp_path), "src", "d", "hollow"),
                exist_ok=True)  # empty dir: expands to no files, no error
    dst, ep_id, read = DSTS[dst_kind](tmp_path, creds, clock)
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "out", ep_id),
                      TransferOptions(startup_cost=0.0, integrity=True),
                      sync=True)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    assert task.stats.files_done == len(EDGE_TREE)
    assert task.stats.bytes_done == task.stats.bytes_total
    for name, payload in EDGE_TREE.items():
        assert read("out/" + name[len("d/"):]) == payload


def test_zero_byte_files_materialized_unbatched(tmp_path):
    """Same edge tree with batching disabled: per-file path must also
    create empty destination objects."""
    svc, creds, clock = make_service(tmp_path)
    src = seeded_posix(tmp_path, EDGE_TREE, sub="src2")
    dst = MemoryConnector()
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "out"),
                      TransferOptions(startup_cost=0.0, coalesce_threshold=0),
                      sync=True)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    assert dst.store.get("out/zero.bin") == b""
    assert dst.store.get("out/sub/zero2.bin") == b""


# ---------------------------------------------------------------------------
# marker journal
# ---------------------------------------------------------------------------
def test_marker_journal_append_load_compact(tmp_path):
    ms = MarkerStore(os.path.join(str(tmp_path), "m"), compact_every=3)
    ms.append("t1", "a", {"done": [[0, 10]]})
    ms.append("t1", "b", {"done": [[0, 5]], "complete": True,
                          "checksum": "c0ffee"})
    st = ms.load("t1")
    assert st["files"]["a"]["done"] == [[0, 10]]
    assert st["files"]["b"]["complete"] and st["files"]["b"]["checksum"] == "c0ffee"
    # a later record for the same file supersedes the earlier one
    ms.append("t1", "a", {"done": [[0, 20]], "complete": True})
    # compact_every=3 reached: journal folded into the base snapshot
    assert not os.path.exists(ms._journal_path("t1"))
    assert os.path.exists(ms._path("t1"))
    st = ms.load("t1")
    assert st["files"]["a"] == {"done": [[0, 20]], "complete": True}
    ms.clear("t1")
    assert ms.load("t1") == {"files": {}}
    assert not os.path.exists(ms._path("t1"))


def test_marker_journal_torn_tail_ignored(tmp_path):
    ms = MarkerStore(os.path.join(str(tmp_path), "m"))
    ms.append("t2", "a", {"done": [[0, 7]]})
    with open(ms._journal_path("t2"), "a") as f:
        f.write('{"file": "b", "done": [[0,')  # crash mid-append
    st = ms.load("t2")
    assert st["files"] == {"a": {"done": [[0, 7]], "complete": False}}


def test_marker_save_truncates_journal(tmp_path):
    ms = MarkerStore(os.path.join(str(tmp_path), "m"))
    ms.append("t3", "a", {"done": [[0, 7]]})
    ms.save("t3", {"files": {"z": {"done": [], "complete": True}}})
    assert not os.path.exists(ms._journal_path("t3"))
    assert ms.load("t3") == {"files": {"z": {"done": [], "complete": True}}}


# ---------------------------------------------------------------------------
# O(1) structures
# ---------------------------------------------------------------------------
def test_interval_tracker_matches_merge_ranges():
    rng = random.Random(11)
    for _ in range(50):
        ranges = [[rng.randint(0, 1000), rng.randint(1, 60)]
                  for _ in range(rng.randint(1, 40))]
        tr = IntervalTracker()
        for off, ln in ranges:
            tr.add(off, ln)
        expect = _merge_ranges(ranges)
        assert tr.ranges() == expect
        assert tr.covered == sum(ln for _, ln in expect)


def test_interval_tracker_seeded_and_adjacent():
    tr = IntervalTracker([[10, 10], [0, 5]])
    assert tr.ranges() == [[0, 5], [10, 10]]
    tr.add(5, 5)  # bridges the gap exactly
    assert tr.ranges() == [[0, 20]]
    assert tr.covered == 20
    tr.add(3, 4)  # fully inside
    assert tr.ranges() == [[0, 20]] and tr.covered == 20


def test_rate_samples_bounded(tmp_path):
    from repro.core.transfer import TransferTask
    task = TransferTask("rb")
    for _ in range(3 * TransferTask.RATE_WINDOW):
        task._bytes_tick(1)
    assert len(task._rate_samples) == TransferTask.RATE_WINDOW
    assert task.stats.bytes_done == 3 * TransferTask.RATE_WINDOW


# ---------------------------------------------------------------------------
# advisor-sized threshold
# ---------------------------------------------------------------------------
def test_advisor_coalesce_threshold():
    m = PerfModel(route="r", t0=0.1, alpha=12.3, bytes_total=10**9, s0=2.3)
    adv = Advisor([Route("r", m)])
    # break-even: wire time of `threshold` bytes == t0
    th = adv.coalesce_threshold()
    assert th == int(0.1 * m.throughput)
    flat = PerfModel(route="f", t0=0.0, alpha=10.0, bytes_total=10**9)
    assert Advisor([Route("f", flat)]).coalesce_threshold() == 0
