"""Roofline machinery: HLO collective parsing + blockwise extrapolation."""

import pytest

from repro.roofline.hlo import collective_bytes
from repro.roofline.analysis import extrapolate

HLO_SAMPLE = """
HloModule jit_step
%region { ... }
ENTRY %main {
  %ar = f32[1024,8]{1,0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%region
  %ag = bf16[512,32]{1,0} all-gather(%y), channel_id=2, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %rs = f32[64,4]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[16,16]<=[256], dimensions={0}, to_apply=%region
  %a2a = f32[128]{0} all-to-all(%w), channel_id=4, replica_groups=[32,8]<=[256]
  %cp = u32[16,16]{1,0} collective-permute(%v), channel_id=5, source_target_pairs={{0,1}}
  %ard = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-reduce(%p, %q), channel_id=6, replica_groups=[16,16]<=[256], to_apply=%region
  %as = f32[4,4]{1,0} all-reduce-start(%m), channel_id=7, replica_groups=[16,16]<=[256], to_apply=%region
  %ad = f32[4,4]{1,0} all-reduce-done(%as)
}
"""


def test_collective_parse_counts_and_bytes():
    info = collective_bytes(HLO_SAMPLE)
    assert info["count"]["all-reduce"] == 3     # ar + tuple + start
    assert info["count"]["all-gather"] == 1
    assert info["count"]["reduce-scatter"] == 1
    assert info["count"]["all-to-all"] == 1
    assert info["count"]["collective-permute"] == 1
    assert info["by_op"]["all-reduce"] == (1024 * 8 * 4 + 2 * 8 * 8 * 4
                                           + 4 * 4 * 4)
    assert info["by_op"]["all-gather"] == 512 * 32 * 2
    # reduce-scatter scaled by group size (16)
    assert info["by_op"]["reduce-scatter"] == 64 * 4 * 4 * 16
    assert info["by_op"]["collective-permute"] == 16 * 16 * 4
    assert info["total"] == sum(info["by_op"].values())


def test_collective_parse_skips_done():
    done_only = "%ad = f32[4,4]{1,0} all-reduce-done(%as)"
    assert collective_bytes(done_only)["total"] == 0


def test_extrapolate_linear():
    c1 = {"flops": 10.0, "bytes": 100.0,
          "coll": {"total": 7, "by_op": {"all-reduce": 7},
                   "count": {"all-reduce": 2}}}
    c2 = {"flops": 16.0, "bytes": 130.0,
          "coll": {"total": 10, "by_op": {"all-reduce": 10},
                   "count": {"all-reduce": 3}}}
    out = extrapolate(c1, c2, n_blocks=5)
    assert out["flops"] == 10 + 4 * 6
    assert out["bytes"] == 100 + 4 * 30
    assert out["coll"]["by_op"]["all-reduce"] == 7 + 4 * 3
    assert out["coll"]["count"]["all-reduce"] == 2 + 4 * 1


def test_extrapolate_clamps_negative_marginals():
    c1 = {"flops": 10.0, "bytes": 100.0,
          "coll": {"total": 5, "by_op": {}, "count": {}}}
    c2 = {"flops": 8.0, "bytes": 90.0,
          "coll": {"total": 5, "by_op": {}, "count": {}}}
    out = extrapolate(c1, c2, n_blocks=10)
    assert out["flops"] == 10.0   # never extrapolates downward
    assert out["bytes"] == 100.0


def test_model_flops_moe_uses_active_params():
    from repro.configs import get_config
    from repro.models.common import SHAPES
    from repro.roofline.analysis import model_flops
    cfg = get_config("dbrx-132b")
    mf = model_flops(cfg, SHAPES["train_4k"])
    # 132B total but ~36B active -> 6*N_active*D
    tokens = 256 * 4096
    assert mf < 6 * 60e9 * tokens
    assert mf > 6 * 25e9 * tokens
