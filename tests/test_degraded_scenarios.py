"""Degraded-mode scenarios: the health plane under endpoint brownout,
permanent endpoint death, and a flapping-then-dark federation site
(ISSUE 6 acceptance scenarios).

``ScenarioRunner.run_degraded`` already asserts the mode's invariants
into ``DegradedScenarioResult.violations``; these tests run the modes in
the chaos / fed lanes and pin the headline numbers the issue demands."""

import pytest

from repro.core.clock import Clock
from repro.sim import ScenarioRunner


@pytest.fixture()
def runner(tmp_path):
    return ScenarioRunner(str(tmp_path), clock=Clock(scale=0.0))


@pytest.mark.chaos
@pytest.mark.parametrize("seed", [0, 1])
def test_brownout_storm_recovers_byte_exact(runner, seed):
    res = runner.run_degraded("brownout", seed=seed, strict=True)
    assert res.ok
    # full breaker lifecycle, in order: trip on the burst, recover
    # through a half-open probe
    assert res.transitions[0] == "closed->open"
    assert res.transitions[-1] == "half-open->closed"
    assert "open->half-open" in res.transitions
    # probes and fast-fail denials are distinct first-class counters
    assert res.retries_by_kind.get("HalfOpenProbe", 0) >= 1
    assert res.retries_by_kind.get("EndpointUnavailable", 0) >= 1
    assert all(r.task.status == r.task.SUCCEEDED for r in res.results)
    assert all(r.dest == r.expected for r in res.results)


@pytest.mark.chaos
def test_dead_endpoint_fleet_attempts_are_o_budget(runner):
    res = runner.run_degraded("death", seed=0, strict=True)
    assert res.ok
    # the acceptance headline: a 20-task fleet against a dead endpoint
    # touches storage O(budget) times, nowhere near 20 * (retries + 1)
    assert len(res.results) == 20
    assert res.attempts <= 11
    assert res.attempts < 20 * 7
    assert res.transitions[0] == "closed->open"
    assert res.retries_by_kind.get("EndpointUnavailable", 0) > 0
    assert not any(r.task.status == r.task.SUCCEEDED for r in res.results)


@pytest.mark.fed
def test_flapping_site_heartbeat_failover(runner):
    res = runner.run_degraded("flapping-site", seed=0, strict=True)
    assert res.ok
    coord = res.coordinator
    # flapping below the miss threshold never failed the site; the
    # sustained outage triggered exactly one automatic failover
    assert coord.metrics.auto_failovers == 1
    assert res.moved                       # work re-homed off the victim
    assert res.failover_model_seconds >= 0.0
    assert not coord.metrics.stranded
    # the coordinator stayed a pure third party throughout (heartbeats,
    # failover, and drain polls are charged to wait/control owners)
    coord.assert_third_party()
    assert all(r.task.status == r.task.SUCCEEDED for r in res.results)
