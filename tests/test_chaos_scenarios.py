"""Chaos-grade fault injection: the FaultProxyConnector + FaultSchedule
DSL + ScenarioRunner harness (ISSUE 2 tentpole).

Exercises the six canonical failure modes — transient, rate-limit storm,
bit-flip (integrity repair), session drop mid-batch, truncated stream,
latency spike — against posix / memory / emulated-cloud routes, and
asserts the end-state invariants hold: byte-exact trees, cleared
markers, consistent TaskStats, reproducible seeded runs."""

import os
import time

import pytest

from repro.connectors import (FaultProxyConnector, MemoryConnector,
                              ObjectStoreConnector, PosixConnector,
                              make_cloud)
from repro.core import (Credential, CredentialStore, Endpoint, FaultSchedule,
                        TransferOptions, TransferService)
from repro.core.clock import Clock
from repro.core.errors import FaultInjected, RateLimitError
from repro.sim import ROUTES, TREES, ScenarioRunner, canonical_tree

KB = 1024
MB = 1024 * 1024

pytestmark = pytest.mark.chaos

#: the three-route coverage demanded by the acceptance criteria:
#: conn (emulated cloud), posix, memory all appear on both ends
CHAOS_ROUTES = ("posix->memory", "posix->cloud", "cloud->memory")


@pytest.fixture()
def runner(tmp_path):
    return ScenarioRunner(str(tmp_path), clock=Clock(scale=0.0))


# ---------------------------------------------------------------------------
# baseline: every canonical tree over every route, no faults
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tree", sorted(TREES))
def test_trees_clean_over_default_route(runner, tree):
    res = runner.run(tree=tree, route="posix->memory", strict=True)
    assert res.task.status == res.task.SUCCEEDED
    assert res.dest == res.expected  # includes zero-byte + unicode names


@pytest.mark.parametrize("route", ROUTES)
def test_routes_clean_with_empty_schedule(runner, route):
    res = runner.run(tree="mixed", route=route,
                     schedule=FaultSchedule(seed=1), proxy="both", strict=True)
    assert res.task.status == res.task.SUCCEEDED
    assert res.task.stats.faults_retried == 0  # fabric invents no faults


# ---------------------------------------------------------------------------
# the six failure modes x three routes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("route", CHAOS_ROUTES)
def test_transient_fault_recovers(runner, route):
    sched = FaultSchedule(seed=2).transient(op="recv*", at=1, times=1)
    res = runner.run(tree="mixed", route=route, schedule=sched, strict=True)
    assert res.task.status == res.task.SUCCEEDED
    assert sched.count("transient") >= 1
    assert res.task.stats.retries_by_kind.get("FaultInjected", 0) >= 1


@pytest.mark.parametrize("route", CHAOS_ROUTES)
def test_rate_limit_storm_recovers(runner, route):
    sched = FaultSchedule(seed=3).rate_limit(op="recv*", at=1, times=1,
                                             retry_after=0.25)
    res = runner.run(tree="many-small", route=route, schedule=sched,
                     strict=True)
    assert res.task.status == res.task.SUCCEEDED
    assert sched.count("rate_limit") >= 1
    assert res.task.stats.retries_by_kind.get("RateLimitError", 0) >= 1


@pytest.mark.parametrize("route", CHAOS_ROUTES)
def test_bit_flip_triggers_integrity_repair(runner, route):
    sched = FaultSchedule(seed=4).bit_flip(at=1, times=1)
    res = runner.run(tree="few-large", route=route, schedule=sched,
                     options=TransferOptions(startup_cost=0.0, integrity=True,
                                             retry_backoff=0.01),
                     strict=True)
    assert res.task.status == res.task.SUCCEEDED
    assert sched.count("bit_flip") >= 1
    assert res.task.stats.integrity_failures >= 1
    assert res.dest == res.expected  # repaired, not silently corrupt


@pytest.mark.parametrize("route", CHAOS_ROUTES)
def test_session_drop_mid_batch_contained(runner, route):
    sched = FaultSchedule(seed=5).session_drop(op="recv_batch", at=1, times=1)
    res = runner.run(tree="many-small", route=route, schedule=sched,
                     strict=True)
    assert res.task.status == res.task.SUCCEEDED
    assert sched.count("session_drop") == 1
    # the dropped batch handed every file to the per-file path
    assert res.task.stats.batch_fallbacks > 0


@pytest.mark.parametrize("route", CHAOS_ROUTES)
def test_truncated_stream_detected_and_resent(runner, route):
    sched = FaultSchedule(seed=6).truncate(after_bytes=100 * KB, at=1, times=1)
    res = runner.run(tree="few-large", route=route, schedule=sched,
                     strict=True)
    assert res.task.status == res.task.SUCCEEDED
    assert sched.count("truncate") >= 1
    assert res.task.stats.retries_by_kind.get("TruncatedStream", 0) >= 1
    assert res.dest == res.expected  # holes were re-claimed byte-exact


@pytest.mark.parametrize("route", CHAOS_ROUTES)
def test_latency_spike_on_model_clock_only(runner, route):
    """Injected latency must land on the model clock, never the wall
    clock, when REPRO_TIME_SCALE=0 (pure accounting)."""
    sched = FaultSchedule(seed=7).latency(op="read", delay=3.0, times=None)
    v0 = runner.clock.virtual_elapsed
    t0 = time.monotonic()
    res = runner.run(tree="many-small", route=route, schedule=sched,
                     strict=True)
    wall = time.monotonic() - t0
    assert res.task.status == res.task.SUCCEEDED
    assert sched.count("latency") >= 1
    assert runner.clock.virtual_elapsed - v0 >= 3.0 * sched.count("latency")
    assert wall < 30.0  # seconds of *injected* model latency, instant wall


# ---------------------------------------------------------------------------
# reproducibility + exact schedule observability
# ---------------------------------------------------------------------------
def test_seeded_scenario_reproducible(runner):
    """Same seed -> same fault sequence -> same TaskStats fingerprint."""
    def build():
        return (FaultSchedule(seed=17)
                .transient(op="read", prob=0.03, times=None)
                .latency(op="stat", delay=0.2, times=None)
                .rate_limit(op="recv_batch", at=1, times=1, retry_after=0.1))

    runs = [runner.run(tree="many-small", route="posix->cloud",
                       schedule=build(), strict=True) for _ in range(2)]
    assert runs[0].fingerprint() == runs[1].fingerprint()
    assert runs[0].fingerprint()["events"]  # something actually fired


def test_faults_retried_matches_schedule_exactly(runner):
    """With a per-file route (batching off, one worker) every injected
    transient maps 1:1 onto a counted retry."""
    sched = FaultSchedule(seed=8).transient(op="recv", at=1, times=1)
    res = runner.run(tree="many-small", route="posix->memory",
                     schedule=sched,
                     options=TransferOptions(startup_cost=0.0,
                                             coalesce_threshold=0,
                                             concurrency=1,
                                             retry_backoff=0.01),
                     strict=True)
    n = res.task.stats.files_total
    assert sched.count("transient") == n
    assert res.task.stats.faults_retried == n
    assert res.task.stats.retries_by_kind == {"FaultInjected": n}


def test_truncation_with_transient_restat_not_silently_accepted(runner):
    """Regression: when the post-truncation source re-stat itself hits a
    transient fault, the short file must NOT be accepted as complete —
    the transient propagates to the retry loop and the hole is re-sent."""
    sched = (FaultSchedule(seed=13)
             .truncate(after_bytes=100 * KB, op="recv", at=1, times=1)
             .transient(op="stat", path="data/*", at=1, times=1))
    res = runner.run(tree="few-large", route="posix->memory",
                     schedule=sched, proxy="both", strict=True)
    assert res.task.status == res.task.SUCCEEDED
    assert res.dest == res.expected
    assert res.task.stats.bytes_done == res.task.stats.bytes_total
    assert res.task.stats.retries_by_kind.get("FaultInjected", 0) >= 1


def test_batch_level_fault_counted_once(runner):
    """Regression: one batch-level injection fails every batch-mate with
    the same error object; faults_retried must count it once, keeping
    the 1:1 observability contract with schedule.count()."""
    sched = FaultSchedule(seed=14).rate_limit(op="recv_batch", at=1, times=1,
                                              retry_after=0.1)
    res = runner.run(tree="many-small", route="posix->memory",
                     schedule=sched, strict=True)
    assert res.task.status == res.task.SUCCEEDED
    assert sched.count("rate_limit") == 1
    assert res.task.stats.retries_by_kind.get("RateLimitError") == 1
    assert res.task.stats.faults_retried == 1
    assert res.task.stats.batch_fallbacks == res.task.stats.files_total


def test_exhausted_retries_fail_cleanly(runner):
    """A schedule that never relents produces a *clean* failure: every
    failed file carries an error, accounting stays consistent."""
    sched = FaultSchedule(seed=9).transient(op="recv*", times=None)
    res = runner.run(tree="zero-byte", route="posix->memory",
                     schedule=sched,
                     options=TransferOptions(startup_cost=0.0, max_retries=2,
                                             retry_backoff=0.01),
                     strict=True)
    assert res.task.status == res.task.FAILED
    assert res.task.stats.files_failed == res.task.stats.files_total
    assert all(fr.error for fr in res.task.files if not fr.ok)


# ---------------------------------------------------------------------------
# proxy transparency + legacy shim
# ---------------------------------------------------------------------------
def test_proxy_delegates_metadata_and_checksum(tmp_path):
    clock = Clock(scale=0.0)
    inner = PosixConnector(os.path.join(str(tmp_path), "root"))
    proxy = FaultProxyConnector(inner, FaultSchedule(seed=0), clock=clock)
    assert proxy.name == "chaos[posix]"
    assert proxy.root == inner.root  # __getattr__ transparency
    with proxy.start(None) as s:
        proxy.command(s, "mkdir", "d")
        with open(os.path.join(inner.root, "d", "x.bin"), "wb") as f:
            f.write(b"hello world")
        info = proxy.stat(s, "d/x.bin")
        assert info.size == 11
        assert [i.name for i in proxy.listdir(s, "d")] == ["d/x.bin"]
        from repro.core import checksum_bytes
        assert proxy.checksum(s, "d/x.bin", "sha256") == \
            checksum_bytes(b"hello world", "sha256")


def test_proxy_forwards_location_inference(tmp_path):
    """Link selection must see through the proxy (placement/storage)."""
    from repro.core.transfer import _location
    clock = Clock(scale=0.0)
    storage = make_cloud("s3", clock=clock)
    conn = ObjectStoreConnector(storage, placement="cloud", clock=clock)
    proxy = FaultProxyConnector(conn, FaultSchedule(seed=0))
    assert _location(proxy) == _location(conn) == "cloud:s3"


def test_cloud_fault_plan_shim_deprecated_but_works():
    clock = Clock(scale=0.0)
    storage = make_cloud("s3", clock=clock)
    with pytest.warns(DeprecationWarning):
        storage.fault_plan = lambda op, idx: op == "put"
    from repro.connectors.cloud import lan_link
    link = lan_link(clock)
    with pytest.raises(FaultInjected):
        storage.api_put("k", b"x", link)
    storage.fault_plan = None  # clearing does not warn further
    storage.api_put("k", b"x", link)
    assert storage.blobs.get("k") == b"x"


def test_cloud_storage_native_fault_schedule():
    """CloudStorage speaks the shared FaultSchedule natively (the
    fault_plan replacement), keyed by API op + object key."""
    clock = Clock(scale=0.0)
    sched = FaultSchedule(seed=11).rate_limit(op="put", path="bkt/hot*",
                                              at=1, times=1, retry_after=0.5)
    storage = make_cloud("s3", clock=clock, faults=sched)
    from repro.connectors.cloud import lan_link
    link = lan_link(clock)
    with pytest.raises(RateLimitError) as ei:
        storage.api_put("bkt/hot1", b"x", link)
    assert ei.value.retry_after == 0.5
    storage.api_put("bkt/cold", b"y", link)   # non-matching key unaffected
    storage.api_put("bkt/hot1", b"x", link)   # window consumed: retry lands
    assert storage.blobs.get("bkt/hot1") == b"x"
    assert sched.count("rate_limit") == 1


def test_chaos_transfer_through_cloud_storage_schedule(tmp_path):
    """End to end: schedule attached to the *storage* (not a proxy) is
    retried by the service and counted by kind."""
    clock = Clock(scale=0.0)
    creds = CredentialStore()
    svc = TransferService(credential_store=creds,
                          marker_root=os.path.join(str(tmp_path), "m"),
                          clock=clock)
    sched = FaultSchedule(seed=12).transient(op="put*", at=1, times=1)
    storage = make_cloud("s3", clock=clock, faults=sched)
    dst = ObjectStoreConnector(storage, placement="local", clock=clock)
    creds.register(dst.name, Credential("s3-keypair", {}))
    src = PosixConnector(os.path.join(str(tmp_path), "src"))
    payload = os.urandom(64 * KB)
    with open(os.path.join(src.root, "a.bin"), "wb") as f:
        f.write(payload)
    task = svc.submit(Endpoint(src, "a.bin"), Endpoint(dst, "o/a.bin", dst.name),
                      TransferOptions(startup_cost=0.0, retry_backoff=0.01),
                      sync=True)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    assert storage.blobs.get("o/a.bin") == payload
    assert task.stats.retries_by_kind.get("FaultInjected", 0) >= 1
