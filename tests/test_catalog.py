"""Replica catalog: content-addressed dedupe across the three planes.

Unit level: exact LRU eviction under a byte budget, staleness
invalidation on signature mismatch, hint travel through TransferSpec.
Full stack: fan-out of N identical submissions collapses to one real
transfer plus N-1 verified replica reads; a mutated source forces a
real transfer; a corrupted replica fails the §7 fold and falls back.

The suite is marked ``catalog`` (its own tier-1 CI step); the
chaos-grade fan-out scenario additionally carries ``chaos`` so the
chaos lane picks it up.
"""

import os
import random

import pytest

from repro.catalog import ReplicaCatalog, hint_bytes, source_key
from repro.core import (Advisor, Credential, CredentialStore, Endpoint,
                        PerfModel, Route, TransferManager, TransferOptions)
from repro.fed import TransferSpec
from repro.sim import ScenarioRunner
from repro.sim.scenarios import _MeteredSrc
from repro.connectors import MemoryConnector, PosixConnector

KB = 1024
MB = 1024 * 1024

pytestmark = pytest.mark.catalog

#: integrity on (the catalog only trusts §7-folded content keys);
#: coalescing off so every file exercises the per-file replica path
OPTS = TransferOptions(integrity=True, startup_cost=0.0,
                       retry_backoff=0.01, coalesce_threshold=0)


def tree(n=3, seed=7):
    rng = random.Random(seed)
    return {f"data/f{i}.bin" if i % 2 else f"data/sub/f{i}.bin":
            rng.randbytes(rng.randint(2 * KB, 6 * KB)) for i in range(n)}


def make_fabric(tmp_path, files, catalog, max_workers=2):
    """posix source (live stat signatures) behind a send-side byte
    meter, memory destination, one manager sharing ``catalog``."""
    src_root = os.path.join(str(tmp_path), "srcfs")
    for name, payload in files.items():
        p = os.path.join(src_root, name)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "wb") as f:
            f.write(payload)
    src = _MeteredSrc(PosixConnector(src_root))
    dst = MemoryConnector()
    creds = CredentialStore()
    for ep in ("src-ep", "dst-ep"):
        creds.register(ep, Credential("local-user", {"token": "t"}))
    manager = TransferManager(
        max_workers=max_workers, per_endpoint_cap=None,
        credential_store=creds, catalog=catalog,
        marker_root=os.path.join(str(tmp_path), "markers"))
    return manager, src, dst, src_root


def xfer(manager, src, dst, k):
    task = manager.submit(Endpoint(src, "data", "src-ep"),
                          Endpoint(dst, f"out/t{k}", "dst-ep"),
                          OPTS, task_id=f"cat-t{k}")
    assert task.wait(120)
    assert task.status == task.SUCCEEDED, task.events[-3:]
    return task


def landed(dst, k):
    pfx = f"out/t{k}/"
    return {key[len(pfx):]: dst.store.get(key)
            for key in dst.store.keys() if key.startswith(pfx)}


# --------------------------------------------------------------------------
# unit: eviction, staleness, hints
# --------------------------------------------------------------------------
def _publish(cat, name, size, sig=(100, 1.0)):
    return cat.publish(content=f"c-{name}", size=size, src_sig=list(sig),
                       src_endpoint="src-ep", src_path=f"data/{name}",
                       endpoint_id="dst-ep", path=f"out/{name}")


def test_lru_eviction_is_exact():
    cat = ReplicaCatalog(byte_budget=100)
    _publish(cat, "a", 40)
    _publish(cat, "b", 40)
    # a serving lookup refreshes recency: a becomes MRU, b is now LRU
    assert cat.lookup("src-ep", "data/a", [100, 1.0], "dst-ep") is not None
    _publish(cat, "c", 40)  # 120 > 100: exactly one eviction, and it is b
    assert cat.evictions == 1
    assert [e.src_path for e in cat.entries()] == ["data/a", "data/c"]
    assert cat.bytes == 80
    assert cat.lookup("src-ep", "data/b", [100, 1.0], "dst-ep") is None


def test_oversized_publish_is_refused_not_thrashed():
    cat = ReplicaCatalog(byte_budget=100)
    _publish(cat, "a", 90)
    assert _publish(cat, "big", 200) is None
    # the resident entry survived: refusing beats evicting everything
    assert [e.src_path for e in cat.entries()] == ["data/a"]
    assert cat.evictions == 0


def test_stale_signature_invalidates_on_lookup():
    cat = ReplicaCatalog()
    _publish(cat, "a", 50, sig=(50, 1.0))
    assert cat.lookup("src-ep", "data/a", [50, 2.0], "dst-ep") is None
    assert cat.stale_invalidations == 1
    assert cat.entries() == []
    # and the stale entry is gone even for a matching-sig retry
    assert cat.peek("src-ep", "data/a", [50, 1.0], "dst-ep") is None


def test_hint_bytes_matches_exact_and_prefix():
    sources = {source_key("src-ep", "data/a.bin"): 100,
               source_key("src-ep", "data/sub/b.bin"): 50,
               source_key("src-ep", "database"): 999,
               source_key("other-ep", "data/a.bin"): 7}
    assert hint_bytes(sources, "src-ep", "data") == 150
    assert hint_bytes(sources, "src-ep", "data/a.bin") == 100
    assert hint_bytes(sources, "src-ep", "nope") == 0


def test_replica_hints_travel_with_spec():
    cat = ReplicaCatalog(site="s0")
    _publish(cat, "a", 100)
    spec = TransferSpec.new("t1", "src-ep", "data", "dst-ep", "out2")
    spec.replicas = cat.export_hints("src-ep", "data")
    traveled = TransferSpec.from_json(spec.to_json())
    adopted = ReplicaCatalog(site="s1")
    for hint in traveled.replicas:
        assert adopted.merge_hint(hint) is not None
    assert adopted.peek("src-ep", "data/a", [100, 1.0],
                        "dst-ep") is not None
    # a mutated source must never be served from a traveled hint
    assert adopted.lookup("src-ep", "data/a", [100, 2.0], "dst-ep") is None
    # malformed hints are ignored, never raised
    assert adopted.merge_hint({"garbage": True}) is None


def test_advisor_discounts_replica_bytes():
    model = PerfModel(route="r", t0=0.01, alpha=10.0,
                      bytes_total=100 * MB, s0=1.0)
    adv = Advisor([Route("r", model)])
    _, _, t_full = adv.best(10, 100 * MB)
    _, _, t_half = adv.best(10, 100 * MB, replica_bytes=50 * MB)
    _, _, t_all = adv.best(10, 100 * MB, replica_bytes=500 * MB)
    assert t_half < t_full
    assert t_all <= t_half
    # Eq. 4's N*t0 + S0 terms survive: a full replica hit still pays
    # per-file and startup overhead
    assert t_all >= model.s0


# --------------------------------------------------------------------------
# full stack: the data plane against the catalog
# --------------------------------------------------------------------------
def test_fanout_collapses_to_one_transfer(tmp_path):
    files = tree()
    cat = ReplicaCatalog()
    manager, src, dst, _ = make_fabric(tmp_path, files, cat)
    try:
        xfer(manager, src, dst, 0)
        sent_once = src.sent("data")
        assert sent_once == sum(len(p) for p in files.values())
        t1 = xfer(manager, src, dst, 1)
        t2 = xfer(manager, src, dst, 2)
        # not one more byte left the source; the fan-out was replica reads
        assert src.sent("data") == sent_once
        assert t1.stats.replica_hits == len(files)
        assert t2.stats.replica_hits == len(files)
        assert t1.stats.replica_bytes == sent_once
        expected = {name[len("data/"):]: p for name, p in files.items()}
        for k in (0, 1, 2):
            assert landed(dst, k) == expected
    finally:
        manager.shutdown(wait=False)


def test_mutated_source_forces_real_transfer(tmp_path):
    files = tree()
    cat = ReplicaCatalog()
    manager, src, dst, src_root = make_fabric(tmp_path, files, cat)
    try:
        xfer(manager, src, dst, 0)
        victim = sorted(files)[0]
        mutated = bytes(b ^ 0xFF for b in files[victim])
        p = os.path.join(src_root, victim)
        with open(p, "wb") as f:
            f.write(mutated)
        st = os.stat(p)
        os.utime(p, (st.st_atime + 100, st.st_mtime + 100))
        files[victim] = mutated

        t1 = xfer(manager, src, dst, 1)
        # the mutated file was re-read for real, the others hit
        assert cat.stale_invalidations >= 1
        assert t1.stats.replica_hits == len(files) - 1
        expected = {name[len("data/"):]: p for name, p in files.items()}
        assert landed(dst, 1) == expected
    finally:
        manager.shutdown(wait=False)


def test_corrupt_replica_fails_fold_and_falls_back(tmp_path):
    files = tree()
    cat = ReplicaCatalog()
    manager, src, dst, _ = make_fabric(tmp_path, files, cat)
    try:
        xfer(manager, src, dst, 0)
        for key in list(dst.store.keys()):
            if key.startswith("out/t0/"):
                data = dst.store.get(key)
                dst.store.put(key, bytes([data[0] ^ 0xFF]) + data[1:])

        t1 = xfer(manager, src, dst, 1)
        # every corrupted replica read failed its fold, was invalidated,
        # and fell back to a real source read — correct bytes landed
        assert t1.stats.replica_fallbacks == len(files)
        assert cat.corrupt_invalidations == len(files)
        expected = {name[len("data/"):]: p for name, p in files.items()}
        assert landed(dst, 1) == expected
        assert src.sent("data") == 2 * sum(len(p) for p in files.values())
    finally:
        manager.shutdown(wait=False)


def test_manager_digest_carries_catalog_summary(tmp_path):
    files = tree()
    cat = ReplicaCatalog()
    manager, src, dst, _ = make_fabric(tmp_path, files, cat)
    try:
        d = manager.digest()
        assert d["catalog"]["stats"]["entries"] == 0
        xfer(manager, src, dst, 0)
        d = manager.digest()
        assert d["catalog"]["stats"]["entries"] == len(files)
        held = hint_bytes(d["catalog"]["sources"], "src-ep", "data")
        assert held == sum(len(p) for p in files.values())
    finally:
        manager.shutdown(wait=False)


# --------------------------------------------------------------------------
# chaos: the fan-out scenario under catalog betrayals
# --------------------------------------------------------------------------
@pytest.mark.chaos
@pytest.mark.parametrize("chaos", ["none", "evict", "stale", "corrupt"])
def test_run_fanout_chaos(tmp_path, chaos):
    res = ScenarioRunner(str(tmp_path)).run_fanout(
        n_fanout=4, chaos=chaos, strict=True)
    assert res.ok
    if chaos == "none":
        assert res.moved_ratio <= 1.05
        assert res.catalog.hit_rate() >= 0.7
    else:
        # betrayed catalog: more source bytes moved, never wrong bytes
        assert res.source_bytes >= 2 * res.tree_bytes
