"""Per-kernel validation: shape/dtype sweeps + hypothesis properties,
always against the pure-jnp oracle, in interpret mode on CPU."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis")  # container may lack it
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_chunked, ssm_scan_ref
from repro.kernels.checksum.ops import checksum_digest
from repro.kernels.checksum.ref import digest_ref
from repro.core.integrity import checksum_bytes


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    # B, Sq, Skv, H, KV, dh, causal, window
    (2, 128, 128, 4, 2, 64, True, None),
    (1, 128, 128, 4, 4, 128, True, None),   # MHA, MXU-aligned dh
    (1, 96, 96, 8, 1, 32, True, None),      # MQA, ragged seq
    (2, 64, 256, 4, 4, 48, False, None),    # cross/bidir, padded dh
    (1, 256, 256, 4, 2, 64, True, 96),      # sliding window
    (1, 130, 130, 2, 2, 80, True, 64),      # non-multiple seq + window
]


@pytest.mark.parametrize("case", ATTN_CASES,
                         ids=[f"a{i}" for i in range(len(ATTN_CASES))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Skv, H, KV, dh, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, dh), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, dh), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window)
    want = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 2), st.sampled_from([32, 64, 96]),
       st.sampled_from([1, 2, 4]), st.sampled_from([16, 32, 64]),
       st.booleans())
def test_flash_attention_property(b, s, kv, dh, causal):
    h = kv * 2
    ks = jax.random.split(jax.random.PRNGKey(s + dh), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, dh), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=None)
    want = attention_ref(q, k, v, causal=causal, window=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_causality():
    """Future tokens must not influence earlier outputs."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)
    base = flash_attention(q, k, v, causal=True, window=None)
    k2 = k.at[:, 40:].set(99.0)
    v2 = v.at[:, 40:].set(-99.0)
    pert = flash_attention(q, k2, v2, causal=True, window=None)
    np.testing.assert_allclose(np.asarray(base[:, :40]),
                               np.asarray(pert[:, :40]), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------
def _ssm_inputs(B, T, H, K, V, seed, scalar=False, decay_scale=1.5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (B, T, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, V)) * 0.5
    if scalar:
        g = -jnp.exp(jax.random.normal(ks[3], (B, T, H, 1)) - decay_scale)
        g = jnp.broadcast_to(g, (B, T, H, K))
    else:
        g = -jnp.exp(jax.random.normal(ks[3], (B, T, H, K)) - decay_scale)
    s0 = jax.random.normal(ks[4], (B, H, K, V)) * 0.3
    u = jax.random.normal(ks[5], (H, K)) * 0.5
    return q, k, v, g, s0, u


SSM_CASES = [
    # B, T, H, K, V, use_u, chunk, sub
    (2, 64, 3, 8, 16, False, 32, 8),
    (1, 128, 2, 16, 16, False, 64, 16),
    (2, 48, 2, 8, 8, True, 16, 8),
    (1, 40, 4, 8, 8, True, 16, 4),          # pad path (40 % 16 != 0)
    (1, 256, 1, 32, 32, False, 128, 16),
]


@pytest.mark.parametrize("case", SSM_CASES,
                         ids=[f"s{i}" for i in range(len(SSM_CASES))])
def test_ssm_scan_matches_ref(case):
    B, T, H, K, V, use_u, chunk, sub = case
    q, k, v, g, s0, u = _ssm_inputs(B, T, H, K, V, seed=T + K)
    uu = u if use_u else None
    y_ref, s_ref = ssm_scan_ref(q, k, v, g, u=uu, initial_state=s0)
    y, s_fin = ssm_scan(q, k, v, g, u=uu, initial_state=s0,
                        chunk=chunk, subchunk=sub)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s_ref),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([16, 32, 48]), st.integers(1, 3),
       st.sampled_from([4, 8]), st.booleans(), st.booleans())
def test_ssm_chunked_jnp_property(T, H, K, use_u, scalar):
    q, k, v, g, s0, u = _ssm_inputs(1, T, H, K, K, seed=T * H + K,
                                    scalar=scalar)
    uu = u if use_u else None
    y_ref, s_ref = ssm_scan_ref(q, k, v, g, u=uu, initial_state=s0)
    y, s = ssm_scan_chunked(q, k, v, g, u=uu, initial_state=s0,
                            chunk=16, subchunk=8,
                            scalar_decay=scalar and not use_u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=5e-5, atol=5e-5)


def test_ssm_scan_strong_decay_stability():
    """Strong decays (rwkv-style) must not overflow the chunked form."""
    q, k, v, g, s0, u = _ssm_inputs(1, 64, 2, 8, 8, seed=0, decay_scale=-1.5)
    # decay_scale -1.5 -> log decays around -e^{1.5} ~ -4.5 per step
    y_ref, s_ref = ssm_scan_ref(q, k, v, g, u=u, initial_state=s0)
    y, s = ssm_scan(q, k, v, g, u=u, initial_state=s0, chunk=32, subchunk=8)
    assert np.isfinite(np.asarray(y)).all()
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# checksum
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,dtype", [
    ((1024,), jnp.float32), ((8, 128), jnp.float32), ((1000,), jnp.float32),
    ((333,), jnp.int32), ((64, 9), jnp.bfloat16), ((5,), jnp.float32),
])
def test_checksum_kernel_matches_bytes(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    d_kernel = checksum_digest(x, use_pallas=True)
    d_jnp = checksum_digest(x, use_pallas=False)
    d_bytes = digest_ref(np.asarray(x).tobytes())
    assert d_kernel == d_bytes == d_jnp


@settings(max_examples=30, deadline=None)
@given(st.binary(min_size=0, max_size=3000))
def test_lanesum32_stream_matches_ref(data):
    assert checksum_bytes(data, "lanesum32") == digest_ref(data)


def test_checksum_detects_single_bitflip():
    x = np.random.default_rng(1).standard_normal(4096).astype(np.float32)
    d0 = checksum_digest(jnp.asarray(x))
    raw = bytearray(x.tobytes())
    raw[1234] ^= 0x01
    x2 = np.frombuffer(bytes(raw), np.float32)
    assert checksum_digest(jnp.asarray(x2)) != d0
