"""Federation plane: TransferSpec serialization, cross-site placement,
third-party handoff, and the streaming checksum fold that lets a
resumed/handed-off task skip the §7 source re-read.

The suite is marked ``fed`` (tier-1 CI lane); the chaos-grade federated
scenario additionally carries ``chaos`` so the chaos lane picks it up.
"""

import json
import os
import random
import tempfile
import threading
import time

import pytest

from repro.connectors import MemoryConnector, PosixConnector
from repro.core import (Advisor, Credential, CredentialStore, Endpoint,
                        FaultSchedule, PerfModel, Route, TransferManager,
                        TransferOptions)
from repro.core.clock import Clock
from repro.core.transfer import COMPOSITE_PREFIX, TransferTask
from repro.fed import (FederatedCoordinator, QueueDigest,
                       StrandedTasksError, TransferSpec, SPEC_STATES)
from repro.sim import ScenarioRunner
from repro.sim.scenarios import _HoldSrc, _InstrumentedDst

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KB = 1024
MB = 1024 * 1024

pytestmark = pytest.mark.fed


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------
def make_site(tmp_path, name, clock, tenants=("alice",), advisor=None,
              max_workers=2):
    creds = CredentialStore()
    for tenant in tenants:
        creds.register("src-ep", Credential("local-user",
                                            {"identity": tenant}))
    return TransferManager(credential_store=creds, max_workers=max_workers,
                           per_endpoint_cap=None, advisor=advisor,
                           marker_root=os.path.join(str(tmp_path),
                                                    f"markers-{name}"),
                           clock=clock, site_id=name)


def seed_memory(files):
    conn = MemoryConnector()
    for name, payload in files.items():
        conn.store.put(name, payload)
    return conn


def small_tree(n=12, size=3 * KB, seed=0):
    rng = random.Random(seed)
    return {f"data/f{i:02d}.bin": rng.randbytes(size) for i in range(n)}


def read_out(store, prefix="out/"):
    return {k[len(prefix):]: store.get(k)
            for k in store.keys() if k.startswith(prefix)}


# --------------------------------------------------------------------------
# TransferSpec serialization
# --------------------------------------------------------------------------
def _random_spec(seed: int) -> TransferSpec:
    rng = random.Random(f"spec|{seed}")
    state = rng.choice(SPEC_STATES)
    files = {}
    if state == "paused":
        for i in range(rng.randint(1, 4)):
            size = rng.randint(1, 4 * MB)
            done, digests, at = [], {}, 0
            for _ in range(rng.randint(0, 3)):
                if at >= size:
                    break
                ln = rng.randint(1, max(1, (size - at) // 2))
                done.append([at, ln])
                digests[f"{at}:{ln}"] = f"{rng.getrandbits(128):032x}"
                at += ln + rng.randint(0, 1024)
            files[f"data/ü{i}.bin"] = {
                "done": done, "complete": False, "digests": digests}
    return TransferSpec(
        task_id=f"t-{seed}", src_endpoint="ep-a", src_path="data",
        dst_endpoint="ep-b", dst_path="out",
        tenant=rng.choice(["alice", "bob", ""]),
        priority=rng.randint(-2, 5), state=state,
        options={"concurrency": rng.choice([1, 4]),
                 "integrity": rng.random() < 0.5,
                 "coalesce_threshold": rng.choice([0, 64 * KB])},
        route=rng.choice(["", "s3/up"]),
        n_files=rng.randint(0, 40), nbytes=rng.randint(0, 10 * MB),
        origin_site=rng.choice(["", "s0", "s1"]),
        stats={"actual_model_seconds": rng.random() * 10,
               "resumes": rng.randint(0, 3)},
        markers={"files": files})


def _roundtrip_property(seed: int) -> None:
    spec = _random_spec(seed)
    wire = spec.to_json()
    back = TransferSpec.from_json(wire)
    assert back == spec
    # canonical wire form is stable (sorted keys, value-identical)
    assert back.to_json() == wire
    # the manager payload shape round-trips too (handoff path)
    assert TransferSpec.from_payload(spec.to_payload()) == spec
    # the wire form is plain JSON a foreign control plane could parse
    raw = json.loads(wire)
    assert raw["task_id"] == spec.task_id
    assert raw["markers"] == spec.markers


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=0, max_value=2 ** 32 - 1))
    def test_spec_json_roundtrip(seed):
        _roundtrip_property(seed)
else:
    @pytest.mark.parametrize("seed", list(range(16)))
    def test_spec_json_roundtrip(seed):
        _roundtrip_property(seed)


def test_spec_roundtrip_covers_every_state():
    seen = set()
    for seed in range(64):
        spec = _random_spec(seed)
        _roundtrip_property(seed)
        seen.add(spec.state)
    assert seen == set(SPEC_STATES)


def test_spec_validation_rejects_garbage():
    with pytest.raises(ValueError):
        TransferSpec.new("", "a", "p", "b", "q").validate()
    with pytest.raises(ValueError):
        TransferSpec.new("t", "", "p", "b", "q").validate()
    spec = TransferSpec.new("t", "a", "p", "b", "q")
    spec.state = "running"  # live states never travel
    with pytest.raises(ValueError):
        spec.validate()
    spec = TransferSpec.new("t", "a", "p", "b", "q")
    spec.markers = {"oops": 1}
    with pytest.raises(ValueError):
        spec.to_json()


def test_spec_pending_bytes_from_hole_map():
    spec = TransferSpec.new("t", "a", "data", "b", "out", nbytes=100)
    assert spec.pending_bytes() == 100
    spec.markers = {"files": {"data/x": {"done": [[0, 30], [50, 10]],
                                         "complete": False}}}
    assert spec.done_bytes() == 40
    assert spec.pending_bytes() == 60


# --------------------------------------------------------------------------
# cross-site placement + attribution
# --------------------------------------------------------------------------
def test_cross_site_placement_attribution(tmp_path):
    """A spec whose source endpoint is owned by a different site is
    placed there, completes byte-exact, and both tenant and model-time
    attribution stick — while the coordinator charges nothing."""
    clock = Clock(scale=0.0)
    files = small_tree()
    src_conn = seed_memory(files)
    dst_conn = MemoryConnector()
    eps = {"src-ep": src_conn, "dst-ep": dst_conn}

    coord = FederatedCoordinator(placement="owner")
    coord.register_site("near-dst", make_site(tmp_path, "near-dst", clock),
                        eps, owns={"dst-ep"})
    coord.register_site("near-src", make_site(tmp_path, "near-src", clock),
                        eps, owns={"src-ep"})

    spec = TransferSpec.new(
        "xsite-1", "src-ep", "data", "dst-ep", "out", tenant="alice",
        options=TransferOptions(startup_cost=0.0),
        n_files=len(files), nbytes=sum(map(len, files.values())))
    task = coord.submit(spec.to_json(), sync=True)

    assert coord.site_of("xsite-1") == "near-src"
    assert task.status == task.SUCCEEDED
    assert task.stats.tenant == "alice"
    assert task.stats.site == "near-src"
    assert task.stats.origin_site == "near-src"
    assert task.stats.actual_model_seconds > 0
    got = read_out(dst_conn.store)
    assert got == {k[len("data/"):]: v for k, v in files.items()}
    coord.assert_third_party()
    assert coord.model_seconds() == 0.0
    digests = coord.exchange_digests()
    assert set(digests) == {"near-dst", "near-src"}
    assert all(isinstance(d, QueueDigest) and d.depth == 0
               for d in digests.values())
    coord.shutdown()


def test_manager_export_import_paused_task(tmp_path):
    """Manager-level travel: a paused task exports with its hole map,
    the origin handle finishes HANDED_OFF, and a peer manager resumes
    it re-sending only the holes (carried stats intact)."""
    clock = Clock(scale=0.0)
    payload = os.urandom(2 * MB)
    src_conn = _HoldSrc(seed_memory({"data/big.bin": payload}))
    src_conn.arm_hold(["data/"], 256 * KB)
    dst_inner = MemoryConnector()
    dst_conn = _InstrumentedDst(dst_inner)

    mgr_a = make_site(tmp_path, "a", clock)
    opts = TransferOptions(startup_cost=0.0, concurrency=1, parallelism=1,
                           blocksize=64 * KB, coalesce_threshold=0)
    task_a = mgr_a.submit(Endpoint(src_conn, "data", "src-ep"),
                          Endpoint(dst_conn, "out", "dst-ep"), opts,
                          task_id="trav-1", tenant="alice")
    assert src_conn.engaged.wait(30)
    mgr_a.pause("trav-1")
    src_conn.release()
    deadline = time.monotonic() + 30
    payload_out = None
    while time.monotonic() < deadline:
        payload_out = mgr_a.export_state("trav-1")
        if payload_out is not None or task_a._done.is_set():
            break
        task_a.wait_idle(0.05)
    assert payload_out is not None, task_a.status
    assert task_a.status == TransferTask.HANDED_OFF
    assert task_a.wait(1)  # origin waiters unblock

    # the payload is JSON-clean and carries real partial progress
    spec = TransferSpec.from_payload(json.loads(json.dumps(payload_out)))
    assert spec.state == "paused"
    assert spec.done_bytes() > 0
    carried = spec.stats["actual_model_seconds"]

    before_import = dst_conn.written("out/")
    mgr_b = make_site(tmp_path, "b", clock)
    task_b = mgr_b.import_state(spec.to_payload(),
                                Endpoint(src_conn, "data", "src-ep"),
                                Endpoint(dst_conn, "out", "dst-ep"))
    assert task_b.wait(30)
    assert task_b.status == task_b.SUCCEEDED
    assert dst_inner.store.get("out/big.bin") == payload
    # only the holes were re-sent
    assert dst_conn.written("out/") == len(payload)
    assert dst_conn.written("out/") - before_import \
        == len(payload) - spec.done_bytes()
    assert task_b.stats.resumes == 1
    assert task_b.stats.tenant == "alice"
    assert task_b.stats.origin_site == "a"
    assert task_b.stats.site == "b"
    assert task_b.stats.actual_model_seconds >= carried
    assert mgr_a.metrics.exports == 1 and mgr_b.metrics.imports == 1
    mgr_a.shutdown(wait=False)
    mgr_b.shutdown(wait=False)


# --------------------------------------------------------------------------
# handoff race: site dies mid-batch
# --------------------------------------------------------------------------
def test_handoff_race_site_dies_mid_batch(tmp_path):
    """The victim site is killed while its task is inside a coalesced
    batch; the peer resumes byte-exact, and the destination write meter
    proves every byte landed exactly once (holes only)."""
    clock = Clock(scale=0.0)
    files = small_tree(n=16, size=4 * KB, seed=3)
    total = sum(map(len, files.values()))
    src_conn = _HoldSrc(seed_memory(files))
    src_conn.arm_hold(["data/"], 6 * KB)  # mid-batch: a few files landed
    dst_inner = MemoryConnector()
    dst_conn = _InstrumentedDst(dst_inner)
    eps = {"src-ep": src_conn, "dst-ep": dst_conn}

    coord = FederatedCoordinator(placement="owner")
    coord.register_site("a", make_site(tmp_path, "a", clock), eps,
                        owns={"src-ep", "dst-ep"})
    coord.register_site("b", make_site(tmp_path, "b", clock), eps,
                        owns=set())

    spec = TransferSpec.new(
        "race-1", "src-ep", "data", "dst-ep", "out", tenant="bob",
        options=TransferOptions(startup_cost=0.0,
                                coalesce_threshold=64 * KB,
                                max_batch_files=32),
        n_files=len(files), nbytes=total)
    task_a = coord.submit(spec.to_json())
    assert coord.site_of("race-1") == "a"
    assert src_conn.engaged.wait(30)
    # the crossing block is still in flight on the receive side; killing
    # the site before it lands durable would checkpoint zero progress
    # (same sequencing as ScenarioRunner.run_federated)
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline and task_a.stats.bytes_done == 0:
        time.sleep(0.002)

    moved: list = []
    failer = threading.Thread(
        target=lambda: moved.extend(coord.fail_site("a", timeout=60)),
        daemon=True)
    failer.start()
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        if task_a._pause_req.is_set() or task_a._done.is_set() \
                or task_a.status == task_a.PAUSED:
            break
        time.sleep(0.005)
    src_conn.release()
    failer.join(60)
    assert not failer.is_alive()

    assert moved == [("race-1", "b")]
    traveled = coord.last_spec("race-1")
    assert traveled.state == "paused"
    assert traveled.done_bytes() > 0
    task_b = coord.task("race-1")
    assert task_b is not task_a
    assert task_b.wait(30)
    assert task_b.status == task_b.SUCCEEDED
    assert read_out(dst_inner.store) \
        == {k[len("data/"):]: v for k, v in files.items()}
    # byte-exact accounting: nothing the first run landed was re-sent
    assert dst_conn.written("out/") == total
    assert task_b.stats.tenant == "bob"
    assert task_b.stats.origin_site == "a"
    coord.assert_third_party()
    coord.shutdown(wait=False)


# --------------------------------------------------------------------------
# streaming checksum fold (§7 without source re-reads)
# --------------------------------------------------------------------------
class ChecksumCountingPosix(PosixConnector):
    """Counts whole-file source checksum re-reads — the §7 cost the
    per-range digest journal exists to eliminate."""

    def __init__(self, root):
        super().__init__(root)
        self.checksum_calls = 0

    def checksum(self, session, path, algorithm):
        self.checksum_calls += 1
        return super().checksum(session, path, algorithm)


def seeded_posix(tmp_path, files):
    root = os.path.join(str(tmp_path), "srcroot")
    conn = ChecksumCountingPosix(root)
    for name, payload in files.items():
        p = os.path.join(root, name)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "wb") as f:
            f.write(payload)
    return conn


def test_checksum_fold_on_pause_resume_no_source_reread(tmp_path):
    clock = Clock(scale=0.0)
    payload = os.urandom(3 * MB)
    counting = seeded_posix(tmp_path, {"data/big.bin": payload})
    src_conn = _HoldSrc(counting)
    src_conn.arm_hold(["data/"], 512 * KB)
    dst_inner = MemoryConnector()
    dst_conn = _InstrumentedDst(dst_inner)

    mgr = make_site(tmp_path, "solo", clock)
    opts = TransferOptions(startup_cost=0.0, integrity=True, concurrency=1,
                           parallelism=1, blocksize=128 * KB,
                           digest_segment=256 * KB, coalesce_threshold=0)
    task = mgr.submit(Endpoint(src_conn, "data", "src-ep"),
                      Endpoint(dst_conn, "out", "dst-ep"), opts,
                      task_id="fold-1")
    assert src_conn.engaged.wait(30)
    mgr.pause("fold-1")
    src_conn.release()
    assert task.wait_idle(30)
    deadline = time.monotonic() + 30
    while task.status != task.PAUSED and time.monotonic() < deadline:
        if task._done.is_set():
            break
        time.sleep(0.005)
    assert task.status == task.PAUSED

    # the journal now holds digest-backed resumable ranges
    state = mgr.service.markers.load("fold-1")["files"]["data/big.bin"]
    assert state["digests"]
    digested = sum(ln for _, ln in
                   (map(int, k.split(":")) for k in state["digests"]))
    assert digested == sum(ln for _, ln in state["done"])

    mgr.resume("fold-1")
    assert task.wait(30)
    assert task.status == task.SUCCEEDED
    assert dst_inner.store.get("out/big.bin") == payload
    fr = task.files[-1]
    assert fr.ok and fr.checksum.startswith(COMPOSITE_PREFIX)
    # §7 held (verify passed) with ZERO source re-reads
    assert counting.checksum_calls == 0
    assert task.stats.integrity_failures == 0
    mgr.shutdown(wait=False)


def test_checksum_fold_travels_across_handoff(tmp_path):
    """A handed-off integrity task must not re-read the source on the
    new site: the per-range digests ride the spec's marker state."""
    clock = Clock(scale=0.0)
    payload = os.urandom(2 * MB)
    counting = seeded_posix(tmp_path, {"data/big.bin": payload})
    src_conn = _HoldSrc(counting)
    src_conn.arm_hold(["data/"], 256 * KB)
    dst_inner = MemoryConnector()
    dst_conn = _InstrumentedDst(dst_inner)
    eps = {"src-ep": src_conn, "dst-ep": dst_conn}

    coord = FederatedCoordinator(placement="owner")
    coord.register_site("a", make_site(tmp_path, "a", clock), eps,
                        owns={"src-ep", "dst-ep"})
    coord.register_site("b", make_site(tmp_path, "b", clock), eps,
                        owns=set())
    spec = TransferSpec.new(
        "foldoff-1", "src-ep", "data", "dst-ep", "out", tenant="alice",
        options=TransferOptions(startup_cost=0.0, integrity=True,
                                concurrency=1, parallelism=1,
                                blocksize=64 * KB,
                                digest_segment=128 * KB,
                                coalesce_threshold=0),
        n_files=1, nbytes=len(payload))
    task_a = coord.submit(spec.to_json())
    assert src_conn.engaged.wait(30)

    out: list = []
    mover = threading.Thread(
        target=lambda: out.append(coord.handoff("foldoff-1", timeout=60)),
        daemon=True)
    mover.start()
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        if task_a._pause_req.is_set() or task_a._done.is_set():
            break
        time.sleep(0.005)
    src_conn.release()
    mover.join(60)
    assert not mover.is_alive()
    task_b = out[0]
    assert task_b is not None
    assert coord.site_of("foldoff-1") == "b"

    traveled = coord.last_spec("foldoff-1")
    fstate = traveled.markers["files"]["data/big.bin"]
    assert fstate["digests"], "digests did not travel with the spec"
    assert task_b.wait(30)
    assert task_b.status == task_b.SUCCEEDED
    assert dst_inner.store.get("out/big.bin") == payload
    assert task_b.files[-1].checksum.startswith(COMPOSITE_PREFIX)
    assert counting.checksum_calls == 0
    assert coord.metrics.handoffs == 1
    coord.assert_third_party()
    coord.shutdown(wait=False)


def test_checksum_fold_discarded_when_source_changes_under_pause(tmp_path):
    """A source modified while the task was paused invalidates the
    journaled digests AND hole map: the resume re-sends the whole file
    (no stale old/new mix can pass §7)."""
    clock = Clock(scale=0.0)
    old = os.urandom(2 * MB)
    new = os.urandom(2 * MB)
    src_inner = seed_memory({"data/big.bin": old})
    src_conn = _HoldSrc(src_inner)
    src_conn.arm_hold(["data/"], 256 * KB)
    dst_inner = MemoryConnector()
    dst_conn = _InstrumentedDst(dst_inner)

    mgr = make_site(tmp_path, "mut", clock)
    opts = TransferOptions(startup_cost=0.0, integrity=True, concurrency=1,
                           parallelism=1, blocksize=64 * KB,
                           digest_segment=128 * KB, coalesce_threshold=0)
    task = mgr.submit(Endpoint(src_conn, "data", "src-ep"),
                      Endpoint(dst_conn, "out", "dst-ep"), opts,
                      task_id="mut-1")
    assert src_conn.engaged.wait(30)
    mgr.pause("mut-1")
    src_conn.release()
    assert task.wait_idle(30)
    deadline = time.monotonic() + 30
    while task.status != task.PAUSED and time.monotonic() < deadline:
        time.sleep(0.005)
    assert task.status == task.PAUSED
    st = mgr.service.markers.load("mut-1")["files"]["data/big.bin"]
    assert st["done"] and st["digests"]  # real partial progress existed

    # the source changes while the task is paused (same size)
    src_inner.store.put("data/big.bin", new)
    mgr.resume("mut-1")
    assert task.wait(30)
    assert task.status == task.SUCCEEDED, task.events[-3:]
    # byte-exact against the CURRENT source, verified, no stale mix
    assert dst_inner.store.get("out/big.bin") == new
    assert task.stats.integrity_failures == 0
    # the whole file was re-sent: old partial progress was discarded
    assert dst_conn.written("out/") >= len(new)
    assert any("source changed" in msg for _, msg in task.events)
    mgr.shutdown(wait=False)


def test_cancelled_spec_import_leaves_no_markers(tmp_path):
    """A cancelled spec is registered terminal on arrival; its traveled
    markers must NOT be installed (a later same-id submission would
    silently inherit the hole map)."""
    clock = Clock(scale=0.0)
    mgr = make_site(tmp_path, "c", clock)
    spec = TransferSpec.new("dead-1", "src-ep", "data", "dst-ep", "out",
                            tenant="alice")
    spec.state = "cancelled"
    spec.markers = {"files": {"data/x.bin": {"done": [[0, 1024]],
                                             "complete": False}}}
    task = mgr.import_state(
        spec.to_payload(),
        Endpoint(MemoryConnector(), "data", "src-ep"),
        Endpoint(MemoryConnector(), "out", "dst-ep"))
    assert task.status == TransferTask.CANCELLED
    assert task.wait(1)
    assert mgr.service.markers.load("dead-1") == {"files": {}}
    mgr.shutdown(wait=False)


# --------------------------------------------------------------------------
# placement policies
# --------------------------------------------------------------------------
def _fabricated_sites(tmp_path, clock, depths, advisors=None):
    coord = FederatedCoordinator(placement="owner")
    eps = {"src-ep": MemoryConnector(), "dst-ep": MemoryConnector()}
    sites = []
    for i, depth in enumerate(depths):
        adv = (advisors or {}).get(i)
        handle = coord.register_site(
            f"s{i}", make_site(tmp_path, f"s{i}", clock, advisor=adv), eps)
        handle.digest = QueueDigest(site_id=f"s{i}", seq=i, queued=depth,
                                    running=0, paused=0, in_flight_bytes=0)
        sites.append(handle)
    return coord, sites


def test_least_loaded_placement(tmp_path):
    clock = Clock(scale=0.0)
    coord, sites = _fabricated_sites(tmp_path, clock, depths=(5, 0, 2))
    coord.placement = "least-loaded"
    spec = TransferSpec.new("p1", "src-ep", "data", "dst-ep", "out")
    assert coord._place(spec, sites).site_id == "s1"


def test_advisor_placement_prefers_predicted_fastest(tmp_path):
    clock = Clock(scale=0.0)
    fast = Advisor([Route("r", PerfModel(route="r", t0=0.001,
                                         alpha=10.0, bytes_total=MB))])
    slow = Advisor([Route("r", PerfModel(route="r", t0=0.5,
                                         alpha=10.0, bytes_total=MB))])
    coord, sites = _fabricated_sites(tmp_path, clock, depths=(0, 0),
                                     advisors={0: slow, 1: fast})
    coord.placement = "advisor"
    spec = TransferSpec.new("p2", "src-ep", "data", "dst-ep", "out",
                            route="r", n_files=100, nbytes=MB)
    assert coord._place(spec, sites).site_id == "s1"
    # load scales the prediction: pile depth onto the fast site and the
    # slow-but-idle one wins
    sites[1].digest = QueueDigest(site_id="s1", seq=9, queued=2000,
                                  running=0, paused=0, in_flight_bytes=0)
    assert coord._place(spec, sites).site_id == "s0"


def test_callable_placement_policy(tmp_path):
    clock = Clock(scale=0.0)
    coord, sites = _fabricated_sites(tmp_path, clock, depths=(0, 0))
    coord.placement = lambda spec, candidates: candidates[-1]
    spec = TransferSpec.new("p3", "src-ep", "data", "dst-ep", "out")
    assert coord._place(spec, sites).site_id == "s1"


def test_handoff_without_adoptable_peer_never_strands_the_task(tmp_path):
    """If no peer can adopt, handoff must raise BEFORE the destructive
    export — the task (and its marker state) stays on the origin and
    remains resumable."""
    clock = Clock(scale=0.0)
    payload = os.urandom(1 * MB)
    src_conn = _HoldSrc(seed_memory({"data/big.bin": payload}))
    src_conn.arm_hold(["data/"], 128 * KB)
    dst_conn = MemoryConnector()
    coord = FederatedCoordinator(placement="owner")
    coord.register_site("a", make_site(tmp_path, "a", clock),
                        {"src-ep": src_conn, "dst-ep": dst_conn},
                        owns={"src-ep", "dst-ep"})
    # the only peer cannot reach the destination endpoint
    coord.register_site("b", make_site(tmp_path, "b", clock),
                        {"src-ep": src_conn}, owns=set())
    spec = TransferSpec.new(
        "strand-1", "src-ep", "data", "dst-ep", "out", tenant="alice",
        options=TransferOptions(startup_cost=0.0, concurrency=1,
                                parallelism=1, blocksize=64 * KB,
                                coalesce_threshold=0),
        n_files=1, nbytes=len(payload))
    task = coord.submit(spec.to_json())
    assert src_conn.engaged.wait(30)
    mgr_a = coord.sites()["a"].manager
    mgr_a.pause("strand-1")
    src_conn.release()
    assert task.wait_idle(30)
    deadline = time.monotonic() + 30
    while task.status != task.PAUSED and time.monotonic() < deadline:
        time.sleep(0.005)
    assert task.status == task.PAUSED

    with pytest.raises(LookupError):
        coord.handoff("strand-1")
    # nothing was destroyed: still placed at (and resumable on) site a
    assert coord.site_of("strand-1") == "a"
    assert task.status == task.PAUSED
    assert mgr_a.service.markers.load("strand-1")["files"]
    assert mgr_a.resume("strand-1")
    assert task.wait(30)
    assert task.status == task.SUCCEEDED
    assert dst_conn.store.get("out/big.bin") == payload
    coord.shutdown(wait=False)


def test_fail_site_reports_stranded_without_losing_moved(tmp_path):
    """A failover where one task has no adoptable peer still re-homes
    the others, pauses+checkpoints the stranded one on the dead site's
    durable store, and reports both through StrandedTasksError."""
    clock = Clock(scale=0.0)
    big_a = os.urandom(1 * MB)
    big_b = os.urandom(1 * MB)
    src_conn = _HoldSrc(seed_memory({"data/t0/a.bin": big_a,
                                     "data/t1/b.bin": big_b}))
    src_conn.arm_hold(["data/"], 128 * KB)
    dst_shared = MemoryConnector()
    dst_only_a = MemoryConnector()
    eps_a = {"src-ep": src_conn, "dst-ep": dst_shared,
             "dst-only-a": dst_only_a}
    eps_b = {"src-ep": src_conn, "dst-ep": dst_shared}

    coord = FederatedCoordinator(placement="owner")
    mgr_a = make_site(tmp_path, "a", clock)
    coord.register_site("a", mgr_a, eps_a, owns=set(eps_a))
    coord.register_site("b", make_site(tmp_path, "b", clock), eps_b,
                        owns=set())
    opts = TransferOptions(startup_cost=0.0, concurrency=1, parallelism=1,
                           blocksize=64 * KB, coalesce_threshold=0)
    t0 = coord.submit(TransferSpec.new(
        "ok-1", "src-ep", "data/t0", "dst-ep", "out/t0", tenant="alice",
        options=opts, n_files=1, nbytes=len(big_a)).to_json())
    t1 = coord.submit(TransferSpec.new(
        "stuck-1", "src-ep", "data/t1", "dst-only-a", "out/t1",
        tenant="bob", options=opts, n_files=1,
        nbytes=len(big_b)).to_json())
    assert src_conn.engaged.wait(30)

    caught: list = []

    def do_fail():
        try:
            coord.fail_site("a", timeout=60)
        except StrandedTasksError as e:
            caught.append(e)

    failer = threading.Thread(target=do_fail, daemon=True)
    failer.start()
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        if all(t._pause_req.is_set() or t._done.is_set()
               or t.status == t.PAUSED for t in (t0, t1)):
            break
        time.sleep(0.005)
    src_conn.release()
    failer.join(60)
    assert not failer.is_alive()

    assert caught, "StrandedTasksError was not raised"
    err = caught[0]
    assert err.moved == [("ok-1", "b")]
    assert err.stranded == ["stuck-1"]
    # the adoptable task completed on the peer despite the stranding
    task_b = coord.task("ok-1")
    assert task_b.wait(30) and task_b.status == task_b.SUCCEEDED
    assert dst_shared.store.get("out/t0/a.bin") == big_a
    # the stranded one was paused, not left streaming; any checkpoint
    # it made stays readable on the dead site's durable store (empty is
    # legitimate when the pause won the race before bytes landed), and
    # its charge accounting was not corrupted by the teardown
    assert t1.status == t1.PAUSED
    state = mgr_a.service.markers.load("stuck-1")
    assert isinstance(state["files"], dict)
    if t1.stats.bytes_done:  # bytes landed -> they must be resumable
        assert sum(ln for st in state["files"].values()
                   for _, ln in st.get("done", [])) == t1.stats.bytes_done
    assert t1.stats.actual_model_seconds >= 0
    coord.shutdown(wait=False)


def test_unresolvable_spec_is_rejected(tmp_path):
    clock = Clock(scale=0.0)
    coord, _ = _fabricated_sites(tmp_path, clock, depths=(0,))
    spec = TransferSpec.new("p4", "no-such-ep", "data", "dst-ep", "out")
    with pytest.raises(LookupError):
        coord.submit(spec)


# --------------------------------------------------------------------------
# the federated chaos scenario
# --------------------------------------------------------------------------
def test_run_federated_quick(tmp_path):
    runner = ScenarioRunner(str(tmp_path), clock=Clock(scale=0.0))
    res = runner.run_federated(n_sites=2, n_tasks=4, strict=True)
    assert res.ok
    assert res.moved, "the site failure must hand off at least one task"
    assert res.coordinator.metrics.failovers == 1


@pytest.mark.chaos
def test_run_federated_chaos(tmp_path):
    """Acceptance: multi-site fleet under an injected fault schedule,
    one site killed mid-flight — placement, byte-exact handoff (holes
    only), tenant/charge attribution, and third-party semantics all
    assert inside run_federated (strict)."""
    runner = ScenarioRunner(str(tmp_path), clock=Clock(scale=0.0))
    schedule = (FaultSchedule(seed=13)
                .transient(op="read", at=2, times=2)
                .rate_limit(op="send_batch", at=1, times=1,
                            retry_after=0.05))
    res = runner.run_federated(n_sites=3, n_tasks=6, schedule=schedule,
                               strict=True)
    assert res.ok
    assert res.moved
    assert schedule.events, "chaos was live, not a no-op"
    for r in res.results:
        assert r.task.status == r.task.SUCCEEDED
