"""§8 auto-tuned concurrency: the probing controller must complete the
transfer and explore beyond the starting concurrency."""

import os

from repro.core import Endpoint, TransferOptions, TransferService
from repro.core.clock import Clock
from repro.connectors import MemoryConnector, PosixConnector


def test_autotune_completes_and_probes(tmp_path):
    from repro.core import Credential, CredentialStore
    from repro.connectors import ObjectStoreConnector, make_cloud

    clock = Clock(scale=0.2)
    creds = CredentialStore()
    svc = TransferService(credential_store=creds,
                          marker_root=os.path.join(str(tmp_path), "m"),
                          clock=clock)
    src = PosixConnector(os.path.join(str(tmp_path), "src"))
    n_files = 96
    payload = os.urandom(512 * 1024)
    for i in range(n_files):
        p = os.path.join(str(tmp_path), "src", "d", f"f{i:03d}.bin")
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "wb") as f:
            f.write(payload)
    s3 = make_cloud("s3", clock=clock)
    dst = ObjectStoreConnector(s3, placement="cloud", clock=clock)
    creds.register(dst.name, Credential("s3-keypair", {}))
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "out", dst.name),
                      TransferOptions(concurrency=1, auto_tune=True,
                                      max_concurrency=8,
                                      startup_cost=0.0), sync=True)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    assert task.stats.files_done == n_files
    # the §8 probing loop must have explored upward from cc=1
    tune_events = [m for _, m in task.events if "auto-tune" in m]
    assert task.stats.effective_concurrency > 1 or tune_events, task.events
