"""Performance-model tests (paper §5): the regression machinery must
recover known model parameters from synthetic and emulated data."""

import math
import random

import pytest

from repro.core.perfmodel import (Advisor, PerfModel, Route, fit_linear,
                                  fit_perf_model, fit_startup_cost, pearson)

GB = 1e9


def test_fit_linear_exact():
    xs = [1, 2, 3, 4]
    ys = [3.0 + 0.5 * x for x in xs]
    a, b = fit_linear(xs, ys)
    assert math.isclose(a, 3.0, rel_tol=1e-9)
    assert math.isclose(b, 0.5, rel_tol=1e-9)


def test_fit_linear_recovers_under_noise():
    rng = random.Random(0)
    t0, alpha = 0.12, 17.0
    xs = [50, 100, 200, 400, 600, 800, 1000]  # paper's N values
    ys = [alpha + t0 * x + rng.gauss(0, 0.5) for x in xs]
    a, b = fit_linear(xs, ys)
    assert abs(b - t0) < 0.02
    assert abs(a - alpha) < 8.0


def test_pearson_bounds_and_signs():
    xs = list(range(10))
    assert pearson(xs, xs) == pytest.approx(1.0)
    assert pearson(xs, [-x for x in xs]) == pytest.approx(-1.0)
    assert abs(pearson(xs, [1, -1] * 5)) < 0.5
    assert pearson(xs, [5.0] * 10) == 0.0


def test_fit_perf_model_roundtrip():
    t0, R, S0, B = 0.25, 500e6, 2.3, 5 * GB
    xs = [50, 100, 200, 400, 800]
    ys = [x * t0 + B / R + S0 for x in xs]
    m = fit_perf_model("syn/upload", xs, ys, int(B), s0=S0)
    assert m.t0 == pytest.approx(t0, rel=1e-6)
    assert m.throughput == pytest.approx(R, rel=1e-6)
    assert m.rho > 0.999  # paper Table 1: ~0.99 everywhere
    # prediction at unseen N, with concurrency overlapping t0
    assert m.predict(600, int(B)) == pytest.approx(600 * t0 + B / R + S0, rel=1e-6)
    assert m.predict(600, int(B), concurrency=4) < m.predict(600, int(B))


def test_fit_startup_cost_eq6():
    s0, tu = 2.3, 1.7  # paper Fig. 12: S0 = 2.3 s
    sizes = [g * GB for g in range(1, 20, 2)]
    times = [s0 + tu * b / GB for b in sizes]
    got_s0, got_tu = fit_startup_cost(sizes, times)
    assert got_s0 == pytest.approx(s0, rel=1e-6)
    assert got_tu * GB == pytest.approx(tu, rel=1e-6)


def _mk_model(route, t0, R, s0=2.3, B=5 * GB):
    return PerfModel(route=route, t0=t0, alpha=B / R + s0, bytes_total=int(B),
                     s0=s0)


def test_advisor_prefers_cloud_placement_for_small_files():
    """Paper §8.1: near-storage placement wins for many-small-files."""
    adv = Advisor()
    adv.add(Route("conn-local", _mk_model("l", t0=0.45, R=420e6)))
    adv.add(Route("conn-cloud", _mk_model("c", t0=0.08, R=480e6)))
    route, cc, t = adv.best(n_files=1000, nbytes=int(1 * GB))
    assert route.name == "conn-cloud"
    assert cc >= 1
    # single big file: difference is marginal; both acceptable, but
    # prediction must monotonically improve with fewer files
    t_many = route.model.predict(1000, int(1 * GB))
    t_one = route.model.predict(1, int(1 * GB))
    assert t_one < t_many


def test_advisor_concurrency_ladder():
    adv = Advisor()
    adv.add(Route("r", _mk_model("r", t0=0.5, R=500e6), max_concurrency=16))
    route, cc, t = adv.best(n_files=1000, nbytes=int(1 * GB))
    assert cc == 16  # pure t0-dominated workload maxes out concurrency


def test_coalesce_advice_shrinks_file_count():
    adv = Advisor()
    adv.add(Route("r", _mk_model("r", t0=0.5, R=500e6)))
    n = adv.coalesce_advice(n_files=10_000, nbytes=int(5 * GB))
    assert 1 <= n < 10_000
    # with zero per-file overhead there is nothing to coalesce
    adv2 = Advisor()
    adv2.add(Route("r0", _mk_model("r0", t0=0.0, R=500e6)))
    assert adv2.coalesce_advice(64, int(1 * GB)) == 64


def test_degenerate_inputs_raise():
    with pytest.raises(ValueError):
        fit_linear([1], [2])
    with pytest.raises(ValueError):
        fit_linear([3, 3, 3], [1, 2, 3])


# ---------------------------------------------------------------------------
# Advisor edge cases — load-bearing now that the TransferManager consults
# the advisor on every routed submission
# ---------------------------------------------------------------------------
def test_best_with_zero_routes_raises():
    with pytest.raises(ValueError):
        Advisor().best(n_files=10, nbytes=1_000_000)


def test_best_survives_degenerate_fit():
    """A model fit on pure noise (rho/r^2 ~ 0) must still rank without
    NaNs or crashes — the manager calls best() on every submission."""
    xs = [10, 20, 40, 80, 160]
    ys = [5.0, 6.0, 5.0, 6.0, 5.0]  # no N-dependence at all
    m = fit_perf_model("noise/up", xs, ys, bytes_total=int(1 * GB))
    assert abs(m.rho) < 0.5
    assert m.r2 < 0.1
    adv = Advisor([Route("noisy", m)])
    route, cc, t = adv.best(n_files=500, nbytes=int(1 * GB))
    assert route.name == "noisy"
    assert cc >= 1
    assert math.isfinite(t) and t >= 0
    # coalesce helpers must also stay finite/sane on the same fit
    assert adv.coalesce_threshold() >= 0
    assert 1 <= adv.coalesce_advice(1000, int(1 * GB)) <= 1000


def test_best_with_zero_max_concurrency_route():
    adv = Advisor([Route("r", _mk_model("r", t0=0.1, R=100e6),
                         max_concurrency=0)])
    route, cc, t = adv.best(n_files=100, nbytes=int(1 * GB))
    assert cc == 1  # cc=1 is always rankable
    assert math.isfinite(t)


def test_coalesce_threshold_monotone_in_t0_and_rate():
    """Break-even size t0*R must grow with per-file overhead and with
    line rate, and degenerate fits must disable batching (0)."""
    R = 200e6
    thresholds = [Advisor([Route("r", _mk_model("r", t0=t0, R=R))])
                  .coalesce_threshold() for t0 in (0.0, 0.01, 0.1, 0.5)]
    assert thresholds[0] == 0  # no measurable overhead -> batching off
    assert thresholds == sorted(thresholds)
    assert thresholds[-1] > thresholds[1]
    t0 = 0.05
    by_rate = [Advisor([Route("r", _mk_model("r", t0=t0, R=r))])
               .coalesce_threshold() for r in (50e6, 200e6, 800e6)]
    assert by_rate == sorted(by_rate)
    # infinite implied throughput (alpha <= s0) cannot overflow int()
    degenerate = PerfModel(route="d", t0=0.1, alpha=1.0, bytes_total=10**9,
                           s0=2.0)
    assert not math.isfinite(degenerate.throughput) or \
        degenerate.throughput > 0
    assert Advisor([Route("d", degenerate)]).coalesce_threshold() == 0
