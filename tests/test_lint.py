"""Contract-linter tests: one seeded violation per rule (exact rule id
and file:line asserted), a clean fixture that must produce no findings,
the suppression/budget round-trip, and a repo-wide "the tree is clean"
gate mirroring the CI lane."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.lint.engine import (budget_violations, load_budget, run_lint,
                               write_budget)
from repro.lint.rules import RULES

REPO_ROOT = Path(__file__).resolve().parents[1]


def lint_tree(tmp_path: Path, files: dict) -> "LintReport":
    """Write ``files`` (repo-relative path -> source) under a temp root
    that mirrors the production layout, then lint it — so rule scoping
    (R001 allowlist, R002 transfer-stack prefixes, ...) applies exactly
    as it does on the real tree."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src), encoding="utf-8")
    return run_lint(tmp_path)


def hits(report, rule):
    return [(f.file, f.line) for f in report.findings if f.rule == rule]


# --------------------------------------------------------------------------
# seeded violations: exact rule + file:line
# --------------------------------------------------------------------------


def test_r001_wall_clock(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/core/thing.py": """\
        import time

        def poll():
            t0 = time.monotonic()
            return t0
        """})
    assert hits(report, "R001") == [("src/repro/core/thing.py", 4)]


def test_r001_aliased_and_from_imports(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/fed/thing.py": """\
        from time import monotonic as mono

        def poll():
            import time as _t
            _t.sleep(0.1)
            return mono()
        """})
    assert hits(report, "R001") == [("src/repro/fed/thing.py", 5),
                                    ("src/repro/fed/thing.py", 6)]


def test_r001_datetime_and_unseeded_random(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/svc/thing.py": """\
        import datetime
        import random

        def stamp():
            return datetime.datetime.now(), random.random()
        """})
    assert ("src/repro/svc/thing.py", 5) in hits(report, "R001")
    assert len(hits(report, "R001")) == 2  # both calls, same line


def test_r001_clock_py_is_allowlisted(tmp_path):
    src = """\
        import time

        def wall_now():
            return time.monotonic()
        """
    clean = lint_tree(tmp_path, {"src/repro/core/clock.py": src})
    assert hits(clean, "R001") == []
    # identical source anywhere else is a violation
    dirty = lint_tree(tmp_path / "b", {"src/repro/core/clock2.py": src})
    assert hits(dirty, "R001") == [("src/repro/core/clock2.py", 4)]


def test_r002_unbound_thread_and_pool(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/connectors/thing.py": """\
        import threading

        def spawn(fn, pool):
            threading.Thread(target=fn, daemon=True).start()
            pool.submit(fn, 1)
        """})
    assert hits(report, "R002") == [("src/repro/connectors/thing.py", 4),
                                    ("src/repro/connectors/thing.py", 5)]


def test_r002_bound_callables_pass(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/connectors/thing.py": """\
        import threading
        from ..core.clock import bind_charge_owner

        def spawn(fn, pool):
            threading.Thread(target=bind_charge_owner(fn)).start()
            run = bind_charge_owner(fn)
            pool.submit(run, 1)
        """})
    assert hits(report, "R002") == []


def test_r002_out_of_scope_tree_untouched(tmp_path):
    # sim/ harness threads are not charge-accounted — rule scoped out
    report = lint_tree(tmp_path, {"src/repro/sim/thing.py": """\
        import threading

        def spawn(fn):
            threading.Thread(target=fn).start()
        """})
    assert hits(report, "R002") == []


def test_r003_locked_call_without_lock(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/core/thing.py": """\
        class Q:
            def _pick_locked(self):
                return 1

            def pick(self):
                return self._pick_locked()

            def pick_safely(self):
                with self._lock:
                    return self._pick_locked()
        """})
    assert hits(report, "R003") == [("src/repro/core/thing.py", 6)]


def test_r003_sleep_under_lock(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/core/thing.py": """\
        class Q:
            def slow(self, clock, conn, session, path, ch):
                with self._lock:
                    clock.sleep(1.0)
                    conn.recv(session, path, ch)
        """})
    assert hits(report, "R003") == [("src/repro/core/thing.py", 4),
                                    ("src/repro/core/thing.py", 5)]


def test_r004_bare_raise_and_blind_swallow(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/core/thing.py": """\
        def bad():
            try:
                raise Exception("boom")
            except Exception:
                pass
        """})
    assert hits(report, "R004") == [("src/repro/core/thing.py", 3),
                                    ("src/repro/core/thing.py", 4)]


def test_r004_scoped_to_core(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/sim/thing.py": """\
        def tolerated():
            try:
                raise Exception("boom")
            except Exception:
                pass
        """})
    assert hits(report, "R004") == []


def test_r005_blocking_reachable_from_publish(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/svc/thing.py": """\
        class StatusBus:
            def publish(self, topic, data=None):
                self._fan_out(topic)

            def _fan_out(self, topic):
                self._cv.wait_for(lambda: True)
        """})
    assert hits(report, "R005") == [("src/repro/svc/thing.py", 6)]


def test_r005_nonblocking_publish_clean(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/svc/thing.py": """\
        class StatusBus:
            def publish(self, topic, data=None):
                with self._lock:
                    self._ring.append(topic)
                self._cv.notify_all()
        """})
    assert hits(report, "R005") == []


# --------------------------------------------------------------------------
# clean fixture: the idiomatic stack produces no findings
# --------------------------------------------------------------------------


def test_clean_fixture_no_false_positives(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/core/thing.py": """\
        import threading
        from .clock import Clock, bind_charge_owner, charge_to
        from .errors import TransientError

        class Worker:
            def __init__(self, clock):
                self.clock = clock
                self._lock = threading.Lock()

            def _pop_locked(self):
                return 1

            def run(self, task_id, pool, fn):
                with charge_to(task_id):
                    self.clock.sleep(0.5)
                with self._lock:
                    item = self._pop_locked()
                threading.Thread(target=bind_charge_owner(fn)).start()
                pool.submit(bind_charge_owner(fn), item)

            def fail(self):
                raise TransientError("routable")
        """})
    assert report.findings == [] and report.meta == []


# --------------------------------------------------------------------------
# suppressions + budget
# --------------------------------------------------------------------------

SUPPRESSED_SRC = """\
    import time

    def poll():
        return time.monotonic()  # lint: disable=R001(fixture: sanctioned wall read)
    """


def test_suppression_with_reason_closes_finding(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/core/thing.py": SUPPRESSED_SRC})
    assert report.findings == [] and report.meta == []
    assert [(f.rule, f.line, f.reason) for f in report.suppressed] == \
        [("R001", 4, "fixture: sanctioned wall read")]


def test_reasonless_suppression_is_r000(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/core/thing.py": """\
        import time

        def poll():
            return time.monotonic()  # lint: disable=R001
        """})
    # the disable still closes nothing: the R001 stays open AND the
    # reason-less marker is its own meta finding
    assert [(f.rule, f.line) for f in report.failing] == \
        [("R000", 4), ("R001", 4)]


def test_budget_round_trip_and_growth_fails(tmp_path):
    files = {"src/repro/core/thing.py": SUPPRESSED_SRC}
    report = lint_tree(tmp_path, files)
    budget_path = tmp_path / "lint-budget.json"
    write_budget(budget_path, report)
    budget = load_budget(budget_path)
    assert budget == {"src/repro/core/thing.py": {"R001": 1}}
    assert budget_violations(report, budget) == []

    # a second drive-by disable exceeds the blessed count (appended
    # lines keep the literal's indent so dedent still strips uniformly)
    grown = dict(files)
    grown["src/repro/core/thing.py"] += (
        "\n    def poll2():\n"
        "        return time.monotonic()"
        "  # lint: disable=R001(fixture: another one)\n")
    report2 = lint_tree(tmp_path / "b", grown)
    assert report2.findings == []  # suppressed line-by-line...
    over = budget_violations(report2, budget)
    assert len(over) == 1 and "exceed" in over[0]  # ...but over budget


def test_unused_suppression_reported(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/core/thing.py": """\
        def fine():
            return 1  # lint: disable=R001(stale: nothing to suppress)
        """})
    assert [(s.rule, s.line) for s in report.unused_suppressions] == \
        [("R001", 2)]


# --------------------------------------------------------------------------
# the real tree + the CI entry point
# --------------------------------------------------------------------------


def test_repo_tree_is_clean():
    report = run_lint(REPO_ROOT)
    assert report.failing == [], \
        [f.to_dict() for f in report.failing]
    # every committed suppression carries a reason (R000 covers the
    # absent case; this asserts the reasons survived the round trip)
    assert all(f.reason for f in report.suppressed)


def test_repo_suppressions_within_budget():
    report = run_lint(REPO_ROOT)
    budget = load_budget(REPO_ROOT / "lint-budget.json")
    assert budget_violations(report, budget) == []


def test_cli_check_exits_zero():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--check", "--json"],
        cwd=REPO_ROOT, capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["ok"] is True
    assert payload["findings"] == []


def test_cli_reports_seeded_violation(tmp_path):
    bad = tmp_path / "src" / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\nt = time.time()\n", encoding="utf-8")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--check", "--json",
         "--root", str(tmp_path)],
        cwd=REPO_ROOT, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO_ROOT / "src"),
             "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    assert [(f["rule"], f["file"], f["line"])
            for f in payload["findings"]] == \
        [("R001", "src/repro/core/bad.py", 2)]


def test_rules_registry_complete():
    assert set(RULES) == {"R001", "R002", "R003", "R004", "R005",
                          "R006"}
    for rule, (title, check) in RULES.items():
        assert title and callable(check)
