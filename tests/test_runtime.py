"""Training-loop fault tolerance + sharding-rule unit tests."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.connectors import PosixConnector
from repro.ckpt import CheckpointManager
from repro.data import DataPipelineConfig, ShardedTokenDataset, synthetic_corpus
from repro.models.registry import build
from repro.optim import OptimizerConfig, adamw_init, adamw_update
from repro.runtime.train import TrainLoopConfig, run_training
from repro.sharding.rules import (AxisRules, axis_rules, batch_spec,
                                  param_specs)


def _setup(tmp_path, steps=12):
    cfg = get_config("qwen1.5-0.5b").scaled_down(
        n_layers=2, d_model=64, d_ff=128, vocab_size=256, n_heads=2,
        n_kv_heads=2, d_head=32)
    api = build(cfg)
    store = PosixConnector(str(tmp_path))
    synthetic_corpus(store, "corpus", vocab_size=cfg.vocab_size, seq_len=32,
                     n_records=64, records_per_shard=16)
    ds = ShardedTokenDataset(store, "corpus",
                             DataPipelineConfig(seq_len=32, batch_size=4))
    opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=steps,
                          state_dtype="float32")
    return api, store, ds, opt


def test_loss_decreases(tmp_path):
    api, store, ds, opt = _setup(tmp_path, steps=30)
    loop = TrainLoopConfig(total_steps=30, log_every=5, ckpt_every=1000)
    res = run_training(api, opt, loop, ds)
    first = res.losses[0][1]
    last = res.losses[-1][1]
    assert last < first, (first, last)


def test_preemption_restart_resumes(tmp_path):
    """Kill training mid-run; the restart must resume from the latest
    checkpoint (step AND data cursor), not from scratch."""
    api, store, ds, opt = _setup(tmp_path, steps=12)
    mgr = CheckpointManager(store, "ckpt")
    loop = TrainLoopConfig(total_steps=12, log_every=4, ckpt_every=4,
                           fail_at_step=9)
    with pytest.raises(RuntimeError, match="injected failure"):
        run_training(api, opt, loop, ds, ckpt_mgr=mgr)

    # fresh pipeline objects, as after a real preemption
    ds2 = ShardedTokenDataset(store, "corpus",
                              DataPipelineConfig(seq_len=32, batch_size=4))
    mgr2 = CheckpointManager(store, "ckpt")
    loop2 = TrainLoopConfig(total_steps=12, log_every=4, ckpt_every=4)
    res = run_training(api, opt, loop2, ds2, ckpt_mgr=mgr2)
    assert res.restored_from == 8
    assert res.steps_run == 4  # only steps 9..12 re-run
    # data cursor resumed past the consumed batches
    assert ds2.state()["record"] > 0 or ds2.state()["shard"] > 0


def test_adamw_converges_quadratic():
    opt = OptimizerConfig(peak_lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, state_dtype="float32")
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params, opt)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(params, g, state, opt)
    assert float(loss(params)) < 1e-2


def test_param_specs_match_rules():
    cfg = get_config("qwen1.5-0.5b").scaled_down()
    api = build(cfg)
    shapes = api.abstract_params()
    rules = AxisRules({"fsdp": ("data",), "model": ("model",),
                       "expert": ("model",)})
    with axis_rules(rules):
        specs = param_specs(shapes)
    # embed table: (V, d) -> vocab over model, d over data, behind the
    # stacked-blocks convention only for blocks/*
    from jax.sharding import PartitionSpec as P
    assert specs["embed"]["table"] == P("model", "data")
    wq = specs["blocks"]["layers"][0]["attn"]["wq"]["w"]
    assert wq == P(None, "data", "model")  # stacked dim unsharded
    norm = specs["blocks"]["layers"][0]["norm1"]["scale"]
    assert norm == P()


def test_param_specs_drop_nondividing_axes():
    cfg = get_config("whisper-medium")  # vocab 51865: not 16-divisible
    api = build(cfg)
    shapes = api.abstract_params()

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    rules = AxisRules({"fsdp": ("data",), "model": ("model",)},
                      mesh=FakeMesh())
    with axis_rules(rules):
        specs = param_specs(shapes)
    from jax.sharding import PartitionSpec as P
    assert specs["embed"]["table"][0] is None  # vocab not divisible
    assert specs["embed"]["table"][1] == "data"


def test_batch_spec_divisibility():
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P
    assert batch_spec(8, mesh) == P("data")

    class M:
        shape = {"pod": 2, "data": 16}

    assert batch_spec(256, M()) == P(("pod", "data"))
    assert batch_spec(16, M()) == P("data")
    assert batch_spec(1, M()) == P(None)
