"""Hypothesis property tests over the connector/transfer invariants."""

import os

import pytest

pytest.importorskip("hypothesis")  # container may lack it
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.connector import ByteRange
from repro.core.integrity import checksum_bytes, hasher
from repro.core.perfmodel import fit_linear, pearson
from repro.core.transfer import _holes, _merge_ranges
from repro.connectors.memory import BlobDict

RANGES = st.lists(
    st.tuples(st.integers(0, 1 << 20), st.integers(1, 1 << 16)), max_size=24)


@given(RANGES)
def test_merge_ranges_invariants(pairs):
    merged = _merge_ranges([[o, l] for o, l in pairs])
    # sorted, non-overlapping, non-adjacent
    for (o1, l1), (o2, l2) in zip(merged, merged[1:]):
        assert o1 + l1 < o2
    # coverage is preserved
    want = set()
    for o, l in pairs:
        want.add(o)
        want.add(o + l - 1)
    for point in want:
        covered_in = any(o <= point < o + l for o, l in pairs)
        covered_out = any(o <= point < o + l for o, l in merged)
        assert covered_in == covered_out


@given(st.integers(1, 1 << 20), RANGES)
def test_holes_partition_the_file(size, pairs):
    done = [[o, min(l, max(0, size - o))] for o, l in pairs if o < size]
    done = [d for d in done if d[1] > 0]
    holes = _holes(size, done)
    # holes and done together tile [0, size) exactly, without overlap
    covered = sorted([(o, l) for o, l in _merge_ranges(done)] +
                     [(h.offset, h.length) for h in holes])
    at = 0
    for o, l in covered:
        assert o == at
        at = o + l
    assert at == size


@given(st.binary(max_size=4096), st.sampled_from(
    ["sha256", "md5", "crc32", "fletcher64"]))
def test_checksum_deterministic_and_incremental(data, alg):
    whole = checksum_bytes(data, alg)
    h = hasher(alg)
    third = max(1, len(data) // 3)
    for i in range(0, len(data), third):
        h.update(data[i:i + third])
    assert h.hexdigest() == whole


@given(st.binary(min_size=1, max_size=2048), st.binary(min_size=1, max_size=2048))
def test_checksum_collision_resistance_smoke(a, b):
    if a != b:
        assert checksum_bytes(a, "sha256") != checksum_bytes(b, "sha256")


@given(st.binary(max_size=8192), st.integers(1, 64),
       st.randoms(use_true_random=False))
def test_blobdict_out_of_order_range_assembly(payload, nblocks, rnd):
    """Out-of-order positional writes (OOO GridFTP blocks) must
    reassemble to the exact original object."""
    store = BlobDict()
    n = len(payload)
    blocks = []
    step = max(1, n // nblocks)
    for off in range(0, n, step):
        blocks.append((off, payload[off:off + step]))
    rnd.shuffle(blocks)
    for off, data in blocks:
        store.put_range("k", off, data)
    if n:
        assert store.get("k") == payload


@given(st.lists(st.floats(-1e6, 1e6), min_size=3, max_size=50, unique=True),
       st.floats(-100, 100), st.floats(-1e3, 1e3))
@settings(suppress_health_check=[HealthCheck.filter_too_much])
def test_fit_linear_is_exact_on_linear_data(xs, beta, alpha):
    ys = [alpha + beta * x for x in xs]
    a, b = fit_linear(xs, ys)
    scale = max(1.0, abs(alpha), abs(beta))
    assert abs(a - alpha) / scale < 1e-3
    assert abs(b - beta) / scale < 1e-3


@given(st.lists(st.tuples(st.floats(-1e3, 1e3), st.floats(-1e3, 1e3)),
                min_size=2, max_size=64))
def test_pearson_in_unit_interval(pairs):
    xs = [p[0] for p in pairs]
    ys = [p[1] for p in pairs]
    rho = pearson(xs, ys)
    assert -1.0 - 1e-9 <= rho <= 1.0 + 1e-9
