"""Connector-interface conformance tests (paper §3 semantics), run
against every implementation through the same harness."""

import os

import pytest

from repro.core import Credential, NotFound, checksum_bytes
from repro.core.clock import Clock
from repro.core.connector import iter_files
from repro.connectors import (MemoryConnector, ObjectStoreConnector,
                              PosixConnector, make_cloud)
from repro.connectors.cloud import NativeClient


def _mk_posix(tmp_path):
    return PosixConnector(os.path.join(str(tmp_path), "posix")), None


def _mk_memory(tmp_path):
    return MemoryConnector(), None


def _mk_s3_local(tmp_path):
    clock = Clock(scale=0.0)
    storage = make_cloud("s3", clock=clock)
    cred = Credential("s3-keypair", {"access_key": "AK", "secret": "SK"})
    return ObjectStoreConnector(storage, placement="local", clock=clock), cred


def _mk_drive_cloud(tmp_path):
    clock = Clock(scale=0.0)
    storage = make_cloud("drive", clock=clock, quota_rate=10_000,
                         quota_burst=100_000, consistency_delay=0.0)
    cred = Credential("oauth2-token", {"token": "ya29.x"})
    return ObjectStoreConnector(storage, placement="cloud", clock=clock), cred


FACTORIES = {
    "posix": _mk_posix,
    "memory": _mk_memory,
    "s3-local": _mk_s3_local,
    "drive-cloud": _mk_drive_cloud,
}


class SinkChannel:
    """Collects Send output (test-side AppChannel)."""

    def __init__(self, blocksize=7_001, concurrency=3):
        self.blocks = {}
        self.bs = blocksize
        self.cc = concurrency
        self._claim = 0
        self._size = None
        import threading
        self._lock = threading.Lock()

    def set_size(self, size):
        self._size = size

    def write(self, offset, data):
        with self._lock:
            self.blocks[offset] = data

    def read(self, offset, length):
        raise NotImplementedError

    def get_concurrency(self):
        return self.cc

    def get_blocksize(self):
        return self.bs

    def get_read_range(self):
        from repro.core.connector import ByteRange
        with self._lock:
            if self._size is not None and self._claim >= self._size:
                return None
            ln = self.bs if self._size is None else min(self.bs, self._size - self._claim)
            rng = ByteRange(self._claim, ln)
            self._claim += ln
            return rng

    def bytes_written(self, offset, length):
        pass

    def finished(self, error=None):
        self.error = error

    def data(self):
        return b"".join(self.blocks[o] for o in sorted(self.blocks))


class SourceChannel:
    """Feeds Recv input (test-side AppChannel)."""

    def __init__(self, payload: bytes, blocksize=5_003, concurrency=2):
        self.payload = payload
        self.bs = blocksize
        self.cc = concurrency
        self._claim = 0
        self.written = []
        import threading
        self._lock = threading.Lock()

    def write(self, offset, data):
        raise NotImplementedError

    def read(self, offset, length):
        return self.payload[offset:offset + length]

    def get_concurrency(self):
        return self.cc

    def get_blocksize(self):
        return self.bs

    def get_read_range(self):
        from repro.core.connector import ByteRange
        with self._lock:
            if self._claim >= len(self.payload):
                return None
            ln = min(self.bs, len(self.payload) - self._claim)
            rng = ByteRange(self._claim, ln)
            self._claim += ln
            return rng

    def bytes_written(self, offset, length):
        self.written.append((offset, length))

    def finished(self, error=None):
        pass


@pytest.fixture(params=sorted(FACTORIES))
def conn(request, tmp_path):
    connector, cred = FACTORIES[request.param](tmp_path)
    session = connector.start(cred)
    yield connector, session
    connector.destroy(session)


def test_roundtrip(conn):
    connector, session = conn
    payload = bytes(range(256)) * 1000 + b"tail"
    connector.recv(session, "a/b/file.bin", SourceChannel(payload))
    info = connector.stat(session, "a/b/file.bin")
    assert info.size == len(payload)
    sink = SinkChannel()
    connector.send(session, "a/b/file.bin", sink)
    assert sink.data() == payload


def test_stat_missing_raises(conn):
    connector, session = conn
    with pytest.raises(NotFound):
        connector.stat(session, "no/such/object")


def test_listdir_and_recursive_expand(conn):
    connector, session = conn
    for name in ("d/x.bin", "d/sub/y.bin", "d/sub/z.bin"):
        connector.recv(session, name, SourceChannel(b"payload-" + name.encode()))
    names = {s.name for s in connector.listdir(session, "d")}
    assert any(n.endswith("x.bin") for n in names)
    files = sorted(fi.name for fi in iter_files(connector, session, "d"))
    assert len(files) == 3
    assert any(f.endswith("y.bin") for f in files)


def test_delete_and_rename(conn):
    connector, session = conn
    connector.recv(session, "f1", SourceChannel(b"abc123"))
    connector.command(session, "rename", "f1", to="f2")
    assert connector.stat(session, "f2").size == 6
    connector.command(session, "delete", "f2")
    with pytest.raises(NotFound):
        connector.stat(session, "f2")


def test_server_side_checksum(conn):
    connector, session = conn
    payload = b"integrity" * 4096
    connector.recv(session, "c.bin", SourceChannel(payload))
    assert connector.checksum(session, "c.bin", "sha256") == \
        checksum_bytes(payload, "sha256")


def test_posix_path_escape_rejected(tmp_path):
    connector, _ = _mk_posix(tmp_path)
    session = connector.start(None)
    from repro.core.errors import PermanentError
    with pytest.raises(PermanentError):
        connector.stat(session, "../../etc/passwd")


def test_cloud_requires_credential(tmp_path):
    clock = Clock(scale=0.0)
    storage = make_cloud("s3", clock=clock)
    connector = ObjectStoreConnector(storage, placement="local", clock=clock)
    from repro.core.errors import AuthError
    with pytest.raises(AuthError):
        connector.start(None)
    with pytest.raises(AuthError):
        connector.start(Credential("oauth2-token", {}))


def test_native_client_roundtrip(tmp_path):
    clock = Clock(scale=0.0)
    storage = make_cloud("gcs", clock=clock)
    client = NativeClient(storage, clock=clock)
    client.login()
    client.upload_bytes(b"hello cloud", "k1")
    assert client.download_bytes("k1") == b"hello cloud"


# --------------------------------------------------------------------------
# model-deterministic mtimes (contract R001: no wall clock in storage)
# --------------------------------------------------------------------------


def _signature_run(seed_payloads):
    """One fresh clocked memory store, the same scripted write sequence:
    returns the final {key: (size, mtime)} stat signature."""
    clock = Clock(scale=0.0)
    connector = MemoryConnector(clock=clock)
    session = connector.start(None)
    for key, payload, advance in seed_payloads:
        clock.sleep(advance)
        connector.recv(session, key, SourceChannel(payload))
    return {k: (connector.stat(session, k).size,
                connector.stat(session, k).mtime)
            for k, _, _ in seed_payloads}


def test_memory_mtimes_model_deterministic():
    """Same-seed runs must produce byte-identical (size, mtime)
    signatures — the replica catalog's staleness check and the marker
    journal's src_sig guard depend on it.  A wall-clock stamp (the old
    behaviour) makes every run unique."""
    script = [("a/x.bin", b"x" * 512, 0.25),
              ("a/y.bin", b"y" * 2048, 1.5),
              ("b/z.bin", b"z" * 64, 0.0)]
    assert _signature_run(script) == _signature_run(script)


def test_memory_mtime_tracks_model_clock():
    clock = Clock(scale=0.0)
    store = MemoryConnector(clock=clock).store
    store.put("k", b"v1")
    first = store.mtime("k")
    clock.sleep(3.0)
    store.put("k", b"v2")  # same size — only mtime can signal the change
    assert store.mtime("k") >= 3.0 > first


def test_memory_mtime_strictly_increases_within_an_instant():
    """Two writes in the same model instant (zero-latency store) must
    still get distinct, ordered stamps, so a same-size rewrite is never
    invisible to the (size, mtime) staleness check."""
    for clock in (Clock(scale=0.0), None):  # injected clock and fallback
        store = MemoryConnector(clock=clock).store
        store.put("k", b"same-size")
        first = store.mtime("k")
        store.put("k", b"same-size")
        assert store.mtime("k") > first
