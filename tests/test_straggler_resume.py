"""Straggler mitigation (hedged reads) + randomized restart-marker
resume property."""

import os
import threading

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # container may lack it
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Endpoint, TransferOptions, TransferService
from repro.core.connector import Session
from repro.connectors import MemoryConnector, PosixConnector
from repro.data import DataPipelineConfig, ShardedTokenDataset, synthetic_corpus


class SlowOnceConnector(MemoryConnector):
    """First read of each shard stalls; the hedge must win."""

    def __init__(self, stall: float = 0.5):
        super().__init__()
        self.stall = stall
        self._seen: set = set()
        self._lock = threading.Lock()

    def send(self, session, path, channel):
        import time
        with self._lock:
            first = path not in self._seen
            self._seen.add(path)
        if first:
            time.sleep(self.stall)
        super().send(session, path, channel)


def test_hedged_reads_fire_on_stragglers():
    conn = SlowOnceConnector(stall=0.25)
    synthetic_corpus(conn, "corpus", vocab_size=64, seq_len=16,
                     n_records=64, records_per_shard=8)
    replica = MemoryConnector(conn.store)  # same blobs, fast path
    cfg = DataPipelineConfig(seq_len=16, batch_size=2, hedge_factor=2.0,
                             hedge_min_samples=4)
    ds = ShardedTokenDataset(conn, "corpus", cfg, replica=replica)
    for _, b in zip(range(24), ds.batches()):
        assert b["tokens"].shape == (2, 16)
    # at least one hedged read should have fired on a stalled shard
    assert ds.hedged_reads >= 1


@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 63), st.integers(1, 32)),
                max_size=6),
       st.integers(0, 2**31 - 1))
def test_random_partial_progress_resumes_exact(done_ranges, seed):
    """Whatever partial state a crashed transfer left behind (any set of
    completed ranges recorded in the restart marker), resuming completes
    the file byte-exact."""
    import tempfile
    rng = np.random.default_rng(seed)
    payload = rng.bytes(64 * 1024)
    with tempfile.TemporaryDirectory() as tmp:
        src = PosixConnector(os.path.join(tmp, "src"))
        p = os.path.join(tmp, "src", "f.bin")
        with open(p, "wb") as f:
            f.write(payload)
        dst = MemoryConnector()
        svc = TransferService(marker_root=os.path.join(tmp, "m"))
        # fabricate prior progress: these ranges were "already sent"
        done = [[off * 1024, ln * 1024] for off, ln in done_ranges]
        done = [[o, min(l, len(payload) - o)] for o, l in done
                if o < len(payload)]
        state = {"files": {"f.bin": {"done": done, "complete": False}}}
        svc.markers.save("prop-test", state)
        for o, l in done:
            dst.store.put_range("f.bin", o, payload[o:o + l])
        task = svc.submit(Endpoint(src, "f.bin"), Endpoint(dst, "f.bin"),
                          TransferOptions(blocksize=7 * 1024),
                          task_id="prop-test", sync=True)
        assert task.status == task.SUCCEEDED
        assert dst.store.get("f.bin") == payload
