"""TransferManager control-plane behaviour: fleet scheduling, caps,
tenant fairness, lifecycle (pause/resume/cancel), session sharing, and
Advisor-driven route selection (paper §2.1-§2.2: the managed third-party
orchestrator, scaled out)."""

import os
import threading
import time

import pytest

from repro.connectors import MemoryConnector, PosixConnector
from repro.core import (Advisor, Credential, CredentialStore, Endpoint,
                        FaultSchedule, PerfModel, Route, RouteCandidate,
                        TransferManager, TransferOptions)
from repro.core.clock import Clock
from repro.sim import ScenarioRunner

MB = 1024 * 1024
GB = 1e9


def make_manager(tmp_path, creds=None, **kw):
    creds = creds or CredentialStore()
    kw.setdefault("max_workers", 4)
    kw.setdefault("per_endpoint_cap", 2)
    return TransferManager(credential_store=creds,
                           marker_root=os.path.join(str(tmp_path), "markers"),
                           clock=Clock(scale=0.0), **kw)


def seeded_posix(tmp_path, files):
    root = os.path.join(str(tmp_path), "srcroot")
    conn = PosixConnector(root)
    for name, payload in files.items():
        p = os.path.join(root, name)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "wb") as f:
            f.write(payload)
    return conn


class OpCountingMemory(MemoryConnector):
    """Counts concurrently-active data-plane ops — independent evidence
    that the manager's per-endpoint cap holds at the connector."""

    def __init__(self):
        super().__init__()
        self._lock = threading.Lock()
        self.active = 0
        self.peak = 0
        self.starts = 0

    def _enter(self):
        with self._lock:
            self.active += 1
            self.peak = max(self.peak, self.active)

    def _exit(self):
        with self._lock:
            self.active -= 1

    def start(self, credential=None):
        with self._lock:
            self.starts += 1
        return super().start(credential)

    def recv(self, session, path, channel):
        self._enter()
        try:
            return super().recv(session, path, channel)
        finally:
            self._exit()

    def recv_batch(self, session, paths, channel_factory):
        self._enter()
        try:
            return super().recv_batch(session, paths, channel_factory)
        finally:
            self._exit()


# --------------------------------------------------------------------------
# acceptance: a chaos fleet across tenants
# --------------------------------------------------------------------------
def test_fleet_chaos_pause_resume_byte_exact(tmp_path):
    """>= 4 concurrent tasks across 2 tenants under an injected
    FaultSchedule, with a pause->resume mid-run: every task completes
    byte-exact, caps hold, and markers end cleared."""
    runner = ScenarioRunner(str(tmp_path), clock=Clock(scale=0.0))
    schedule = (FaultSchedule(seed=11)
                .transient(op="recv", at=1, times=1)
                .transient(op="read", at=3, times=1))
    res = runner.run_multi(n_tasks=5, tenants=("alice", "bob"),
                           trees=("mixed", "many-small"),
                           route="posix->memory", schedule=schedule,
                           max_workers=3, per_endpoint_cap=2,
                           pause_resume=(1, 3), seed=7, strict=True)
    assert res.ok
    assert len(res.tasks) == 5
    for task in res.tasks:
        assert task.status == task.SUCCEEDED, (task.task_id, task.events[-3:])
    # the schedule actually fired (chaos was live, not a no-op)
    assert schedule.events
    m = res.manager.metrics
    assert m.peak_active <= 3
    assert all(peak <= 2 for peak in m.peak_by_endpoint.values())
    assert set(m.dispatches_by_tenant) == {"alice", "bob"}


def test_endpoint_cap_holds_at_connector(tmp_path):
    """Cap evidence measured at the destination connector itself: with
    per-task concurrency 1, concurrently-active recv ops == concurrently
    active tasks on that endpoint."""
    files = {f"d/f{i}.bin": os.urandom(64 * 1024) for i in range(6)}
    src = seeded_posix(tmp_path, files)
    dst = OpCountingMemory()
    creds = CredentialStore()
    mgr = make_manager(tmp_path, creds, max_workers=4, per_endpoint_cap=2)
    opts = TransferOptions(startup_cost=0.0, concurrency=1,
                           coalesce_threshold=0)
    tasks = [mgr.submit(Endpoint(src, "d", f"src{i}"),
                        Endpoint(dst, f"out{i}", "the-dst"), opts,
                        task_id=f"cap{i}")
             for i in range(6)]
    assert mgr.wait_all(timeout=60)
    for t in tasks:
        assert t.status == t.SUCCEEDED
    assert mgr.metrics.peak_by_endpoint["the-dst"] <= 2
    assert dst.peak <= 2
    mgr.shutdown()


def test_pause_resume_no_resend_of_completed_ranges(tmp_path):
    """Pause mid-transfer; the resume must move only the holes the
    MarkerStore says are missing (paper §3 'holey' restart, driven
    through the control plane)."""
    payload = os.urandom(8 * MB)
    src = seeded_posix(tmp_path, {"big.bin": payload})

    gate = threading.Event()      # set => reads flow
    reached = threading.Event()   # first 2 MB landed
    seen = {"n": 0}
    lock = threading.Lock()

    class GateMemory(MemoryConnector):
        def recv(self, session, path, channel):
            outer = self

            class Wrap:
                def __getattr__(w, k):
                    return getattr(channel, k)

                def read(w, offset, length):
                    with lock:
                        seen["n"] += length
                        hit = seen["n"] >= 2 * MB
                    if hit:
                        reached.set()
                        gate.wait(timeout=30)
                    return channel.read(offset, length)

            super().recv(session, path, Wrap())

    dst = GateMemory()
    mgr = make_manager(tmp_path)
    opts = TransferOptions(startup_cost=0.0, blocksize=256 * 1024,
                           parallelism=1, concurrency=1)
    task = mgr.submit(Endpoint(src, "big.bin"), Endpoint(dst, "big.bin"),
                      opts, task_id="pr1")
    assert reached.wait(30), "transfer never reached the gate"
    assert mgr.pause("pr1")
    gate.set()
    assert task.wait_idle(30)
    assert task.status == task.PAUSED

    state = mgr.service.markers.load("pr1")
    done_ranges = state["files"]["big.bin"]["done"]
    done_bytes = sum(length for _, length in done_ranges)
    assert 0 < done_bytes < len(payload)
    assert not state["files"]["big.bin"].get("complete")

    sent = {"n": 0}
    orig = PosixConnector.send

    def counting_send(self, session, path, channel):
        class Wrap:
            def __getattr__(w, k):
                return getattr(channel, k)

            def write(w, offset, data):
                sent["n"] += len(data)
                channel.write(offset, data)

        return orig(self, session, path, Wrap())

    PosixConnector.send = counting_send
    try:
        assert mgr.resume("pr1")
        assert task.wait(60)
    finally:
        PosixConnector.send = orig
    assert task.status == task.SUCCEEDED, task.events[-5:]
    # only the holes crossed the wire on resume
    assert sent["n"] == len(payload) - done_bytes
    dst.start(None)
    assert dst.store.get("big.bin") == payload
    assert mgr.service.markers.load("pr1") == {"files": {}}
    assert task.stats.resumes == 1
    mgr.shutdown()


def test_pause_lands_after_sender_claimed_everything(tmp_path):
    """A pause request must interrupt a file even when the send side has
    already claimed (and pushed) every block range.  The sender has no
    backpressure, so on an unloaded run it rips through the whole claim
    queue in milliseconds; the claim-side abort gate then can never fire
    again, and before the receive side also checked the abort hook the
    transfer ran to SUCCEEDED despite pause() returning True."""
    payload = os.urandom(8 * MB)

    sent_done = threading.Event()  # sender pushed every block

    class DoneSignalPosix(PosixConnector):
        def send(self, session, path, channel):
            try:
                super().send(session, path, channel)
            finally:
                sent_done.set()

    root = os.path.join(str(tmp_path), "srcroot")
    src = DoneSignalPosix(root)
    p = os.path.join(root, "big.bin")
    os.makedirs(root, exist_ok=True)
    with open(p, "wb") as f:
        f.write(payload)

    gate = threading.Event()      # set => receive-side reads flow
    reached = threading.Event()   # first 2 MB landed
    seen = {"n": 0}
    lock = threading.Lock()

    class GateMemory(MemoryConnector):
        def recv(self, session, path, channel):
            class Wrap:
                def __getattr__(w, k):
                    return getattr(channel, k)

                def read(w, offset, length):
                    with lock:
                        seen["n"] += length
                        hit = seen["n"] >= 2 * MB
                    if hit:
                        reached.set()
                        gate.wait(timeout=30)
                    return channel.read(offset, length)

            super().recv(session, path, Wrap())

    dst = GateMemory()
    mgr = make_manager(tmp_path)
    opts = TransferOptions(startup_cost=0.0, blocksize=256 * 1024,
                           parallelism=1, concurrency=1)
    task = mgr.submit(Endpoint(src, "big.bin"), Endpoint(dst, "big.bin"),
                      opts, task_id="late-pause")
    assert reached.wait(30), "transfer never reached the gate"
    # the receiver is gated, so the unthrottled sender drains its claim
    # queue completely — THEN the pause arrives, deterministically after
    # the last claim (the racy ordering the flaky version only hit under
    # machine load)
    assert sent_done.wait(30), "sender never finished claiming"
    assert mgr.pause("late-pause")
    gate.set()
    assert task.wait_idle(30)
    assert task.status == task.PAUSED, task.events[-5:]

    state = mgr.service.markers.load("late-pause")
    done_ranges = state["files"]["big.bin"]["done"]
    done_bytes = sum(length for _, length in done_ranges)
    assert 0 < done_bytes < len(payload)

    # resume closes only the holes
    assert mgr.resume("late-pause")
    assert task.wait(60)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    dst.start(None)
    assert dst.store.get("big.bin") == payload
    mgr.shutdown()


def test_resume_races_inflight_pause(tmp_path):
    """resume() fired immediately after pause() — before the run loop
    drains — must still re-queue the task, never wedge it in PAUSED."""
    payload = os.urandom(4 * MB)
    src = seeded_posix(tmp_path, {"big.bin": payload})

    gate = threading.Event()
    reached = threading.Event()
    seen = {"n": 0}
    lock = threading.Lock()

    class GateMemory(MemoryConnector):
        def recv(self, session, path, channel):
            outer = self

            class Wrap:
                def __getattr__(w, k):
                    return getattr(channel, k)

                def read(w, offset, length):
                    with lock:
                        seen["n"] += length
                        hit = seen["n"] >= MB
                    if hit:
                        reached.set()
                        gate.wait(timeout=30)
                    return channel.read(offset, length)

            super().recv(session, path, Wrap())

    dst = GateMemory()
    mgr = make_manager(tmp_path)
    opts = TransferOptions(startup_cost=0.0, blocksize=256 * 1024,
                           parallelism=1, concurrency=1)
    task = mgr.submit(Endpoint(src, "big.bin"), Endpoint(dst, "big.bin"),
                      opts, task_id="race1")
    assert reached.wait(30)
    assert mgr.pause("race1")
    # no wait_idle: the pause is still draining when we resume
    assert mgr.resume("race1")
    gate.set()
    assert task.wait(60)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    dst.start(None)
    assert dst.store.get("big.bin") == payload
    mgr.shutdown()


def test_pause_queued_and_cancel(tmp_path):
    files = {f"d/f{i}.bin": os.urandom(32 * 1024) for i in range(3)}
    src = seeded_posix(tmp_path, files)
    dst = MemoryConnector()
    mgr = make_manager(tmp_path, max_workers=1)

    gate = threading.Event()
    reached = threading.Event()

    class SlowSrc(PosixConnector):
        def send(self, session, path, channel):
            reached.set()
            gate.wait(timeout=30)
            return super().send(session, path, channel)

    slow = SlowSrc(src.root)
    opts = TransferOptions(startup_cost=0.0, coalesce_threshold=0)
    t_busy = mgr.submit(Endpoint(slow, "d"), Endpoint(dst, "busy"), opts,
                        task_id="busy")
    t_queued = mgr.submit(Endpoint(src, "d"), Endpoint(dst, "q"), opts,
                          task_id="queued")
    t_cancel = mgr.submit(Endpoint(src, "d"), Endpoint(dst, "c"), opts,
                          task_id="doomed")
    assert reached.wait(10)
    # one-slot manager: the other two are still queued -> deterministic
    assert mgr.pause("queued")
    assert t_queued.status == t_queued.PAUSED
    assert mgr.cancel("doomed")
    assert t_cancel.status == t_cancel.CANCELLED
    gate.set()
    assert t_busy.wait(60)
    # paused task does not run until resumed
    assert t_queued.status == t_queued.PAUSED
    # wait_all must not wedge on (or wait for) the paused task
    assert mgr.wait_all(timeout=10)
    assert t_queued.status == t_queued.PAUSED
    assert mgr.resume("queued")
    assert t_queued.wait(60)
    assert t_queued.status == t_queued.SUCCEEDED
    assert mgr.wait_all(timeout=60)
    dst.start(None)
    assert dst.store.get("q/f0.bin") == files["d/f0.bin"]
    # cancelled before running: nothing landed
    assert not any(k.startswith("c/") for k in dst.store.keys())
    mgr.shutdown()


def test_tenant_fair_round_robin(tmp_path):
    """A tenant flooding the queue cannot starve another: dispatch order
    alternates tenants even when one submitted everything first."""
    files = {"d/f.bin": os.urandom(16 * 1024)}
    src = seeded_posix(tmp_path, files)
    dst = MemoryConnector()
    creds = CredentialStore()
    creds.register("src-alice", Credential("local-user",
                                           {"identity": "alice"}))
    creds.register("src-bob", Credential("local-user", {"identity": "bob"}))
    mgr = make_manager(tmp_path, creds, max_workers=1,
                       per_endpoint_cap=None)

    gate = threading.Event()

    class Gated(PosixConnector):
        def send(self, session, path, channel):
            gate.wait(timeout=30)
            return super().send(session, path, channel)

    gated = Gated(src.root)
    opts = TransferOptions(startup_cost=0.0)
    # alice floods 4 tasks, then bob submits 2
    for i in range(4):
        mgr.submit(Endpoint(gated, "d", "src-alice"),
                   Endpoint(dst, f"a{i}"), opts, task_id=f"a{i}")
    for i in range(2):
        mgr.submit(Endpoint(gated, "d", "src-bob"),
                   Endpoint(dst, f"b{i}"), opts, task_id=f"b{i}")
    gate.set()
    assert mgr.wait_all(timeout=60)
    order = [tenant for tenant, _ in mgr.metrics.dispatch_log]
    # bob's first task is dispatched before alice's queue drains
    first_bob = order.index("bob")
    assert first_bob <= 2, order
    assert mgr.metrics.dispatches_by_tenant == {"alice": 4, "bob": 2}
    mgr.shutdown()


def test_priority_within_tenant(tmp_path):
    files = {"d/f.bin": os.urandom(8 * 1024)}
    src = seeded_posix(tmp_path, files)
    dst = MemoryConnector()
    mgr = make_manager(tmp_path, max_workers=1)

    gate = threading.Event()

    class Gated(PosixConnector):
        def send(self, session, path, channel):
            gate.wait(timeout=30)
            return super().send(session, path, channel)

    gated = Gated(src.root)
    opts = TransferOptions(startup_cost=0.0)
    mgr.submit(Endpoint(gated, "d"), Endpoint(dst, "o0"), opts,
               task_id="first")  # occupies the slot
    mgr.submit(Endpoint(gated, "d"), Endpoint(dst, "o1"), opts,
               task_id="later", priority=5)
    mgr.submit(Endpoint(gated, "d"), Endpoint(dst, "o2"), opts,
               task_id="urgent", priority=0)
    gate.set()
    assert mgr.wait_all(timeout=60)
    ids = [tid for _, tid in mgr.metrics.dispatch_log]
    assert ids.index("urgent") < ids.index("later")
    mgr.shutdown()


def test_session_sharing_across_tasks(tmp_path):
    """One Session per endpoint for the whole fleet — not one per task."""
    files = {f"d/f{i}.bin": os.urandom(16 * 1024) for i in range(2)}
    src = seeded_posix(tmp_path, files)
    dst = OpCountingMemory()
    mgr = make_manager(tmp_path, max_workers=2)
    opts = TransferOptions(startup_cost=0.0)
    for i in range(4):
        mgr.submit(Endpoint(src, "d"), Endpoint(dst, f"out{i}", "dst-ep"),
                   opts, task_id=f"s{i}")
    assert mgr.wait_all(timeout=60)
    assert dst.starts == 1  # shared, not 4
    assert mgr.sessions.live_sessions == 2  # src + dst, still warm
    mgr.shutdown()
    assert mgr.sessions.live_sessions == 0


def _mk_model(route, t0, R, s0=0.0, B=GB):
    return PerfModel(route=route, t0=t0, alpha=B / R + s0,
                     bytes_total=int(B), s0=s0)


def test_advisor_route_selection_and_refit(tmp_path):
    """Candidates are placed by the fitted models; predictions and
    actuals land in TaskStats; the observation log refits the route."""
    files = {f"d/f{i}.bin": os.urandom(4 * 1024) for i in range(8)}
    src = seeded_posix(tmp_path, files)
    fast_dst = MemoryConnector()
    slow_dst = MemoryConnector()
    advisor = Advisor([
        Route("fast", _mk_model("fast", t0=0.01, R=500e6)),
        Route("slow", _mk_model("slow", t0=2.0, R=5e6)),
    ])
    mgr = make_manager(tmp_path, advisor=advisor, max_workers=1)
    candidates = [
        RouteCandidate("slow", Endpoint(src, "d"),
                       Endpoint(slow_dst, "out")),
        RouteCandidate("fast", Endpoint(src, "d"),
                       Endpoint(fast_dst, "out")),
    ]
    shared_opts = TransferOptions(startup_cost=0.0)
    task = mgr.submit(candidates=candidates, options=shared_opts,
                      task_id="routed", sync=True)
    assert task.status == task.SUCCEEDED
    assert task.stats.route == "fast"
    # the advisor tunes a per-task copy, never the caller's options
    assert shared_opts.concurrency == TransferOptions().concurrency
    assert shared_opts.coalesce_threshold == \
        TransferOptions().coalesce_threshold
    assert task.stats.predicted_seconds > 0
    assert task.stats.actual_model_seconds >= 0
    fast_dst.start(None)
    assert fast_dst.store.get("out/f0.bin") == files["d/f0.bin"]
    assert slow_dst.store.keys() == []

    # vary the workload so the observation log supports a refit
    for i, n in enumerate((2, 4, 6)):
        sub = {f"w{i}/g{j}.bin": os.urandom(2 * 1024) for j in range(n)}
        subsrc = seeded_posix(os.path.join(str(tmp_path), f"w{i}"), sub)
        mgr.submit(candidates=[
            RouteCandidate("fast", Endpoint(subsrc, f"w{i}"),
                           Endpoint(fast_dst, f"r{i}"))],
            options=TransferOptions(startup_cost=0.0),
            task_id=f"obs{i}", sync=True)
    obs = mgr.observations("fast")
    assert len(obs) == 4
    model = mgr.refit_route("fast", min_points=3)
    assert model is not None
    assert advisor.routes[0].model is model
    mgr.shutdown()


def test_unknown_candidate_route_raises(tmp_path):
    mgr = make_manager(tmp_path, advisor=Advisor())
    with pytest.raises(ValueError):
        mgr.submit(candidates=[RouteCandidate(
            "nope", Endpoint(MemoryConnector(), "a"),
            Endpoint(MemoryConnector(), "b"))])
    with pytest.raises(ValueError):
        mgr.submit()  # neither src/dst nor candidates
    mgr.shutdown(wait=False)


# --------------------------------------------------------------------------
# per-task model-time attribution (the shared-clock skew fix)
# --------------------------------------------------------------------------
def test_concurrent_tasks_model_time_not_inflated(tmp_path):
    """With max_workers >= 4 and overlapping tasks, each task's
    ``actual_model_seconds`` is exactly its OWN charges — a concurrent
    task's latency never inflates it — and the per-route observations
    carry those exact values."""
    n = 4
    latch_n = [0]
    latch = threading.Event()
    lock = threading.Lock()

    class LatchMemory(MemoryConnector):
        """First recv of every task blocks until all n tasks are
        mid-flight, so the tasks genuinely overlap."""

        def recv(self, session, path, channel):
            with lock:
                latch_n[0] += 1
                if latch_n[0] >= n:
                    latch.set()
            assert latch.wait(30), "fleet never overlapped"
            return super().recv(session, path, channel)

    dst = LatchMemory()
    # t0=0: per-file path (coalesce threshold 0) and cc=1 from the ladder
    advisor = Advisor([Route("r", _mk_model("r", t0=0.0, R=1e12),
                             max_concurrency=1)])
    mgr = make_manager(tmp_path, advisor=advisor, max_workers=n,
                       per_endpoint_cap=None, refit_every=0)
    clock = mgr.service.clock
    tasks, expected = [], []
    for i in range(n):
        n_files = i + 2
        files = {f"d/f{j}.bin": os.urandom(1024) for j in range(n_files)}
        src = seeded_posix(os.path.join(str(tmp_path), f"s{i}"), files)
        opts = TransferOptions(startup_cost=0.5 * (i + 1),
                               file_pipeline_cost=0.125, parallelism=1)
        tasks.append(mgr.submit(
            candidates=[RouteCandidate("r", Endpoint(src, "d"),
                                       Endpoint(dst, f"out{i}"))],
            options=opts, task_id=f"attr{i}",
            n_files=n_files, nbytes=n_files * 1024))
        # posix->memory over loopback charges exactly startup + one
        # pipelined control exchange per file — nothing else
        expected.append(0.5 * (i + 1) + 0.125 * n_files)
    assert mgr.wait_all(timeout=60)
    for i, task in enumerate(tasks):
        assert task.status == task.SUCCEEDED, task.events[-3:]
        assert task.stats.actual_model_seconds == \
            pytest.approx(expected[i], abs=1e-9), \
            f"task {i}: cross-task inflation"
    # the four tasks PARTITION the shared clock: their charges sum to
    # (not each observe) the total modeled time
    assert sum(t.stats.actual_model_seconds for t in tasks) == \
        pytest.approx(clock.virtual_elapsed, abs=1e-9)
    obs = {nf: sec for nf, _, sec in mgr.observations("r")}
    for i, task in enumerate(tasks):
        assert obs[i + 2] == pytest.approx(expected[i], abs=1e-9)
    mgr.shutdown()


def test_auto_refit_loop_converges_and_retunes_queued(tmp_path):
    """The closed loop: a deliberately miscalibrated seed model is refit
    automatically every ``refit_every`` completions, still-queued
    submissions pick up the refreshed knobs + prediction, and post-refit
    median prediction error collapses."""
    dst = MemoryConnector()
    # seed model is ~1000x off: t0=5 s/file when the true per-file cost
    # is the 5 ms pipelined exchange
    advisor = Advisor([Route("r", _mk_model("r", t0=5.0, R=1e12),
                             max_concurrency=1)])
    mgr = make_manager(tmp_path, advisor=advisor, max_workers=1,
                       per_endpoint_cap=None, refit_every=3)
    tasks = []
    seed_predictions = {}
    for i in range(6):
        n_files = 2 + 2 * (i % 3)
        files = {f"d/f{j}.bin": os.urandom(512) for j in range(n_files)}
        src = seeded_posix(os.path.join(str(tmp_path), f"s{i}"), files)
        t = mgr.submit(
            candidates=[RouteCandidate("r", Endpoint(src, "d"),
                                       Endpoint(dst, f"out{i}"))],
            options=TransferOptions(startup_cost=0.01),
            task_id=f"refit{i}", n_files=n_files, nbytes=n_files * 512)
        seed_predictions[t.task_id] = t.stats.predicted_seconds
        tasks.append(t)
    assert mgr.wait_all(timeout=60)
    for t in tasks:
        assert t.status == t.SUCCEEDED, t.events[-3:]
    assert mgr.metrics.refits.get("r", 0) >= 1
    # queued submissions were re-predicted by the refreshed model
    gens = [g for _, g, _, _ in mgr.metrics.prediction_log]
    assert 0 in gens and max(gens) >= 1
    retuned = [t for t in tasks
               if t.stats.predicted_seconds != seed_predictions[t.task_id]]
    assert retuned, "no queued submission picked up the refit model"
    pre = mgr.prediction_error(generation=0)
    post = mgr.prediction_error(min_generation=1)
    assert post < pre, (pre, post)
    # the seed model was off by orders of magnitude; the refit one must
    # actually predict (not just improve)
    assert post < 1.0
    mgr.shutdown()


def test_observation_history_is_bounded(tmp_path):
    """Stale observations age out: the per-route ring keeps only the
    most recent ``history_limit`` points."""
    dst = MemoryConnector()
    advisor = Advisor([Route("r", _mk_model("r", t0=0.0, R=1e12),
                             max_concurrency=1)])
    mgr = make_manager(tmp_path, advisor=advisor, max_workers=1,
                       refit_every=0, history_limit=4)
    files = {"d/f.bin": os.urandom(256)}
    src = seeded_posix(tmp_path, files)
    for i in range(7):
        mgr.submit(candidates=[RouteCandidate(
            "r", Endpoint(src, "d"), Endpoint(dst, f"o{i}"))],
            options=TransferOptions(startup_cost=0.1 * (i + 1)),
            task_id=f"h{i}", n_files=1, nbytes=256, sync=True)
    obs = mgr.observations("r")
    assert len(obs) == 4
    # the survivors are the most recent four (largest startup charges)
    assert [round(sec, 6) for _, _, sec in obs] == \
        [round(0.1 * (i + 1) + 0.005, 6) for i in range(3, 7)]
    mgr.shutdown()


def test_refit_convergence_under_multitenant_chaos(tmp_path):
    """Acceptance: a multi-tenant fleet under fault injection still
    shrinks its median prediction error once the online refit loop has
    fired (run_multi's convergence invariant, strict)."""
    runner = ScenarioRunner(str(tmp_path), clock=Clock(scale=0.0))
    advisor = Advisor([Route("fleet", _mk_model("fleet", t0=3.0, R=1e9),
                             max_concurrency=1)])
    schedule = (FaultSchedule(seed=5)
                .transient(op="read", at=4, times=2)
                .latency(op="stat", delay=0.05, times=3))
    res = runner.run_multi(n_tasks=10, tenants=("alice", "bob", "carol"),
                           trees=("mixed", "many-small"),
                           route="posix->memory", schedule=schedule,
                           max_workers=3, per_endpoint_cap=None,
                           advisor=advisor, refit_every=3, seed=3,
                           strict=True)
    assert res.ok
    mgr = res.manager
    assert mgr.metrics.refits.get("fleet", 0) >= 1
    assert mgr.prediction_error(min_generation=1) < \
        mgr.prediction_error(generation=0)


# --------------------------------------------------------------------------
# scheduler races
# --------------------------------------------------------------------------
def test_cancel_while_queued_races_pump(tmp_path):
    """Cancels fired from other threads while _pump is dispatching:
    every task drains to a terminal state, the queue empties, and the
    accounting adds up — no wedge, no double-dispatch."""
    files = {"d/f.bin": os.urandom(4 * 1024)}
    src = seeded_posix(tmp_path, files)
    dst = MemoryConnector()
    mgr = make_manager(tmp_path, max_workers=2, per_endpoint_cap=None)

    gate = threading.Event()

    class Gated(PosixConnector):
        def send(self, session, path, channel):
            gate.wait(timeout=30)
            return super().send(session, path, channel)

    gated = Gated(src.root)
    opts = TransferOptions(startup_cost=0.0)
    n = 24
    tasks = [mgr.submit(Endpoint(gated, "d"), Endpoint(dst, f"o{i}"),
                        opts, task_id=f"c{i}") for i in range(n)]
    doomed = [f"c{i}" for i in range(0, n, 3)]

    def chop():
        for tid in doomed:
            mgr.cancel(tid)

    cancellers = [threading.Thread(target=chop) for _ in range(3)]
    for t in cancellers:
        t.start()
    gate.set()  # open the flood while cancels are in flight
    for t in cancellers:
        t.join()
    assert mgr.wait_all(timeout=120)
    counts = mgr.counts()
    assert counts["queued"] == 0 and counts["running"] == 0
    for task in tasks:
        assert task.status in (task.SUCCEEDED, task.CANCELLED), task.status
    m = mgr.metrics
    assert m.completed + m.cancelled == n
    # a task cancelled while queued must never have been dispatched
    dispatched = {tid for _, tid in m.dispatch_log}
    for task in tasks:
        if task.status == task.CANCELLED and task.task_id not in dispatched:
            assert task.stats.bytes_done == 0
    mgr.shutdown()


def test_resume_pending_cycles_under_concurrent_pump(tmp_path):
    """Repeated pause->immediate-resume cycles against a running fleet
    (so _pump is constantly re-entered) always drain to completion,
    byte-exact."""
    payload = {f"d/f{i}.bin": os.urandom(64 * 1024) for i in range(8)}
    src = seeded_posix(tmp_path, payload)
    dst = MemoryConnector()

    class Dawdling(PosixConnector):
        def send(self, session, path, channel):
            time.sleep(0.002)  # a window for pause to land mid-run
            return super().send(session, path, channel)

    slow = Dawdling(src.root)
    mgr = make_manager(tmp_path, max_workers=3, per_endpoint_cap=None)
    opts = TransferOptions(startup_cost=0.0, concurrency=2,
                           coalesce_threshold=0)
    main = mgr.submit(Endpoint(slow, "d"), Endpoint(dst, "main"), opts,
                      task_id="main")
    noise = [mgr.submit(Endpoint(slow, "d"), Endpoint(dst, f"n{i}"), opts,
                        task_id=f"n{i}") for i in range(4)]
    for _ in range(5):
        mgr.pause("main")
        mgr.resume("main")  # may race the drain -> resume_pending path
        time.sleep(0.005)
    # a final resume in case the last pause landed after its resume
    main.wait_idle(60)
    mgr.resume("main")
    assert mgr.wait_all(timeout=120)
    assert main.status == main.SUCCEEDED, main.events[-5:]
    for t in noise:
        assert t.status == t.SUCCEEDED
    dst.start(None)
    for name, data in payload.items():
        assert dst.store.get("main/" + name[len("d/"):]) == data
    mgr.shutdown()


# --------------------------------------------------------------------------
# session pool generations
# --------------------------------------------------------------------------
def test_session_pool_stale_release_is_noop(tmp_path):
    """A holder of a dead session releasing after the pool replaced it
    must not touch the replacement's refcount or destroy it."""
    from repro.core import SessionPool
    conn = MemoryConnector()
    creds = CredentialStore()
    pool = SessionPool(creds)
    ep = Endpoint(conn, "a", "ep")
    s1 = pool.acquire(ep)
    # the provider drops the session mid-task
    conn.destroy(s1)
    assert s1.closed
    # next task replaces the generation
    s2 = pool.acquire(ep)
    assert s2 is not s1 and not s2.closed
    # the stale holder's release is a no-op against the new generation
    pool.release(ep, s1)
    assert not s2.closed
    assert pool.live_sessions == 1
    # and a second stale release cannot drive anything negative / kill s2
    pool.release(ep, s1)
    pool.release(ep, s2)
    assert not s2.closed  # refcount 0: stays warm, not destroyed
    assert pool.live_sessions == 1
    pool.close_all()
    assert s2.closed


def test_session_pool_usable_after_close_all(tmp_path):
    """close_all retires the current generations only: the pool keeps
    sessions warm for work that starts afterwards instead of destroying
    every future session at refcount zero."""
    from repro.core import SessionPool
    conn = MemoryConnector()
    pool = SessionPool(CredentialStore())
    ep = Endpoint(conn, "a", "ep")
    s1 = pool.acquire(ep)
    pool.release(ep, s1)
    pool.close_all()
    assert s1.closed and pool.live_sessions == 0
    # the pool drained once; it must still pool (keep warm) afterwards
    s2 = pool.acquire(ep)
    pool.release(ep, s2)
    assert not s2.closed
    assert pool.live_sessions == 1
    s3 = pool.acquire(ep)
    assert s3 is s2  # warm reuse, not a fresh start
    pool.release(ep, s3)
    pool.close_all()
    assert s2.closed


def test_session_drop_mid_task_spares_replacement(tmp_path):
    """A chaos session drop mid-task (via FaultProxyConnector) closes
    the shared session; the victim task's stale release must not tear
    down the replacement the rest of the fleet is using."""
    from repro.connectors.faultproxy import FaultProxyConnector
    from repro.core.errors import SessionClosed

    files = {f"d/f{i}.bin": os.urandom(16 * 1024) for i in range(3)}
    src = seeded_posix(tmp_path, files)

    class DroppingProxy(FaultProxyConnector):
        """An injected drop also closes the live session, the way a real
        transport teardown would."""

        def recv(self, session, path, channel):
            try:
                return super().recv(session, path, channel)
            except SessionClosed:
                session.closed = True
                raise

    schedule = FaultSchedule(seed=1).session_drop(op="recv", at=1, times=1,
                                                  scope="global")
    dst = DroppingProxy(MemoryConnector(), schedule,
                        clock=Clock(scale=0.0))
    mgr = make_manager(tmp_path, max_workers=1)
    opts = TransferOptions(startup_cost=0.0, coalesce_threshold=0,
                           concurrency=1)
    victim = mgr.submit(Endpoint(src, "d"), Endpoint(dst, "v", "dst-ep"),
                        opts, task_id="victim")
    assert victim.wait(60)
    assert victim.status == victim.FAILED  # SessionClosed is permanent
    # the fleet keeps going on a fresh generation
    healthy = mgr.submit(Endpoint(src, "d"), Endpoint(dst, "h", "dst-ep"),
                         opts, task_id="healthy", sync=True)
    assert healthy.status == healthy.SUCCEEDED, healthy.events[-5:]
    inner = dst.inner
    inner.start(None)
    assert inner.store.get("h/f0.bin") == files["d/f0.bin"]
    # all references drained; the replacement session is alive and warm
    assert all(e.refs == 0 for e in mgr.sessions._by_session.values())
    assert mgr.sessions.live_sessions == 2  # src + replacement dst
    mgr.shutdown()
    assert mgr.sessions.live_sessions == 0


def test_degenerate_service_submit_is_managed(tmp_path):
    """A bare service.submit rides the same control plane (the implicit
    manager) and still behaves exactly as before."""
    from repro.core import TransferService
    svc = TransferService(marker_root=os.path.join(str(tmp_path), "m"),
                         clock=Clock(scale=0.0))
    payload = os.urandom(MB)
    src = seeded_posix(tmp_path, {"a.bin": payload})
    dst = MemoryConnector()
    task = svc.submit(Endpoint(src, "a.bin"), Endpoint(dst, "a.bin"),
                      TransferOptions(startup_cost=0.0), sync=True)
    assert task.status == task.SUCCEEDED
    dst.start(None)
    assert dst.store.get("a.bin") == payload
    assert svc.default_manager().metrics.completed == 1
