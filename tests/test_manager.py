"""TransferManager control-plane behaviour: fleet scheduling, caps,
tenant fairness, lifecycle (pause/resume/cancel), session sharing, and
Advisor-driven route selection (paper §2.1-§2.2: the managed third-party
orchestrator, scaled out)."""

import os
import threading

import pytest

from repro.connectors import MemoryConnector, PosixConnector
from repro.core import (Advisor, Credential, CredentialStore, Endpoint,
                        FaultSchedule, PerfModel, Route, RouteCandidate,
                        TransferManager, TransferOptions)
from repro.core.clock import Clock
from repro.sim import ScenarioRunner

MB = 1024 * 1024
GB = 1e9


def make_manager(tmp_path, creds=None, **kw):
    creds = creds or CredentialStore()
    kw.setdefault("max_workers", 4)
    kw.setdefault("per_endpoint_cap", 2)
    return TransferManager(credential_store=creds,
                           marker_root=os.path.join(str(tmp_path), "markers"),
                           clock=Clock(scale=0.0), **kw)


def seeded_posix(tmp_path, files):
    root = os.path.join(str(tmp_path), "srcroot")
    conn = PosixConnector(root)
    for name, payload in files.items():
        p = os.path.join(root, name)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "wb") as f:
            f.write(payload)
    return conn


class OpCountingMemory(MemoryConnector):
    """Counts concurrently-active data-plane ops — independent evidence
    that the manager's per-endpoint cap holds at the connector."""

    def __init__(self):
        super().__init__()
        self._lock = threading.Lock()
        self.active = 0
        self.peak = 0
        self.starts = 0

    def _enter(self):
        with self._lock:
            self.active += 1
            self.peak = max(self.peak, self.active)

    def _exit(self):
        with self._lock:
            self.active -= 1

    def start(self, credential=None):
        with self._lock:
            self.starts += 1
        return super().start(credential)

    def recv(self, session, path, channel):
        self._enter()
        try:
            return super().recv(session, path, channel)
        finally:
            self._exit()

    def recv_batch(self, session, paths, channel_factory):
        self._enter()
        try:
            return super().recv_batch(session, paths, channel_factory)
        finally:
            self._exit()


# --------------------------------------------------------------------------
# acceptance: a chaos fleet across tenants
# --------------------------------------------------------------------------
def test_fleet_chaos_pause_resume_byte_exact(tmp_path):
    """>= 4 concurrent tasks across 2 tenants under an injected
    FaultSchedule, with a pause->resume mid-run: every task completes
    byte-exact, caps hold, and markers end cleared."""
    runner = ScenarioRunner(str(tmp_path), clock=Clock(scale=0.0))
    schedule = (FaultSchedule(seed=11)
                .transient(op="recv", at=1, times=1)
                .transient(op="read", at=3, times=1))
    res = runner.run_multi(n_tasks=5, tenants=("alice", "bob"),
                           trees=("mixed", "many-small"),
                           route="posix->memory", schedule=schedule,
                           max_workers=3, per_endpoint_cap=2,
                           pause_resume=(1, 3), seed=7, strict=True)
    assert res.ok
    assert len(res.tasks) == 5
    for task in res.tasks:
        assert task.status == task.SUCCEEDED, (task.task_id, task.events[-3:])
    # the schedule actually fired (chaos was live, not a no-op)
    assert schedule.events
    m = res.manager.metrics
    assert m.peak_active <= 3
    assert all(peak <= 2 for peak in m.peak_by_endpoint.values())
    assert set(m.dispatches_by_tenant) == {"alice", "bob"}


def test_endpoint_cap_holds_at_connector(tmp_path):
    """Cap evidence measured at the destination connector itself: with
    per-task concurrency 1, concurrently-active recv ops == concurrently
    active tasks on that endpoint."""
    files = {f"d/f{i}.bin": os.urandom(64 * 1024) for i in range(6)}
    src = seeded_posix(tmp_path, files)
    dst = OpCountingMemory()
    creds = CredentialStore()
    mgr = make_manager(tmp_path, creds, max_workers=4, per_endpoint_cap=2)
    opts = TransferOptions(startup_cost=0.0, concurrency=1,
                           coalesce_threshold=0)
    tasks = [mgr.submit(Endpoint(src, "d", f"src{i}"),
                        Endpoint(dst, f"out{i}", "the-dst"), opts,
                        task_id=f"cap{i}")
             for i in range(6)]
    assert mgr.wait_all(timeout=60)
    for t in tasks:
        assert t.status == t.SUCCEEDED
    assert mgr.metrics.peak_by_endpoint["the-dst"] <= 2
    assert dst.peak <= 2
    mgr.shutdown()


def test_pause_resume_no_resend_of_completed_ranges(tmp_path):
    """Pause mid-transfer; the resume must move only the holes the
    MarkerStore says are missing (paper §3 'holey' restart, driven
    through the control plane)."""
    payload = os.urandom(8 * MB)
    src = seeded_posix(tmp_path, {"big.bin": payload})

    gate = threading.Event()      # set => reads flow
    reached = threading.Event()   # first 2 MB landed
    seen = {"n": 0}
    lock = threading.Lock()

    class GateMemory(MemoryConnector):
        def recv(self, session, path, channel):
            outer = self

            class Wrap:
                def __getattr__(w, k):
                    return getattr(channel, k)

                def read(w, offset, length):
                    with lock:
                        seen["n"] += length
                        hit = seen["n"] >= 2 * MB
                    if hit:
                        reached.set()
                        gate.wait(timeout=30)
                    return channel.read(offset, length)

            super().recv(session, path, Wrap())

    dst = GateMemory()
    mgr = make_manager(tmp_path)
    opts = TransferOptions(startup_cost=0.0, blocksize=256 * 1024,
                           parallelism=1, concurrency=1)
    task = mgr.submit(Endpoint(src, "big.bin"), Endpoint(dst, "big.bin"),
                      opts, task_id="pr1")
    assert reached.wait(30), "transfer never reached the gate"
    assert mgr.pause("pr1")
    gate.set()
    assert task.wait_idle(30)
    assert task.status == task.PAUSED

    state = mgr.service.markers.load("pr1")
    done_ranges = state["files"]["big.bin"]["done"]
    done_bytes = sum(length for _, length in done_ranges)
    assert 0 < done_bytes < len(payload)
    assert not state["files"]["big.bin"].get("complete")

    sent = {"n": 0}
    orig = PosixConnector.send

    def counting_send(self, session, path, channel):
        class Wrap:
            def __getattr__(w, k):
                return getattr(channel, k)

            def write(w, offset, data):
                sent["n"] += len(data)
                channel.write(offset, data)

        return orig(self, session, path, Wrap())

    PosixConnector.send = counting_send
    try:
        assert mgr.resume("pr1")
        assert task.wait(60)
    finally:
        PosixConnector.send = orig
    assert task.status == task.SUCCEEDED, task.events[-5:]
    # only the holes crossed the wire on resume
    assert sent["n"] == len(payload) - done_bytes
    dst.start(None)
    assert dst.store.get("big.bin") == payload
    assert mgr.service.markers.load("pr1") == {"files": {}}
    assert task.stats.resumes == 1
    mgr.shutdown()


def test_resume_races_inflight_pause(tmp_path):
    """resume() fired immediately after pause() — before the run loop
    drains — must still re-queue the task, never wedge it in PAUSED."""
    payload = os.urandom(4 * MB)
    src = seeded_posix(tmp_path, {"big.bin": payload})

    gate = threading.Event()
    reached = threading.Event()
    seen = {"n": 0}
    lock = threading.Lock()

    class GateMemory(MemoryConnector):
        def recv(self, session, path, channel):
            outer = self

            class Wrap:
                def __getattr__(w, k):
                    return getattr(channel, k)

                def read(w, offset, length):
                    with lock:
                        seen["n"] += length
                        hit = seen["n"] >= MB
                    if hit:
                        reached.set()
                        gate.wait(timeout=30)
                    return channel.read(offset, length)

            super().recv(session, path, Wrap())

    dst = GateMemory()
    mgr = make_manager(tmp_path)
    opts = TransferOptions(startup_cost=0.0, blocksize=256 * 1024,
                           parallelism=1, concurrency=1)
    task = mgr.submit(Endpoint(src, "big.bin"), Endpoint(dst, "big.bin"),
                      opts, task_id="race1")
    assert reached.wait(30)
    assert mgr.pause("race1")
    # no wait_idle: the pause is still draining when we resume
    assert mgr.resume("race1")
    gate.set()
    assert task.wait(60)
    assert task.status == task.SUCCEEDED, task.events[-5:]
    dst.start(None)
    assert dst.store.get("big.bin") == payload
    mgr.shutdown()


def test_pause_queued_and_cancel(tmp_path):
    files = {f"d/f{i}.bin": os.urandom(32 * 1024) for i in range(3)}
    src = seeded_posix(tmp_path, files)
    dst = MemoryConnector()
    mgr = make_manager(tmp_path, max_workers=1)

    gate = threading.Event()
    reached = threading.Event()

    class SlowSrc(PosixConnector):
        def send(self, session, path, channel):
            reached.set()
            gate.wait(timeout=30)
            return super().send(session, path, channel)

    slow = SlowSrc(src.root)
    opts = TransferOptions(startup_cost=0.0, coalesce_threshold=0)
    t_busy = mgr.submit(Endpoint(slow, "d"), Endpoint(dst, "busy"), opts,
                        task_id="busy")
    t_queued = mgr.submit(Endpoint(src, "d"), Endpoint(dst, "q"), opts,
                          task_id="queued")
    t_cancel = mgr.submit(Endpoint(src, "d"), Endpoint(dst, "c"), opts,
                          task_id="doomed")
    assert reached.wait(10)
    # one-slot manager: the other two are still queued -> deterministic
    assert mgr.pause("queued")
    assert t_queued.status == t_queued.PAUSED
    assert mgr.cancel("doomed")
    assert t_cancel.status == t_cancel.CANCELLED
    gate.set()
    assert t_busy.wait(60)
    # paused task does not run until resumed
    assert t_queued.status == t_queued.PAUSED
    # wait_all must not wedge on (or wait for) the paused task
    assert mgr.wait_all(timeout=10)
    assert t_queued.status == t_queued.PAUSED
    assert mgr.resume("queued")
    assert t_queued.wait(60)
    assert t_queued.status == t_queued.SUCCEEDED
    assert mgr.wait_all(timeout=60)
    dst.start(None)
    assert dst.store.get("q/f0.bin") == files["d/f0.bin"]
    # cancelled before running: nothing landed
    assert not any(k.startswith("c/") for k in dst.store.keys())
    mgr.shutdown()


def test_tenant_fair_round_robin(tmp_path):
    """A tenant flooding the queue cannot starve another: dispatch order
    alternates tenants even when one submitted everything first."""
    files = {"d/f.bin": os.urandom(16 * 1024)}
    src = seeded_posix(tmp_path, files)
    dst = MemoryConnector()
    creds = CredentialStore()
    creds.register("src-alice", Credential("local-user",
                                           {"identity": "alice"}))
    creds.register("src-bob", Credential("local-user", {"identity": "bob"}))
    mgr = make_manager(tmp_path, creds, max_workers=1,
                       per_endpoint_cap=None)

    gate = threading.Event()

    class Gated(PosixConnector):
        def send(self, session, path, channel):
            gate.wait(timeout=30)
            return super().send(session, path, channel)

    gated = Gated(src.root)
    opts = TransferOptions(startup_cost=0.0)
    # alice floods 4 tasks, then bob submits 2
    for i in range(4):
        mgr.submit(Endpoint(gated, "d", "src-alice"),
                   Endpoint(dst, f"a{i}"), opts, task_id=f"a{i}")
    for i in range(2):
        mgr.submit(Endpoint(gated, "d", "src-bob"),
                   Endpoint(dst, f"b{i}"), opts, task_id=f"b{i}")
    gate.set()
    assert mgr.wait_all(timeout=60)
    order = [tenant for tenant, _ in mgr.metrics.dispatch_log]
    # bob's first task is dispatched before alice's queue drains
    first_bob = order.index("bob")
    assert first_bob <= 2, order
    assert mgr.metrics.dispatches_by_tenant == {"alice": 4, "bob": 2}
    mgr.shutdown()


def test_priority_within_tenant(tmp_path):
    files = {"d/f.bin": os.urandom(8 * 1024)}
    src = seeded_posix(tmp_path, files)
    dst = MemoryConnector()
    mgr = make_manager(tmp_path, max_workers=1)

    gate = threading.Event()

    class Gated(PosixConnector):
        def send(self, session, path, channel):
            gate.wait(timeout=30)
            return super().send(session, path, channel)

    gated = Gated(src.root)
    opts = TransferOptions(startup_cost=0.0)
    mgr.submit(Endpoint(gated, "d"), Endpoint(dst, "o0"), opts,
               task_id="first")  # occupies the slot
    mgr.submit(Endpoint(gated, "d"), Endpoint(dst, "o1"), opts,
               task_id="later", priority=5)
    mgr.submit(Endpoint(gated, "d"), Endpoint(dst, "o2"), opts,
               task_id="urgent", priority=0)
    gate.set()
    assert mgr.wait_all(timeout=60)
    ids = [tid for _, tid in mgr.metrics.dispatch_log]
    assert ids.index("urgent") < ids.index("later")
    mgr.shutdown()


def test_session_sharing_across_tasks(tmp_path):
    """One Session per endpoint for the whole fleet — not one per task."""
    files = {f"d/f{i}.bin": os.urandom(16 * 1024) for i in range(2)}
    src = seeded_posix(tmp_path, files)
    dst = OpCountingMemory()
    mgr = make_manager(tmp_path, max_workers=2)
    opts = TransferOptions(startup_cost=0.0)
    for i in range(4):
        mgr.submit(Endpoint(src, "d"), Endpoint(dst, f"out{i}", "dst-ep"),
                   opts, task_id=f"s{i}")
    assert mgr.wait_all(timeout=60)
    assert dst.starts == 1  # shared, not 4
    assert mgr.sessions.live_sessions == 2  # src + dst, still warm
    mgr.shutdown()
    assert mgr.sessions.live_sessions == 0


def _mk_model(route, t0, R, s0=0.0, B=GB):
    return PerfModel(route=route, t0=t0, alpha=B / R + s0,
                     bytes_total=int(B), s0=s0)


def test_advisor_route_selection_and_refit(tmp_path):
    """Candidates are placed by the fitted models; predictions and
    actuals land in TaskStats; the observation log refits the route."""
    files = {f"d/f{i}.bin": os.urandom(4 * 1024) for i in range(8)}
    src = seeded_posix(tmp_path, files)
    fast_dst = MemoryConnector()
    slow_dst = MemoryConnector()
    advisor = Advisor([
        Route("fast", _mk_model("fast", t0=0.01, R=500e6)),
        Route("slow", _mk_model("slow", t0=2.0, R=5e6)),
    ])
    mgr = make_manager(tmp_path, advisor=advisor, max_workers=1)
    candidates = [
        RouteCandidate("slow", Endpoint(src, "d"),
                       Endpoint(slow_dst, "out")),
        RouteCandidate("fast", Endpoint(src, "d"),
                       Endpoint(fast_dst, "out")),
    ]
    shared_opts = TransferOptions(startup_cost=0.0)
    task = mgr.submit(candidates=candidates, options=shared_opts,
                      task_id="routed", sync=True)
    assert task.status == task.SUCCEEDED
    assert task.stats.route == "fast"
    # the advisor tunes a per-task copy, never the caller's options
    assert shared_opts.concurrency == TransferOptions().concurrency
    assert shared_opts.coalesce_threshold == \
        TransferOptions().coalesce_threshold
    assert task.stats.predicted_seconds > 0
    assert task.stats.actual_model_seconds >= 0
    fast_dst.start(None)
    assert fast_dst.store.get("out/f0.bin") == files["d/f0.bin"]
    assert slow_dst.store.keys() == []

    # vary the workload so the observation log supports a refit
    for i, n in enumerate((2, 4, 6)):
        sub = {f"w{i}/g{j}.bin": os.urandom(2 * 1024) for j in range(n)}
        subsrc = seeded_posix(os.path.join(str(tmp_path), f"w{i}"), sub)
        mgr.submit(candidates=[
            RouteCandidate("fast", Endpoint(subsrc, f"w{i}"),
                           Endpoint(fast_dst, f"r{i}"))],
            options=TransferOptions(startup_cost=0.0),
            task_id=f"obs{i}", sync=True)
    obs = mgr.observations("fast")
    assert len(obs) == 4
    model = mgr.refit_route("fast", min_points=3)
    assert model is not None
    assert advisor.routes[0].model is model
    mgr.shutdown()


def test_unknown_candidate_route_raises(tmp_path):
    mgr = make_manager(tmp_path, advisor=Advisor())
    with pytest.raises(ValueError):
        mgr.submit(candidates=[RouteCandidate(
            "nope", Endpoint(MemoryConnector(), "a"),
            Endpoint(MemoryConnector(), "b"))])
    with pytest.raises(ValueError):
        mgr.submit()  # neither src/dst nor candidates
    mgr.shutdown(wait=False)


def test_degenerate_service_submit_is_managed(tmp_path):
    """A bare service.submit rides the same control plane (the implicit
    manager) and still behaves exactly as before."""
    from repro.core import TransferService
    svc = TransferService(marker_root=os.path.join(str(tmp_path), "m"),
                         clock=Clock(scale=0.0))
    payload = os.urandom(MB)
    src = seeded_posix(tmp_path, {"a.bin": payload})
    dst = MemoryConnector()
    task = svc.submit(Endpoint(src, "a.bin"), Endpoint(dst, "a.bin"),
                      TransferOptions(startup_cost=0.0), sync=True)
    assert task.status == task.SUCCEEDED
    dst.start(None)
    assert dst.store.get("a.bin") == payload
    assert svc.default_manager().metrics.completed == 1
