"""Managed transfer-service behaviour (paper §2.2, §3, §7)."""

import os
import threading

import pytest

from repro.core import (Credential, CredentialStore, Endpoint, FaultSchedule,
                        TransferOptions, TransferService, checksum_bytes)
from repro.core.clock import Clock
from repro.core.transfer import MarkerStore, _holes, _merge_ranges
from repro.core.connector import ByteRange
from repro.connectors import (MemoryConnector, ObjectStoreConnector,
                              PosixConnector, make_cloud)

MB = 1024 * 1024


def make_service(tmp_path, clock=None):
    store = CredentialStore()
    return TransferService(credential_store=store,
                           marker_root=os.path.join(str(tmp_path), "markers"),
                           clock=clock or Clock(scale=0.0)), store


def seeded_posix(tmp_path, files):
    root = os.path.join(str(tmp_path), "src")
    conn = PosixConnector(root)
    for name, payload in files.items():
        p = os.path.join(root, name)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "wb") as f:
            f.write(payload)
    return conn


def test_single_file_transfer(tmp_path):
    svc, creds = make_service(tmp_path)
    payload = os.urandom(3 * MB + 17)
    src = seeded_posix(tmp_path, {"data.bin": payload})
    dst = MemoryConnector()
    task = svc.submit(Endpoint(src, "data.bin"), Endpoint(dst, "out/data.bin"),
                      TransferOptions(blocksize=256 * 1024), sync=True)
    assert task.status == task.SUCCEEDED, task.events
    s = dst.start(None)
    assert dst.store.get("out/data.bin") == payload
    assert task.stats.bytes_done == len(payload)


def test_directory_transfer_expansion(tmp_path):
    svc, creds = make_service(tmp_path)
    files = {f"d/sub{i}/f{j}.bin": os.urandom(10_000 + i * j)
             for i in range(3) for j in range(4)}
    src = seeded_posix(tmp_path, files)
    dst = MemoryConnector()
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "mirror"),
                      TransferOptions(concurrency=4), sync=True)
    assert task.status == task.SUCCEEDED
    assert task.stats.files_done == 12
    for name, payload in files.items():
        key = "mirror/" + name[len("d/"):]
        assert dst.store.get(key) == payload


def test_third_party_cloud_to_cloud(tmp_path):
    """Inter-cloud transfer (paper §6.5): client never in the data path."""
    clock = Clock(scale=0.0)
    svc, creds = make_service(tmp_path, clock)
    s3 = make_cloud("s3", clock=clock)
    gcs = make_cloud("gcs", clock=clock)
    src_conn = ObjectStoreConnector(s3, placement="cloud", clock=clock)
    dst_conn = ObjectStoreConnector(gcs, placement="cloud", clock=clock)
    creds.register("ep-s3", Credential("s3-keypair", {"access_key": "A"}))
    creds.register("ep-gcs", Credential("oauth2-token", {"token": "t"}))
    payload = os.urandom(2 * MB)
    s3.blobs.put("bucket/obj", payload)
    task = svc.submit(Endpoint(src_conn, "bucket/obj", "ep-s3"),
                      Endpoint(dst_conn, "dst-bucket/obj", "ep-gcs"),
                      TransferOptions(), sync=True)
    assert task.status == task.SUCCEEDED, task.events
    assert gcs.blobs.get("dst-bucket/obj") == payload


def test_integrity_checking_end_to_end(tmp_path):
    svc, creds = make_service(tmp_path)
    payload = os.urandom(1 * MB + 3)
    src = seeded_posix(tmp_path, {"x.bin": payload})
    dst = MemoryConnector()
    task = svc.submit(Endpoint(src, "x.bin"), Endpoint(dst, "x.bin"),
                      TransferOptions(integrity=True), sync=True)
    assert task.status == task.SUCCEEDED
    assert task.files[-1].checksum == checksum_bytes(payload, "sha256")


class CorruptingConnector(MemoryConnector):
    """Flips a byte on the first N writes to a path (silent corruption,
    paper §7)."""

    def __init__(self, n_corrupt=1):
        super().__init__()
        self.n_corrupt = n_corrupt
        self._count = 0
        self._lock = threading.Lock()

    def recv(self, session, path, channel):
        super().recv(session, path, channel)
        with self._lock:
            if self._count < self.n_corrupt:
                self._count += 1
                key = self._key(path)
                data = bytearray(self.store.get(key))
                data[len(data) // 2] ^= 0xFF
                self.store.put(key, bytes(data))


def test_integrity_detects_and_repairs_corruption(tmp_path):
    svc, creds = make_service(tmp_path)
    payload = os.urandom(512 * 1024)
    src = seeded_posix(tmp_path, {"y.bin": payload})
    dst = CorruptingConnector(n_corrupt=1)
    task = svc.submit(Endpoint(src, "y.bin"), Endpoint(dst, "y.bin"),
                      TransferOptions(integrity=True), sync=True)
    assert task.status == task.SUCCEEDED
    assert task.stats.integrity_failures == 1
    s = dst.start(None)
    assert dst.store.get("y.bin") == payload


def test_integrity_gives_up_after_budget(tmp_path):
    svc, creds = make_service(tmp_path)
    payload = os.urandom(64 * 1024)
    src = seeded_posix(tmp_path, {"z.bin": payload})
    dst = CorruptingConnector(n_corrupt=99)
    task = svc.submit(Endpoint(src, "z.bin"), Endpoint(dst, "z.bin"),
                      TransferOptions(integrity=True, max_integrity_retries=2),
                      sync=True)
    assert task.status == task.FAILED
    assert task.stats.files_failed == 1


def test_transient_fault_retry(tmp_path):
    """API-quota faults are retried automatically (paper §4: Drive/Box
    call quotas handled 'through automatic retries')."""
    clock = Clock(scale=0.0)
    svc, creds = make_service(tmp_path, clock)

    faults = FaultSchedule(seed=0).transient(op="put_part", at=1, times=3,
                                            scope="global")
    drive = make_cloud("drive", clock=clock, faults=faults, quota_rate=10_000,
                       quota_burst=100_000, consistency_delay=0.0)
    dst_conn = ObjectStoreConnector(drive, placement="local", clock=clock)
    creds.register("ep-drive", Credential("oauth2-token", {"token": "t"}))
    payload = os.urandom(128 * 1024)
    src = seeded_posix(tmp_path, {"w.bin": payload})
    task = svc.submit(Endpoint(src, "w.bin"),
                      Endpoint(dst_conn, "folder/w.bin", "ep-drive"),
                      TransferOptions(retry_backoff=0.001), sync=True)
    assert task.status == task.SUCCEEDED, task.events
    assert task.stats.faults_retried == 3
    assert task.stats.retries_by_kind == {"FaultInjected": 3}
    assert faults.count("transient") == 3
    assert drive.blobs.get("folder/w.bin") == payload


def test_retries_exhausted_marks_failed(tmp_path):
    clock = Clock(scale=0.0)
    svc, creds = make_service(tmp_path, clock)
    s3 = make_cloud("s3", clock=clock,
                    faults=FaultSchedule().transient(op="put_part",
                                                     times=None))
    dst_conn = ObjectStoreConnector(s3, placement="local", clock=clock)
    creds.register("ep", Credential("s3-keypair", {}))
    src = seeded_posix(tmp_path, {"f.bin": b"x" * 1024})
    task = svc.submit(Endpoint(src, "f.bin"), Endpoint(dst_conn, "f.bin", "ep"),
                      TransferOptions(max_retries=2, retry_backoff=0.001),
                      sync=True)
    assert task.status == task.FAILED
    assert task.stats.faults_retried >= 2


def test_restart_marker_resume(tmp_path):
    """Kill mid-transfer; resume must complete byte-exact without
    re-sending completed ranges (paper §3 'holey' transfers)."""
    svc, creds = make_service(tmp_path)
    payload = os.urandom(4 * MB)
    src = seeded_posix(tmp_path, {"big.bin": payload})
    dst = MemoryConnector()

    # simulate prior partial progress: first half already transferred
    task_id = "resume-test"
    state = {"files": {"big.bin": {"done": [[0, 2 * MB]], "complete": False}}}
    svc.markers.save(task_id, state)
    dst.store.put_range("big.bin", 0, payload[:2 * MB])

    sent = {"bytes": 0}
    orig = PosixConnector.send

    def counting_send(self, session, path, channel):
        class Wrap:
            def __init__(w, inner):
                w.inner = inner

            def __getattr__(w, k):
                return getattr(w.inner, k)

            def write(w, offset, data):
                sent["bytes"] += len(data)
                w.inner.write(offset, data)

        return orig(self, session, path, Wrap(channel))

    PosixConnector.send = counting_send
    try:
        task = svc.submit(Endpoint(src, "big.bin"), Endpoint(dst, "big.bin"),
                          TransferOptions(), task_id=task_id, sync=True)
    finally:
        PosixConnector.send = orig
    assert task.status == task.SUCCEEDED
    assert sent["bytes"] == 2 * MB  # only the hole was re-sent
    assert dst.store.get("big.bin") == payload
    # marker is cleared on success
    assert svc.markers.load(task_id) == {"files": {}}


def test_completed_files_skipped_on_resume(tmp_path):
    svc, creds = make_service(tmp_path)
    files = {f"d/f{i}.bin": os.urandom(8192) for i in range(4)}
    src = seeded_posix(tmp_path, files)
    dst = MemoryConnector()
    task_id = "skip-test"
    state = {"files": {"d/f0.bin": {"done": [[0, 8192]], "complete": True}}}
    svc.markers.save(task_id, state)
    dst.store.put("out/f0.bin", files["d/f0.bin"])
    task = svc.submit(Endpoint(src, "d"), Endpoint(dst, "out"),
                      TransferOptions(), task_id=task_id, sync=True)
    assert task.status == task.SUCCEEDED
    assert task.stats.files_done == 4
    for i in range(4):
        assert dst.store.get(f"out/f{i}.bin") == files[f"d/f{i}.bin"]


def test_fire_and_forget_async(tmp_path):
    svc, creds = make_service(tmp_path)
    payload = os.urandom(MB)
    src = seeded_posix(tmp_path, {"a.bin": payload})
    dst = MemoryConnector()
    task = svc.submit(Endpoint(src, "a.bin"), Endpoint(dst, "a.bin"))
    assert task.wait(timeout=30)
    assert task.status == task.SUCCEEDED
    assert dst.store.get("a.bin") == payload


def test_merge_ranges_and_holes():
    assert _merge_ranges([[0, 10], [10, 5], [20, 5]]) == [[0, 15], [20, 5]]
    assert _merge_ranges([[5, 5], [0, 5]]) == [[0, 10]]
    holes = _holes(100, [[0, 20], [50, 10]])
    assert holes == [ByteRange(20, 30), ByteRange(60, 40)]
    assert _holes(10, []) == [ByteRange(0, 10)]
    assert _holes(10, [[0, 10]]) == []
