"""Checkpoint + data-pipeline integration over the Connector layer."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.connectors import MemoryConnector, PosixConnector
from repro.core import Credential, CredentialStore, Endpoint, TransferService
from repro.core.errors import IntegrityError
from repro.ckpt import (CheckpointManager, replicate_checkpoint,
                        restore_checkpoint, save_checkpoint)
from repro.ckpt.io import get_bytes, put_bytes
from repro.data import (DataPipelineConfig, ShardedTokenDataset,
                        synthetic_corpus)


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w1": jax.random.normal(k, (64, 64)),
                   "b1": jnp.zeros((64,)),
                   "blocks": {"wq": jax.random.normal(k, (4, 32, 32))}},
        "opt": {"m": {"w": jnp.ones((16,), jnp.bfloat16)},
                "step": jnp.int32(7)},
    }


def abstract_like(state):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)


def test_io_roundtrip(tmp_path):
    conn = PosixConnector(str(tmp_path))
    s = conn.start(None)
    payload = os.urandom(5 * 1024 * 1024 + 13)
    put_bytes(conn, s, "deep/dir/obj.bin", payload)
    assert get_bytes(conn, s, "deep/dir/obj.bin") == payload
    assert get_bytes(conn, s, "deep/dir/obj.bin",
                     offset=100, length=999) == payload[100:1099]


def test_checkpoint_roundtrip(tmp_path):
    conn = PosixConnector(str(tmp_path))
    state = make_state()
    manifest = save_checkpoint(state, conn, "ckpt", step=3)
    assert manifest["step"] == 3
    restored, step = restore_checkpoint(abstract_like(state), conn, "ckpt")
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_small_leaves_coalesced(tmp_path):
    """Paper §5/§8: small tensors must be bundled, not written as many
    tiny objects (per-file overhead t0 dominates otherwise)."""
    conn = MemoryConnector()
    state = {f"tiny{i}": jnp.full((8,), i, jnp.float32) for i in range(40)}
    manifest = save_checkpoint(state, conn, "c", step=0)
    assert len(manifest["objects"]) == 0           # nothing large
    assert len(manifest["bundles"]) == 40          # all bundled
    objects = {m["object"] for m in manifest["bundles"].values()}
    assert len(objects) <= 2                        # into a couple blobs
    restored, _ = restore_checkpoint(abstract_like(state), conn, "c")
    assert float(restored["tiny7"][0]) == 7.0


def test_checkpoint_detects_corruption(tmp_path):
    conn = MemoryConnector()
    state = {"w": jnp.arange(131072, dtype=jnp.float32)}
    save_checkpoint(state, conn, "c", step=1)
    # flip a byte in the stored object
    key = [k for k in conn.store.keys() if k.endswith(".bin")][0]
    raw = bytearray(conn.store.get(key))
    raw[1000] ^= 0xFF
    conn.store.put(key, bytes(raw))
    with pytest.raises(IntegrityError):
        restore_checkpoint(abstract_like(state), conn, "c", step=1)


def test_checkpoint_manager_async_and_gc(tmp_path):
    conn = PosixConnector(str(tmp_path))
    mgr = CheckpointManager(conn, "run1", retain=2)
    state = make_state()
    for step in (1, 2, 3, 4):
        mgr.save_async(state, step)
        mgr.wait()
    s = conn.start(None)
    names = {i.name for i in conn.listdir(s, "run1")}
    assert any("step_4" in n for n in names)
    assert not any("step_1" in n for n in names)  # GC'd
    restored, step = mgr.restore_latest(abstract_like(state))
    assert step == 4


def test_checkpoint_replication_third_party(tmp_path):
    """Cluster -> cloud replication via the managed transfer service."""
    cluster = PosixConnector(os.path.join(str(tmp_path), "cluster"))
    cloud = MemoryConnector()
    state = make_state()
    save_checkpoint(state, cluster, "ckpt", step=5)
    svc = TransferService(marker_root=os.path.join(str(tmp_path), "m"))
    task = replicate_checkpoint(
        svc, Endpoint(cluster, "ckpt"), Endpoint(cloud, "mirror"),
        step=5, sync=True)
    assert task.status == task.SUCCEEDED, task.events
    restored, step = restore_checkpoint(abstract_like(state), cloud,
                                        "mirror", step=5)
    assert step == 5
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w1"]),
        np.asarray(state["params"]["w1"]))


def test_elastic_restore_resharded(tmp_path):
    """Checkpoint written unsharded restores onto explicit shardings
    (mesh-independent format -> elastic restart)."""
    conn = MemoryConnector()
    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save_checkpoint(state, conn, "c", step=0)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = restore_checkpoint(abstract_like(state), conn, "c",
                                     step=0, shardings=sh)
    assert restored["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_dataset_determinism_and_shapes(tmp_path):
    conn = MemoryConnector()
    synthetic_corpus(conn, "corpus", vocab_size=100, seq_len=32,
                     n_records=64, seed=1, records_per_shard=16)
    cfg = DataPipelineConfig(seq_len=32, batch_size=4)
    ds1 = ShardedTokenDataset(conn, "corpus", cfg)
    ds2 = ShardedTokenDataset(conn, "corpus", cfg)
    for _, (a, b) in zip(range(10), zip(ds1.batches(), ds2.batches())):
        assert a["tokens"].shape == (4, 32)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
        assert (a["labels"][:, -1] == -1).all()


def test_dataset_host_partition_disjoint(tmp_path):
    conn = MemoryConnector()
    synthetic_corpus(conn, "corpus", vocab_size=50, seq_len=16,
                     n_records=64, records_per_shard=8)
    seen = []
    for host in range(2):
        cfg = DataPipelineConfig(seq_len=16, batch_size=2, host_id=host,
                                 n_hosts=2)
        ds = ShardedTokenDataset(conn, "corpus", cfg)
        seen.append(set(ds.shards))
    assert seen[0].isdisjoint(seen[1])
    assert len(seen[0]) + len(seen[1]) == 8


def test_dataset_resume_state(tmp_path):
    conn = MemoryConnector()
    synthetic_corpus(conn, "corpus", vocab_size=50, seq_len=16,
                     n_records=32, records_per_shard=8)
    cfg = DataPipelineConfig(seq_len=16, batch_size=2)
    ds = ShardedTokenDataset(conn, "corpus", cfg)
    it = ds.batches()
    batches = [next(it) for _ in range(5)]
    state = ds.state()
    nxt = next(it)
    # new dataset restored from state must continue at the same point
    ds2 = ShardedTokenDataset(conn, "corpus", cfg)
    ds2.restore(state)
    nxt2 = next(ds2.batches())
    np.testing.assert_array_equal(nxt["tokens"], nxt2["tokens"])


def test_dataset_prefetch(tmp_path):
    conn = MemoryConnector()
    synthetic_corpus(conn, "corpus", vocab_size=50, seq_len=16,
                     n_records=16, records_per_shard=8)
    cfg = DataPipelineConfig(seq_len=16, batch_size=2, prefetch=2)
    ds = ShardedTokenDataset(conn, "corpus", cfg)
    got = [b for _, b in zip(range(6), ds.prefetching_batches())]
    assert len(got) == 6
