"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + prefill/decode on CPU, asserting shapes + no NaNs."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import SHAPES, build
from repro.models.registry import input_specs

#: architectures whose scaled-down smoke steps still take minutes on a
#: CPU runner — tier-1 CI skips them (-m "not slow"); the slow lane and
#: the full local suite keep running them
SLOW_ARCHS = {"jamba-1.5-large-398b", "whisper-medium",
              "llava-next-mistral-7b", "rwkv6-7b"}

ARCH_CASES = [pytest.param(a, marks=pytest.mark.slow) if a in SLOW_ARCHS
              else a for a in ARCH_IDS]


def small_cfg(arch_id):
    return get_config(arch_id).scaled_down()


def tiny_batch(cfg, B=2, S=64, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        batch["audio_embeds"] = jax.random.normal(
            ks[2], (B, cfg.encdec.n_audio_ctx, cfg.d_model), jnp.float32)
    if cfg.vlm is not None:
        batch["image_embeds"] = jax.random.normal(
            ks[2], (B, cfg.vlm.n_image_tokens, cfg.vlm.patch_dim),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_CASES)
def test_forward_loss_finite(arch_id):
    cfg = small_cfg(arch_id)
    api = build(cfg)
    params = jax.jit(api.init)(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)
    loss, metrics = jax.jit(api.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch_id, float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch_id", ARCH_CASES)
def test_train_step_grads_finite(arch_id):
    cfg = small_cfg(arch_id)
    api = build(cfg)
    params = jax.jit(api.init)(jax.random.PRNGKey(1))
    batch = tiny_batch(cfg, key=1)

    @jax.jit
    def step(p, b):
        (l, m), g = jax.value_and_grad(api.loss, has_aux=True)(p, b)
        return l, g

    loss, grads = step(params, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all(), arch_id


@pytest.mark.parametrize("arch_id", ARCH_CASES)
def test_prefill_decode_consistency(arch_id):
    """Greedy decode logits from (prefill -> decode_step) must match the
    full-sequence forward at the same position."""
    import dataclasses
    cfg = small_cfg(arch_id)
    if cfg.moe is not None:
        # decode-vs-full equivalence needs drop-free routing: with the
        # default capacity factor, tokens late in the sequence can be
        # dropped in the full pass but never in the 1-token decode pass.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    api = build(cfg)
    params = jax.jit(api.init)(jax.random.PRNGKey(2))
    B, S = 2, 32
    batch = tiny_batch(cfg, B=B, S=S, key=2)
    prefill_batch = {k: v for k, v in batch.items() if k != "labels"}
    max_seq = S + 4
    logits_p, cache, pos = jax.jit(
        lambda p, b: api.prefill(p, b, pad_to=max_seq))(params, prefill_batch)
    assert logits_p.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits_p)).all()

    # feed the next token; decode-step logits must be finite & shaped
    next_tok = jnp.argmax(logits_p[:, -1], axis=-1).astype(jnp.int32)
    logits_d, cache = jax.jit(api.decode)(params, cache,
                                          next_tok[:, None], jnp.int32(S))
    assert logits_d.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits_d)).all()

    # cross-check: running the extended sequence through prefill again
    # must produce the same last-token logits as the decode step
    ext = jnp.concatenate([batch["tokens"], next_tok[:, None]], axis=1)
    # pad to keep shapes chunk-friendly
    batch2 = dict(prefill_batch, tokens=ext)
    logits_full, _, _ = jax.jit(
        lambda p, b: api.prefill(p, b, pad_to=None))(params, batch2)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_input_specs_shapes(arch_id):
    cfg = get_config(arch_id)
    specs = input_specs(cfg, "train_4k")
    assert specs["batch"]["tokens"].shape == (256, 4096)
    d = input_specs(cfg, "decode_32k")
    assert d["token"].shape == (128, 1)
    # cache leaves must be well-formed ShapeDtypeStructs
    for leaf in jax.tree.leaves(d["cache"]):
        assert all(dim > 0 for dim in leaf.shape)


def test_param_count_sanity():
    """Full configs must land near their nameplate sizes (within 20%)."""
    expected = {
        "jamba-1.5-large-398b": 398e9,
        "dbrx-132b": 132e9,
        "granite-moe-1b-a400m": 1.3e9,
        "granite-20b": 20e9,
        "h2o-danube-3-4b": 4e9,
        "qwen1.5-110b": 111e9,
        "qwen1.5-0.5b": 0.46e9,
        "whisper-medium": 0.76e9,
        "rwkv6-7b": 7e9,
        "llava-next-mistral-7b": 7.2e9,
    }
    for arch_id, want in expected.items():
        cfg = get_config(arch_id)
        api = build(cfg)
        shapes = api.abstract_params()
        n = sum(np.prod(l.shape) for l in jax.tree.leaves(shapes))
        assert want * 0.8 < n < want * 1.25, (arch_id, n / 1e9)
