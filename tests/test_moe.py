"""MoE dispatch invariants."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.common import ArchConfig, MoEConfig
from repro.models.moe import capacity, moe_apply, moe_init


def mk_cfg(E=4, k=2, cf=1.25, d=32, f=64):
    return ArchConfig(arch_id="t", family="moe", n_layers=2, d_model=d,
                      n_heads=4, n_kv_heads=4, d_ff=f, vocab_size=64,
                      moe=MoEConfig(n_experts=E, top_k=k,
                                    capacity_factor=cf),
                      param_dtype="float32", compute_dtype="float32")


def test_capacity_formula():
    cfg = mk_cfg(E=8, k=2, cf=1.0)
    # 128 tokens * 2 slots / 8 experts = 32
    assert capacity(128, cfg) == 32
    cfg = mk_cfg(E=8, k=2, cf=1.25)
    assert capacity(128, cfg) == 40
    assert capacity(1, cfg) == 8  # floor


def test_moe_output_finite_and_shaped():
    cfg = mk_cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["moe_aux"]) > 0.0


def test_moe_no_drops_at_high_capacity():
    cfg = mk_cfg(cf=8.0)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = moe_apply(p, x, cfg)
    assert float(aux["moe_drop_frac"]) == 0.0


def test_moe_drops_at_tiny_capacity():
    cfg = mk_cfg(E=4, k=2, cf=0.3)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))
    y, aux = moe_apply(p, x, cfg)
    assert float(aux["moe_drop_frac"]) > 0.0
    assert np.isfinite(np.asarray(y)).all()


def test_moe_token_independence_at_high_capacity():
    """With no drops, each token's output is independent of the other
    tokens in the batch (routing is per-token)."""
    cfg = mk_cfg(cf=8.0)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y_full, _ = moe_apply(p, x, cfg)
    y_tok, _ = moe_apply(p, x[:, 3:4, :], cfg)
    np.testing.assert_allclose(np.asarray(y_full[:, 3]),
                               np.asarray(y_tok[:, 0]),
                               rtol=1e-4, atol=1e-5)


def test_moe_gate_renormalization():
    """Outputs scale with renormalized top-k gates: uniform router
    logits -> equal mixing."""
    cfg = mk_cfg(E=4, k=4, cf=8.0)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    p["router"]["w"] = jnp.zeros_like(p["router"]["w"])  # uniform routing
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y, aux = moe_apply(p, x, cfg)
    # equal-weight mixture of all experts == mean of per-expert FFNs
    outs = []
    for e in range(4):
        pe = {"router": p["router"],
              "wi": p["wi"][e:e + 1].repeat(4, 0),
              "wg": p["wg"][e:e + 1].repeat(4, 0),
              "wo": p["wo"][e:e + 1].repeat(4, 0)}
        ye, _ = moe_apply(pe, x, cfg)
        outs.append(np.asarray(ye))
    np.testing.assert_allclose(np.asarray(y), np.mean(outs, axis=0),
                               rtol=1e-3, atol=1e-4)
