"""Service plane: StatusBus subscription semantics, the digest etag,
and the PR's three foregrounded bug regressions (uncapped saturation,
condition-variable wait_all, model-clock event timestamps).

Bus/etag tests carry the ``svc`` marker (their own CI lane); the bug
regressions are unmarked so they run in tier-1.
"""

import os
import threading
import time

import pytest

from repro.connectors import MemoryConnector
from repro.core import (CredentialStore, Endpoint, TransferManager,
                        TransferOptions)
from repro.core.clock import Clock
from repro.core.transfer import TransferTask
from repro.fed import FederatedCoordinator, RebalancePolicy, TransferSpec
from repro.svc import StatusBus

KB = 1024

svc = pytest.mark.svc


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------
def make_manager(tmp_path, **kw):
    kw.setdefault("max_workers", 4)
    kw.setdefault("per_endpoint_cap", 2)
    return TransferManager(credential_store=CredentialStore(),
                           marker_root=os.path.join(str(tmp_path), "markers"),
                           clock=Clock(scale=0.0), **kw)


def seed_memory(files):
    conn = MemoryConnector()
    for name, payload in files.items():
        conn.store.put(name, payload)
    return conn


class GatedDst(MemoryConnector):
    """Destination whose data plane blocks until ``release()`` — holds
    tasks in the running state for as long as a test needs."""

    def __init__(self):
        super().__init__()
        self.gate = threading.Event()
        self.entered = threading.Semaphore(0)

    def release(self):
        self.gate.set()

    def recv(self, session, path, channel):
        self.entered.release()
        assert self.gate.wait(60)
        return super().recv(session, path, channel)

    def recv_batch(self, session, paths, channel_factory):
        self.entered.release()
        assert self.gate.wait(60)
        return super().recv_batch(session, paths, channel_factory)


FAST = TransferOptions(startup_cost=0.0, concurrency=1,
                       coalesce_threshold=0)


# --------------------------------------------------------------------------
# StatusBus semantics (svc lane)
# --------------------------------------------------------------------------
@svc
def test_slow_subscriber_drop_oldest_exact():
    bus = StatusBus(site_id="s")
    sub = bus.subscribe(capacity=4)
    for i in range(10):
        bus.publish("progress", task_id=f"t{i}")
    assert sub.dropped == 6
    events = sub.poll()
    # the tail survived, oldest-first, and the seq gap equals dropped
    assert [e.task_id for e in events] == ["t6", "t7", "t8", "t9"]
    assert events[0].seq == 6
    assert len(sub) == 0
    # after a drain the ring accepts new events without further drops
    bus.publish("done", task_id="t10")
    assert sub.dropped == 6
    assert [e.task_id for e in sub.poll()] == ["t10"]


@svc
def test_unsubscribe_frees_buffer_and_stops_delivery():
    bus = StatusBus()
    keep = bus.subscribe()
    gone = bus.subscribe()
    bus.publish("queued", task_id="a")
    assert len(gone) == 1
    gone.close()
    assert bus.subscribers == 1
    assert len(gone) == 0  # buffer freed, not just detached
    bus.publish("queued", task_id="b")
    assert len(gone) == 0
    assert [e.task_id for e in keep.poll()] == ["a", "b"]
    # idempotent
    gone.close()
    assert bus.subscribers == 1


@svc
def test_subscription_filters_and_blocking_next():
    clock = Clock(scale=0.0)
    bus = StatusBus(site_id="x", clock=clock)
    only_done = bus.subscribe(types=("done", "failed"))
    only_t1 = bus.subscribe(task_id="t1")
    bus.publish("queued", task_id="t1")
    bus.publish("done", task_id="t2")
    assert [e.type for e in only_done.poll()] == ["done"]
    assert [(e.type, e.task_id) for e in only_t1.poll()] == [("queued", "t1")]

    sub = bus.subscribe()
    got = []
    t = threading.Thread(target=lambda: got.append(sub.next(timeout=30)))
    t.start()
    clock.sleep(1.5)
    bus.publish("progress", task_id="t3", data={"bytes_done": 7})
    t.join(30)
    assert not t.is_alive()
    ev = got[0]
    assert ev.type == "progress" and ev.task_id == "t3"
    assert ev.t == pytest.approx(1.5)  # model-time stamp
    assert ev.site_id == "x"


@svc
def test_manager_streams_lifecycle_events(tmp_path):
    files = {f"d/f{i}.bin": b"x" * (2 * KB) for i in range(3)}
    src = seed_memory(files)
    mgr = make_manager(tmp_path)
    sub = mgr.bus.subscribe(capacity=512)
    task = mgr.submit(Endpoint(src, "d", "src"),
                      Endpoint(MemoryConnector(), "out", "dst"),
                      FAST, task_id="lc-1", sync=True)
    assert task.status == task.SUCCEEDED
    events = [e for e in sub.poll() if e.task_id == "lc-1"]
    types = [e.type for e in events]
    assert types[0] == "queued"
    assert types[1] == "dispatched"
    assert types[-1] == "done"
    assert "progress" in types
    # progress events carry byte counts and land between dispatch/done
    prog = [e for e in events if e.type == "progress"]
    assert prog[-1].data["bytes_done"] == task.stats.bytes_total
    # model-time stamps, monotone non-decreasing through the lifecycle
    ts = [e.t for e in events]
    assert ts == sorted(ts)
    mgr.shutdown(wait=False)


@svc
def test_manager_streams_pause_resume_cancel(tmp_path):
    files = {"d/a.bin": b"x" * KB}
    src = seed_memory(files)
    dst = GatedDst()
    mgr = make_manager(tmp_path, max_workers=1, per_endpoint_cap=None)
    sub = mgr.bus.subscribe()
    # q1 occupies the single worker; q2/q3 stay queued
    mgr.submit(Endpoint(src, "d", "src"), Endpoint(dst, "o1", "d1"),
               FAST, task_id="q1")
    assert dst.entered.acquire(timeout=30)
    mgr.submit(Endpoint(src, "d", "src"), Endpoint(dst, "o2", "d2"),
               FAST, task_id="q2")
    mgr.submit(Endpoint(src, "d", "src"), Endpoint(dst, "o3", "d3"),
               FAST, task_id="q3")
    assert mgr.pause("q2")
    assert mgr.resume("q2")
    assert mgr.cancel("q3")
    dst.release()
    assert mgr.wait_all(timeout=60)
    seen = [(e.type, e.task_id) for e in sub.poll()]
    assert ("paused", "q2") in seen
    assert ("resumed", "q2") in seen
    assert ("cancelled", "q3") in seen
    assert ("done", "q1") in seen and ("done", "q2") in seen
    mgr.shutdown(wait=False)


# --------------------------------------------------------------------------
# digest etag (svc lane)
# --------------------------------------------------------------------------
@svc
def test_digest_etag_stable_until_queue_mutates(tmp_path):
    files = {"d/a.bin": b"x" * KB}
    src = seed_memory(files)
    dst = GatedDst()
    mgr = make_manager(tmp_path, max_workers=1, per_endpoint_cap=None)
    mgr.submit(Endpoint(src, "d", "src"), Endpoint(dst, "o1", "d1"),
               FAST, task_id="e1")
    assert dst.entered.acquire(timeout=30)

    d1 = mgr.digest()
    h0 = mgr.metrics.digest_hits
    d2 = mgr.digest()
    d3 = mgr.digest()
    # no queue mutation: same snapshot object, no recompute
    assert d2 is d1 and d3 is d1
    assert mgr.metrics.digest_hits == h0 + 2

    # every queue mutation bumps the etag
    etags = [d1["etag"]]
    mgr.submit(Endpoint(src, "d", "src"), Endpoint(dst, "o2", "d2"),
               FAST, task_id="e2")
    etags.append(mgr.digest()["etag"])
    assert mgr.pause("e2")
    etags.append(mgr.digest()["etag"])
    assert mgr.resume("e2")
    etags.append(mgr.digest()["etag"])
    assert mgr.cancel("e2")
    etags.append(mgr.digest()["etag"])
    assert etags == sorted(etags) and len(set(etags)) == len(etags)

    # fresh=True recomputes without inventing a new generation
    f = mgr.digest(fresh=True)
    assert f["etag"] == etags[-1]
    dst.release()
    assert mgr.wait_all(timeout=60)
    mgr.shutdown(wait=False)


@svc
def test_coordinator_reuses_digest_across_noop_beats(tmp_path):
    clock = Clock(scale=0.0)
    eps = {"src-ep": seed_memory({"d/a.bin": b"x"}),
           "dst-ep": MemoryConnector()}
    coord = FederatedCoordinator(placement="owner")
    mgr = make_manager(tmp_path, per_endpoint_cap=None)
    site = coord.register_site("a", mgr, eps)

    coord.beat()
    seq1 = site.digest.seq
    reuses0 = coord.metrics.digest_reuses
    coord.beat()
    coord.beat()
    # no queue mutation between beats: the QueueDigest was reused, not
    # rebuilt (seq unchanged), and the manager answered from cache
    assert site.digest.seq == seq1
    assert coord.metrics.digest_reuses == reuses0 + 2
    assert mgr.metrics.digest_hits >= 2

    # a real submission invalidates: the next beat rebuilds
    spec = TransferSpec.new("b-1", "src-ep", "d", "dst-ep", "out",
                            options=FAST)
    coord.submit(spec.to_json(), sync=True)
    coord.beat()
    assert site.digest.seq > seq1
    coord.shutdown(wait=False)


# --------------------------------------------------------------------------
# regression 1: uncapped saturation (unmarked -> tier-1)
# --------------------------------------------------------------------------
def test_uncapped_digest_reports_busy_saturation(tmp_path):
    """per_endpoint_cap=None used to report saturation 0.0 for every
    endpoint, making a fully-busy uncapped site look idle."""
    files = {"d/a.bin": b"x" * KB}
    src = seed_memory(files)
    dst = GatedDst()
    mgr = make_manager(tmp_path, max_workers=2, per_endpoint_cap=None)
    for i in range(2):
        mgr.submit(Endpoint(src, "d", "src"), Endpoint(dst, f"o{i}", "dst"),
                   FAST, task_id=f"sat-{i}")
    assert dst.entered.acquire(timeout=30)
    assert dst.entered.acquire(timeout=30)
    sat = mgr.digest(fresh=True)["saturation"]
    # both endpoints are at the full worker budget: saturation 1.0
    assert sat and all(v == pytest.approx(1.0) for v in sat.values()), sat
    dst.release()
    assert mgr.wait_all(timeout=60)
    mgr.shutdown(wait=False)


def test_uncapped_busy_site_does_not_win_placement(tmp_path):
    """Rebalance placement must see an uncapped busy site as hot and
    migrate its queued spec to an idle peer — before the fix the busy
    site's signal was 0 and the queued task stayed put."""
    clock = Clock(scale=0.0)
    src = seed_memory({"d/a.bin": b"x" * KB})
    dst = GatedDst()
    eps = {"src-ep": src, "dst-ep": dst}

    def site(name):
        return TransferManager(
            credential_store=CredentialStore(), max_workers=2,
            per_endpoint_cap=None,
            marker_root=os.path.join(str(tmp_path), f"markers-{name}"),
            clock=clock, site_id=name)

    coord = FederatedCoordinator(
        placement="owner",
        rebalance=RebalancePolicy(enter=0.75, exit=0.35, dwell=0.0,
                                  max_moves=2, move_cooldown=0.0))
    coord.register_site("busy", site("busy"), eps,
                        owns={"src-ep", "dst-ep"})
    coord.register_site("idle", site("idle"), eps, owns=set())

    # two gated tasks fill the busy site's worker budget; a third queues
    for i in range(3):
        spec = TransferSpec.new(f"rb-{i}", "src-ep", "d", "dst-ep",
                                f"out{i}", options=FAST)
        coord.submit(spec.to_json())
    assert dst.entered.acquire(timeout=30)
    assert dst.entered.acquire(timeout=30)
    assert all(coord.site_of(f"rb-{i}") == "busy" for i in range(3))

    coord.exchange_digests()
    moved = coord.maybe_rebalance()
    assert ("rb-2", "busy", "idle") in moved, moved
    assert coord.site_of("rb-2") == "idle"

    dst.release()
    assert coord.wait_all(timeout=60)
    coord.assert_third_party()
    coord.shutdown(wait=False)


# --------------------------------------------------------------------------
# regression 2: wait_all is notification-driven (unmarked -> tier-1)
# --------------------------------------------------------------------------
def test_wait_all_does_not_slice_poll(tmp_path, monkeypatch):
    """The old wait_all re-polled ``pending[0].wait(0.02)`` on wall
    time; the rewrite blocks on the manager condition variable and
    never touches task.wait at all."""
    assert not hasattr(TransferManager, "WAIT_SLICE")

    files = {"d/a.bin": b"x" * KB}
    src = seed_memory(files)
    dst = GatedDst()
    mgr = make_manager(tmp_path, max_workers=1, per_endpoint_cap=None)
    mgr.submit(Endpoint(src, "d", "src"), Endpoint(dst, "o1", "d1"),
               FAST, task_id="w1")
    assert dst.entered.acquire(timeout=30)

    wait_calls = []
    orig_wait = TransferTask.wait

    def spying_wait(self, timeout=None):
        wait_calls.append(timeout)
        return orig_wait(self, timeout)

    monkeypatch.setattr(TransferTask, "wait", spying_wait)
    done = []
    waiter = threading.Thread(
        target=lambda: done.append(mgr.wait_all(timeout=60)))
    waiter.start()
    time.sleep(0.15)  # long enough for the old code to slice many times
    assert not done, "wait_all returned while the task was still gated"
    dst.release()
    waiter.join(60)
    assert done == [True]
    assert wait_calls == [], \
        f"wait_all fell back to polling task.wait: {wait_calls[:5]}"
    mgr.shutdown(wait=False)


def test_wait_all_excludes_paused_and_wakes_on_pause(tmp_path):
    """A task leaving the pending set by pausing (not finishing) must
    wake wait_all — the cv notify covers every queue mutation."""
    files = {"d/a.bin": b"x" * KB}
    src = seed_memory(files)
    dst = GatedDst()
    mgr = make_manager(tmp_path, max_workers=1, per_endpoint_cap=None)
    mgr.submit(Endpoint(src, "d", "src"), Endpoint(dst, "o1", "d1"),
               FAST, task_id="p1")
    assert dst.entered.acquire(timeout=30)
    mgr.submit(Endpoint(src, "d", "src"), Endpoint(dst, "o2", "d2"),
               FAST, task_id="p2")
    done = []
    waiter = threading.Thread(
        target=lambda: done.append(mgr.wait_all(timeout=60)))
    waiter.start()
    # pausing the queued task removes it from the pending set; with p1
    # still gated wait_all must keep waiting, then return when p1 lands
    assert mgr.pause("p2")
    time.sleep(0.05)
    assert not done
    dst.release()
    waiter.join(60)
    assert done == [True]
    assert mgr.get("p2").status == TransferTask.PAUSED
    mgr.shutdown(wait=False)


# --------------------------------------------------------------------------
# regression 3: model-clock event timestamps (unmarked -> tier-1)
# --------------------------------------------------------------------------
def _timestamp_run(tmp_path, tag):
    clock = Clock(scale=0.0)
    src = seed_memory({"d/a.bin": b"y" * (8 * KB)})
    mgr = TransferManager(
        credential_store=CredentialStore(), max_workers=1,
        per_endpoint_cap=None,
        marker_root=os.path.join(str(tmp_path), f"markers-{tag}"),
        clock=clock, site_id=tag)
    opts = TransferOptions(startup_cost=0.5, concurrency=1,
                           coalesce_threshold=0)
    task = mgr.submit(Endpoint(src, "d", "src"),
                      Endpoint(MemoryConnector(), "out", "dst"),
                      opts, task_id="ts-1", sync=True)
    assert task.status == task.SUCCEEDED
    mgr.shutdown(wait=False)
    return task, clock


def test_event_timestamps_are_model_time_and_deterministic(tmp_path):
    """events/_rate_samples used to be stamped with time.monotonic();
    two same-seed runs now produce byte-identical timelines, and every
    stamp lies within the run's model-time span."""
    t1, c1 = _timestamp_run(tmp_path, "run1")
    t2, c2 = _timestamp_run(tmp_path, "run2")
    assert t1.events == t2.events
    assert list(t1._rate_samples) == list(t2._rate_samples)
    # model-time stamps: bounded by the clock's virtual span (wall
    # monotonic stamps would be ~machine-uptime, far outside it)
    span = c1.virtual_elapsed
    assert span > 0.0
    assert all(0.0 <= ts <= span for ts, _ in t1.events)
    assert all(0.0 <= ts <= span for ts, _ in t1._rate_samples)
