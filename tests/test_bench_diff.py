"""The bench-regression gate (benchmarks.diff): identical results pass,
an injected 20% goodput regression fails with a nonzero exit, and a
vanished guarded metric fails too.  Runs unmarked in tier-1 — the gate
itself must never regress silently."""

import copy
import json
import os

from benchmarks.diff import GUARDS, compare, format_table, load_suites, main

#: synthetic results covering every guarded metric, shaped exactly like
#: the BENCH_<suite>.json files benchmarks.run writes
BASE = {
    "federation": {
        "fanout": {"moved_ratio": 1.0, "hit_rate": 0.75,
                   "bytes_not_moved_frac": 0.75},
        "goodput": {"2": {"goodput_mb_s": 100.0}},
    },
    "perfile": {
        "s3/conn-local/up": {"rho": 0.99, "t0_speedup": 10.0},
    },
    "obs": {
        "goodput_ratio": 0.98,
    },
}


def test_guards_all_covered_by_fixture():
    # the fixture must exercise every guard, or the tests below prove
    # nothing about new guards
    rows = compare(BASE, copy.deepcopy(BASE))
    assert len(rows) == len(GUARDS)
    assert all(r["status"] == "ok" for r in rows), rows


def test_identical_results_pass():
    rows = compare(BASE, copy.deepcopy(BASE))
    assert not [r for r in rows if r["status"] in ("regressed", "missing")]
    assert "ok" in format_table(rows)


def test_injected_goodput_regression_fails():
    cur = copy.deepcopy(BASE)
    cur["federation"]["goodput"]["2"]["goodput_mb_s"] = 80.0  # -20%
    bad = [r for r in compare(BASE, cur) if r["status"] == "regressed"]
    assert [r["metric"] for r in bad] == ["goodput.2.goodput_mb_s"]
    assert "regressed" in format_table(compare(BASE, cur))


def test_within_tolerance_wiggle_passes():
    cur = copy.deepcopy(BASE)
    cur["federation"]["goodput"]["2"]["goodput_mb_s"] = 90.0  # -10% < 15%
    cur["perfile"]["s3/conn-local/up"]["rho"] = 0.97
    assert not [r for r in compare(BASE, cur)
                if r["status"] in ("regressed", "missing")]


def test_vanished_metric_fails_and_new_metric_skips():
    cur = copy.deepcopy(BASE)
    del cur["federation"]["fanout"]["hit_rate"]
    rows = compare(BASE, cur)
    assert [r["metric"] for r in rows if r["status"] == "missing"] \
        == ["fanout.hit_rate"]
    # no baseline yet: reported as "new", never a failure
    baseline = copy.deepcopy(BASE)
    del baseline["perfile"]
    rows = compare(baseline, copy.deepcopy(BASE))
    assert [r["suite"] for r in rows if r["status"] == "new"] \
        == ["perfile", "perfile"]
    assert not [r for r in rows if r["status"] in ("regressed", "missing")]


def _write_dirs(tmp_path, baselines, currents):
    base_dir = os.path.join(str(tmp_path), "base")
    cur_dir = os.path.join(str(tmp_path), "cur")
    for d, payload in ((base_dir, baselines), (cur_dir, currents)):
        os.makedirs(d, exist_ok=True)
        for suite, data in payload.items():
            with open(os.path.join(d, f"BENCH_{suite}.json"), "w") as f:
                json.dump(data, f)
    return base_dir, cur_dir


def test_cli_exit_codes(tmp_path, monkeypatch, capsys):
    regressed = copy.deepcopy(BASE)
    regressed["federation"]["goodput"]["2"]["goodput_mb_s"] = 80.0
    base_dir, cur_dir = _write_dirs(tmp_path, BASE, regressed)

    monkeypatch.setattr("sys.argv", ["diff", "--baseline-dir", base_dir,
                                     "--current-dir", base_dir])
    assert main() == 0
    monkeypatch.setattr("sys.argv", ["diff", "--baseline-dir", base_dir,
                                     "--current-dir", cur_dir])
    assert main() == 1
    out = capsys.readouterr()
    assert "regressed" in out.out
    # no baselines at all is a usage error, not a silent pass
    monkeypatch.setattr("sys.argv", ["diff", "--baseline-dir", cur_dir
                                     + "-nope", "--current-dir", cur_dir])
    assert main() == 2


def test_committed_baselines_satisfy_guard_paths():
    """Every guard path must resolve in the committed BENCH_*.json —
    otherwise the CI gate silently skips it as 'new' forever."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    suites = sorted({g.suite for g in GUARDS})
    baselines = load_suites(repo, suites)
    rows = compare(baselines, baselines)
    assert all(r["status"] == "ok" for r in rows), rows
