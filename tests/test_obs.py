"""Observability plane: model-time tracing, the metrics registry, and
per-task time-budget attribution.

Covers the tentpole invariants end-to-end: spans ride the charge-owner
machinery across pool/sender threads, trace ids survive federation
handoff, same-seed runs export byte-identical canonical traces, and
``TaskStats.time_budget()`` decomposes ``actual_model_seconds`` exactly
(within float tolerance) on chaos fleets.  Plus the satellites: bounded
event/rate-sample rings with exact dropped counters, and lint rule
R006 (``Tracer.span`` is a ``with`` context manager ONLY).

Everything here carries the ``obs`` marker (its own CI lane).
"""

import json
import os
import textwrap
import threading

import pytest

from repro.connectors import MemoryConnector
from repro.core import (CredentialStore, Endpoint, FaultSchedule,
                        TransferManager, TransferOptions)
from repro.core.clock import Clock, bind_charge_owner, charge_to
from repro.core.transfer import TransferTask
from repro.fed import TransferSpec
from repro.lint.engine import run_lint
from repro.obs import (CATEGORIES, DEFAULT_BUCKETS, MetricsRegistry,
                       NULL_TRACER, Tracer)
from repro.sim import ScenarioRunner

KB = 1024

pytestmark = pytest.mark.obs


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------
def make_manager(tmp_path, **kw):
    kw.setdefault("max_workers", 4)
    kw.setdefault("per_endpoint_cap", 2)
    kw.setdefault("clock", Clock(scale=0.0))
    return TransferManager(credential_store=CredentialStore(),
                           marker_root=os.path.join(str(tmp_path), "markers"),
                           **kw)


def seed_memory(files):
    conn = MemoryConnector()
    for name, payload in files.items():
        conn.store.put(name, payload)
    return conn


def run_fleet(tmp_path, n_tasks=3, n_files=4, **mgr_kw):
    """Small traced fleet over the per-file data plane; returns
    (manager, tasks)."""
    src = seed_memory({f"t{t}/f{i}.bin": bytes([t]) * (8 * KB)
                       for t in range(n_tasks) for i in range(n_files)})
    dst = MemoryConnector()
    mgr = make_manager(tmp_path, **mgr_kw)
    opts = TransferOptions(startup_cost=0.0, concurrency=2,
                           coalesce_threshold=0)
    tasks = [mgr.submit(Endpoint(src, f"t{t}", f"src{t}"),
                        Endpoint(dst, f"out/t{t}", f"dst{t}"),
                        opts, task_id=f"obs-{t}",
                        tenant=("alice", "bob")[t % 2])
             for t in range(n_tasks)]
    assert mgr.wait_all(timeout=120)
    return mgr, tasks


# --------------------------------------------------------------------------
# tracer unit semantics
# --------------------------------------------------------------------------
def test_span_outside_binding_records_nothing():
    tracer = Tracer(clock=Clock(scale=0.0))
    with tracer.span("orphan", "wire"):
        pass
    assert tracer.spans_recorded == 0


def test_bind_and_span_attach_and_tally():
    clock = Clock(scale=0.0)
    tracer = Tracer(clock=clock)
    with tracer.bind("trace-1", "t1"):
        with charge_to("t1"):
            with tracer.span("send", "wire", path="a.bin"):
                clock.sleep(0.5)
    spans = tracer.spans()
    assert [(s.trace_id, s.task_id, s.name, s.category)
            for s in spans] == [("trace-1", "t1", "send", "wire")]
    assert spans[0].self_seconds == pytest.approx(0.5)
    assert tracer.category_seconds("t1") == {"wire": pytest.approx(0.5)}
    tracer.forget("t1")
    assert tracer.category_seconds("t1") == {}


def test_nested_span_charges_innermost_only():
    clock = Clock(scale=0.0)
    tracer = Tracer(clock=clock)
    with tracer.bind("trace-1", "t1"):
        with charge_to("t1"):
            with tracer.span("outer", "overhead"):
                clock.sleep(1.0)
                with tracer.span("inner", "integrity"):
                    clock.sleep(0.25)
                clock.sleep(0.5)
    per = tracer.category_seconds("t1")
    assert per["integrity"] == pytest.approx(0.25)
    assert per["overhead"] == pytest.approx(1.5)


def test_disabled_tracer_is_inert():
    clock = Clock(scale=0.0)
    tracer = Tracer(clock=clock, enabled=False)
    with tracer.bind("trace-1", "t1"):
        with tracer.span("send", "wire"):
            clock.sleep(0.5)
    assert tracer.spans_recorded == 0
    assert tracer.category_seconds("t1") == {}
    assert NULL_TRACER.enabled is False


def test_record_is_charge_free():
    tracer = Tracer(clock=Clock(scale=0.0))
    tracer.record("queue-wait", "queue", 1.0, 3.5,
                  trace_id="trace-1", task_id="t1", tenant="alice")
    assert tracer.spans_recorded == 1
    # observed windows never feed the time-budget tally
    assert tracer.category_seconds("t1") == {}
    span = tracer.spans()[0]
    assert (span.t0, span.t1) == (1.0, 3.5)
    assert span.self_seconds == 0.0


def test_span_ring_bounded_with_exact_drop_count():
    tracer = Tracer(clock=Clock(scale=0.0), max_spans=4)
    for i in range(10):
        tracer.record(f"w{i}", "queue", 0.0, 0.0, task_id="t1")
    assert len(tracer.spans()) == 4
    assert tracer.spans_dropped == 6
    assert tracer.spans_recorded == 10
    # survivors are the newest
    assert [s.name for s in tracer.spans()] == ["w6", "w7", "w8", "w9"]


def test_charge_crosses_threads_via_bind_charge_owner():
    clock = Clock(scale=0.0)
    tracer = Tracer(clock=clock)
    with tracer.bind("trace-1", "t1"):
        with charge_to("t1"):
            with tracer.span("pool-op", "wire"):
                # capture owner + span context exactly like the
                # connector pools do, run the work on a foreign thread
                fn = bind_charge_owner(lambda: clock.sleep(0.75))
                th = threading.Thread(target=fn)
                th.start()
                th.join()
    assert tracer.category_seconds("t1") == {"wire": pytest.approx(0.75)}
    assert clock.charged("t1") == pytest.approx(0.75)


# --------------------------------------------------------------------------
# exports
# --------------------------------------------------------------------------
def _trace_some(tracer, clock):
    with tracer.bind("trace-1", "t1"):
        with charge_to("t1"):
            with tracer.span("send", "wire", path="a.bin"):
                clock.sleep(0.5)
            with tracer.span("verify", "integrity", path="a.bin"):
                clock.sleep(0.125)
    tracer.record("queue-wait", "queue", 0.0, 0.25,
                  trace_id="trace-1", task_id="t1", tenant="alice")


def test_jsonl_export_sorted_and_stable(tmp_path):
    paths = []
    for i in range(2):
        clock = Clock(scale=0.0)
        tracer = Tracer(clock=clock)
        _trace_some(tracer, clock)
        p = str(tmp_path / f"trace{i}.jsonl")
        n = tracer.export_jsonl(p)
        assert n == 3
        paths.append(p)
    a, b = (open(p, "rb").read() for p in paths)
    assert a == b
    lines = [json.loads(line) for line in a.decode().splitlines()]
    # sorted by semantic key: category-major (integrity < queue < wire)
    assert [ln["name"] for ln in lines] == ["verify", "queue-wait", "send"]
    for ln in lines:
        assert set(ln) == {"trace_id", "task_id", "name", "category",
                           "attrs", "self_seconds"}


def test_chrome_export_is_loadable_trace_event_json(tmp_path):
    clock = Clock(scale=0.0)
    tracer = Tracer(clock=clock)
    _trace_some(tracer, clock)
    p = str(tmp_path / "trace.json")
    n = tracer.export_chrome(p)
    with open(p) as fh:
        doc = json.load(fh)
    events = doc["traceEvents"]
    assert len(events) == n == 3
    for ev in events:
        assert ev["ph"] == "X"
        assert ev["ts"] >= 0 and ev["dur"] >= 0
        assert ev["pid"] == "t1" or ev["pid"] == "trace-1"
    send = next(ev for ev in events if ev["name"] == "send")
    assert send["dur"] == pytest.approx(0.5e6)


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------
def test_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("tasks_total", "finished tasks")
    c.inc(site="a", status="SUCCEEDED")
    c.inc(site="a", status="SUCCEEDED")
    c.inc(site="b", status="FAILED")
    g = reg.gauge("queue_depth", "")
    g.set(7, site="a")
    h = reg.histogram("task_model_seconds", "")
    for v in (0.05, 0.5, 5.0):
        h.observe(v, site="a")
    snap = reg.snapshot()
    assert snap["repro_tasks_total"]['{site="a",status="SUCCEEDED"}'] == 2.0
    assert snap["repro_tasks_total"]['{site="b",status="FAILED"}'] == 1.0
    assert snap["repro_queue_depth"]['{site="a"}'] == 7.0
    hist = snap["repro_task_model_seconds"]['{site="a"}']
    assert hist["count"] == 3
    assert hist["sum"] == pytest.approx(5.55)


def test_histogram_buckets_fixed_and_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "")
    h.observe(0.004)
    h.observe(100.0)
    snap = reg.snapshot()["repro_lat"][""]
    buckets = snap["buckets"]
    assert tuple(sorted(buckets)) == DEFAULT_BUCKETS
    # cumulative, le-style: every bound >= 0.004 counts the small
    # sample; 100.0 first lands at the 300 s bound
    assert buckets[0.005] == 1
    assert buckets[0.1] == 1
    assert buckets[1800.0] == 2
    assert snap["count"] == 2


def test_registry_type_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x", "")
    with pytest.raises(TypeError):
        reg.gauge("x", "")


def test_scrape_is_deterministic_and_prometheus_shaped():
    def build():
        reg = MetricsRegistry()
        c = reg.counter("tasks_total", "done")
        c.inc(tenant="b")
        c.inc(tenant="a")
        reg.histogram("secs", "").observe(1.0, site="s")
        return reg.scrape()
    a, b = build(), build()
    assert a == b
    assert 'repro_tasks_total{tenant="a"} 1' in a
    assert "# TYPE repro_tasks_total counter" in a
    assert 'repro_secs_bucket{le="+Inf",site="s"} 1' in a


def test_register_collector_feeds_snapshot():
    reg = MetricsRegistry()
    reg.register_collector(lambda: {"bus_published": 42,
                                    "depth_by_site": {"x": 7}})
    reg.register_collector(lambda: 1 / 0)  # raising collector: skipped
    snap = reg.snapshot()
    assert snap["repro_bus_published"] == 42
    assert snap["repro_depth_by_site"] == {"x": 7}
    assert "repro_bus_published 42" in reg.scrape()


# --------------------------------------------------------------------------
# bounded task rings (satellite a)
# --------------------------------------------------------------------------
def test_task_event_ring_bounded_with_exact_drop_count(monkeypatch):
    monkeypatch.setattr(TransferTask, "EVENTS_WINDOW", 8)
    task = TransferTask("t1", clock=Clock(scale=0.0))
    for i in range(20):
        task.log(f"event {i}")
    events = task.events
    assert len(events) == 8
    assert task.events_dropped == 12
    assert [msg for _, msg in events] == [f"event {i}"
                                          for i in range(12, 20)]


def test_rate_sample_ring_bounded_with_exact_drop_count(monkeypatch):
    monkeypatch.setattr(TransferTask, "RATE_WINDOW", 8)
    task = TransferTask("t1", clock=Clock(scale=0.0))
    task.stats.bytes_total = 20
    for _ in range(20):
        task._bytes_tick(1)
    assert len(task._rate_samples) == 8
    assert task.rate_samples_dropped == 12


# --------------------------------------------------------------------------
# manager integration: budgets, trace ids, metrics stream
# --------------------------------------------------------------------------
def test_fleet_budgets_sum_exactly_and_spans_attach(tmp_path):
    mgr, tasks = run_fleet(tmp_path)
    tracer = mgr.tracer
    assert tracer.spans_recorded > len(tasks)
    by_task = {}
    for s in tracer.spans():
        if s.task_id:
            by_task.setdefault(s.task_id, set()).add(s.trace_id)
    for task in tasks:
        assert task.status == task.SUCCEEDED
        assert task.trace_id == f"trace-{task.task_id}"
        # spans from this task's pool/sender threads all carry ITS
        # trace id — attribution never bleeds across fleet-mates
        assert by_task[task.task_id] == {task.trace_id, ""} \
            or by_task[task.task_id] == {task.trace_id}
        budget = task.stats.time_budget()
        total = sum(budget.values())
        assert abs(total - task.stats.actual_model_seconds) < 1e-6
        assert set(budget) - {"other"} <= set(CATEGORIES)
        # the per-file data plane slept under wire/overhead spans
        assert task.stats.span_seconds
    # finished tasks were forgotten from the live tally table
    for task in tasks:
        assert tracer.category_seconds(task.task_id) == {}


def test_queue_wait_span_recorded(tmp_path):
    mgr, tasks = run_fleet(tmp_path, max_workers=1)
    waits = [s for s in mgr.tracer.spans() if s.name == "queue-wait"]
    assert {s.task_id for s in waits} == {t.task_id for t in tasks}
    for s in waits:
        assert s.category == "queue"
        assert s.self_seconds == 0.0  # observed, not charged


def test_metrics_events_published_on_bus(tmp_path):
    src = seed_memory({f"t{t}/f.bin": b"x" * KB for t in range(4)})
    dst = MemoryConnector()
    mgr = make_manager(tmp_path, metrics_every=2)
    sub = mgr.bus.subscribe(types=("metrics",))
    opts = TransferOptions(startup_cost=0.0)
    for t in range(4):
        mgr.submit(Endpoint(src, f"t{t}", f"s{t}"),
                   Endpoint(dst, f"o/t{t}", f"d{t}"),
                   opts, task_id=f"m-{t}")
    assert mgr.wait_all(timeout=120)
    events = sub.poll()
    assert len(events) == 2  # every 2 completions
    snap = events[-1].data
    counted = sum(v for labels, v in snap["repro_tasks_total"].items()
                  if 'status="SUCCEEDED"' in labels)
    assert counted == 4
    assert 'repro_tasks_total' in mgr.scrape()


def test_manager_shares_service_tracer_and_health(tmp_path):
    from repro.core.health import EndpointHealth
    clock = Clock(scale=0.0)
    health = EndpointHealth(clock=clock)
    tracer = Tracer(clock=clock)
    mgr = make_manager(tmp_path, clock=clock, health=health, tracer=tracer)
    assert mgr.tracer is tracer
    assert mgr.service.tracer is tracer
    assert health.tracer is tracer


# --------------------------------------------------------------------------
# federation: trace ids travel (satellite c)
# --------------------------------------------------------------------------
def test_transfer_spec_round_trips_trace_id():
    spec = TransferSpec(task_id="t1", src_endpoint="a", src_path="p",
                        dst_endpoint="b", dst_path="q",
                        trace_id="trace-t1")
    payload = json.loads(json.dumps(spec.to_payload()))
    assert TransferSpec.from_payload(payload).trace_id == "trace-t1"
    # absent on the wire (older peer) -> empty, never a crash
    payload.pop("trace_id")
    assert TransferSpec.from_payload(payload).trace_id == ""


def test_trace_id_survives_export_import(tmp_path):
    src = seed_memory({"t0/f.bin": b"x" * KB})
    dst = MemoryConnector()
    a = make_manager(tmp_path / "a", max_workers=1)
    b = make_manager(tmp_path / "b", max_workers=1)
    # keep it queued on a busy site so export_state can take it
    blocker = a.submit(Endpoint(seed_memory({"t/f.bin": b"y" * KB}), "t",
                                "bsrc"),
                       Endpoint(MemoryConnector(), "o", "bdst"),
                       TransferOptions(startup_cost=0.0), task_id="blk")
    task = a.submit(Endpoint(src, "t0", "s0"), Endpoint(dst, "o/t0", "d0"),
                    TransferOptions(startup_cost=0.0), task_id="mv")
    trace_id = task.trace_id
    assert trace_id == "trace-mv"
    payload = a.export_state("mv")
    assert payload is not None and payload["trace_id"] == trace_id
    adopted = b.import_state(payload, Endpoint(src, "t0", "s0"),
                             Endpoint(dst, "o/t0", "d0"))
    assert adopted.trace_id == trace_id
    assert task.status == task.HANDED_OFF
    assert a.wait_all(timeout=60) and b.wait_all(timeout=60)
    assert adopted.status == adopted.SUCCEEDED
    budget = adopted.stats.time_budget()
    assert abs(sum(budget.values())
               - adopted.stats.actual_model_seconds) < 1e-6
    assert blocker.status == blocker.SUCCEEDED


# --------------------------------------------------------------------------
# chaos fleets: the capstone acceptance invariant
# --------------------------------------------------------------------------
def test_run_multi_chaos_budgets_sum_exactly(tmp_root):
    runner = ScenarioRunner(tmp_root)
    fleet = runner.run_multi(
        n_tasks=4, tenants=("alice", "bob"),
        schedule=FaultSchedule(seed=11).transient(op="recv", at=1, times=1),
        max_workers=3, pause_resume=(1,), strict=True)
    tracer = fleet.manager.tracer
    assert tracer.enabled and tracer.spans_recorded > 0
    for task in fleet.tasks:
        budget = task.stats.time_budget()
        assert abs(sum(budget.values())
                   - task.stats.actual_model_seconds) < 1e-6
        assert task.trace_id


def test_run_federated_budgets_and_trace_ids(tmp_root):
    runner = ScenarioRunner(tmp_root)
    fed = runner.run_federated(n_sites=2, n_tasks=4, strict=True)
    moved = dict(fed.moved)
    for task in fed.tasks:
        budget = task.stats.time_budget()
        assert abs(sum(budget.values())
                   - task.stats.actual_model_seconds) < 1e-6
    # every handed-off task kept its trace id through the spec
    for task_id in moved:
        spec = fed.coordinator.last_spec(task_id)
        assert spec is not None and spec.trace_id == f"trace-{task_id}"


def test_same_seed_runs_export_identical_traces(tmp_path):
    digests = []
    for i in range(2):
        mgr, _tasks = run_fleet(tmp_path / f"run{i}", n_tasks=3, n_files=3)
        p = str(tmp_path / f"trace{i}.jsonl")
        mgr.tracer.export_jsonl(p)
        with open(p, "rb") as fh:
            digests.append(fh.read())
    assert digests[0] == digests[1]


# --------------------------------------------------------------------------
# lint rule R006 (satellite b)
# --------------------------------------------------------------------------
def lint_tree(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src), encoding="utf-8")
    return run_lint(tmp_path)


def r006_hits(report):
    return [(f.file, f.line) for f in report.findings if f.rule == "R006"]


def test_r006_flags_bare_span_call(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/core/thing.py": """\
        def work(tracer):
            cm = tracer.span("send", "wire")
            cm.__enter__()
        """})
    assert r006_hits(report) == [("src/repro/core/thing.py", 2)]


def test_r006_accepts_with_managed_span(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/core/thing.py": """\
        def work(tracer, clock):
            with tracer.span("send", "wire", path="p"):
                clock.sleep(1.0)
            with tracer.span("a"), tracer.span("b"):
                pass
        """})
    assert r006_hits(report) == []


def test_r006_suppressible_with_reason(tmp_path):
    report = lint_tree(tmp_path, {"src/repro/core/thing.py": """\
        def work(tracer):
            cm = tracer.span("send")  # lint: disable=R006(test fixture)
            return cm
        """})
    assert r006_hits(report) == []
    assert any(s.rule == "R006" for s in report.suppressed)
