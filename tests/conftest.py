import os

# Tests run with the real single CPU device; the dry-run (and only the
# dry-run) sets --xla_force_host_platform_device_count=512 inside its own
# process.  Keep JAX quiet and deterministic here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("REPRO_TIME_SCALE", "0.0")  # pure accounting, no sleeps

import pytest  # noqa: E402


@pytest.fixture()
def tmp_root(tmp_path):
    return str(tmp_path)
