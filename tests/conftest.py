import os

# Tests run with the real single CPU device; the dry-run (and only the
# dry-run) sets --xla_force_host_platform_device_count=512 inside its own
# process.  Keep JAX quiet and deterministic here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("REPRO_TIME_SCALE", "0.0")  # pure accounting, no sleeps

import pytest  # noqa: E402

# Property suites (hypothesis-based where available) must not push tier-1
# past the seed runtime: cap examples and kill the per-example deadline
# (the emulation's model-clock accounting is bursty under load).
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("tier1", max_examples=16, deadline=None,
                                   derandomize=True)
    _hyp_settings.load_profile("tier1")
except ImportError:  # container without hypothesis: suites fall back to
    pass             # seeded parametrization (see tests/test_chaos_properties)


@pytest.fixture()
def tmp_root(tmp_path):
    return str(tmp_path)
