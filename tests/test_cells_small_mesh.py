"""Integration: the dry-run cell machinery on a small fake-device mesh.

Runs in a subprocess because the device count must be fixed before jax
initializes (the main test process keeps 1 device).
"""

import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax
    from repro.launch.mesh import make_test_mesh
    from repro.launch.cells import build_cell, lower_cell
    from repro.configs import get_config
    from repro.roofline import cost_numbers

    arch, shape = os.environ["ARCH"], os.environ["SHAPE"]
    mesh = make_test_mesh(2, 2, pods=2)
    cfg = get_config(arch).scaled_down(n_layers=2)
    cell = build_cell(arch, shape, mesh, cfg=cfg)
    compiled = lower_cell(cell, mesh).compile()
    ma = compiled.memory_analysis()
    n = cost_numbers(compiled)
    print(json.dumps({
        "ok": True,
        "args": ma.argument_size_in_bytes,
        "flops": n["flops"],
        "coll": n["coll"]["total"],
        "kind": cell.kind,
    }))
""")


@pytest.mark.parametrize("arch,shape", [
    ("qwen1.5-0.5b", "train_4k"),
    ("granite-moe-1b-a400m", "prefill_32k"),
    ("rwkv6-7b", "decode_32k"),
    ("whisper-medium", "decode_32k"),
])
def test_cell_lowers_on_multipod_test_mesh(arch, shape, tmp_path):
    env = {"ARCH": arch, "SHAPE": shape, "PYTHONPATH": "src",
           "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
    assert rec["flops"] > 0
    # distributed program must actually communicate
    assert rec["coll"] > 0
