"""Event-streamed status delivery for the managed transfer service.

The paper's pitch is a service clients *observe* without sitting in the
data path; at fleet scale that observation must not be a poll.  This
module is the service plane's transport: a ``StatusBus`` that managers
and the federation coordinator publish typed lifecycle events through,
and that any number of subscribers consume via bounded per-subscriber
ring buffers.  Task status, fleet digests, and federation placement all
become push streams; ``wait``-style callers and subscribers share the
same completion signal (the manager's condition variable), so no code
path re-polls on a wall-clock timer.

Event taxonomy
--------------
Task lifecycle, published by ``TransferManager`` at each queue
mutation while it still holds the manager lock (so per-task event
order on the bus matches the queue's actual state transitions):

``queued``       task accepted into the ready queue (also on import)
``dispatched``   task activated onto a worker
``progress``     bytes advanced (``bytes_done``/``bytes_total`` data)
``paused``       task checkpointed out of the running/queued set
``resumed``      paused task re-entered the ready queue
``handed_off``   task exported to a peer site (federation)
``done``         terminal success
``failed``       terminal failure
``cancelled``    terminal cancellation
``digest``       a queue-digest snapshot was recomputed (etag miss);
                 the event payload is the digest dict itself

The federation coordinator additionally publishes ``placed`` (every
spec placement, with the reason: submit/handoff/failover/rebalance),
``failover`` and ``beat``.

The observability plane adds ``metrics``: a periodic
:class:`~repro.obs.MetricsRegistry` snapshot published by the manager
every N terminal completions, so subscribers can scrape the fleet's
counters off the same stream they already watch for lifecycle events.

Backpressure contract
---------------------
Publishing never blocks and never drops for *fast* subscribers; each
subscriber owns a bounded ring (default 256 events).  When a slow
subscriber's ring is full the *oldest* undelivered event is dropped and
that subscriber's ``dropped`` counter is incremented — exactly one
increment per lost event, so a consumer can always tell how much of the
stream it missed (the ``seq`` gap agrees with ``dropped``).  Slow
consumers therefore degrade to "fresh tail + loss count" rather than
stalling the publisher or growing unbounded queues.  ``unsubscribe``
(or ``Subscription.close``) detaches the ring and frees its buffer
immediately; further publishes never touch it.

Timestamps are *model* time (``Clock.virtual_elapsed``): under the
simulated clock two same-seed runs produce identical event streams, and
staleness measurements in ``benchmarks/bench_svc.py`` are deterministic.
"""

from __future__ import annotations

import itertools
import threading
from collections import deque
from dataclasses import dataclass

from ..core.clock import DEFAULT_CLOCK, Clock

#: every event type the service plane emits (see module docstring)
EVENT_TYPES = (
    "queued", "dispatched", "progress", "paused", "resumed",
    "handed_off", "done", "failed", "cancelled", "digest",
    "placed", "failover", "beat", "metrics",
)


@dataclass(frozen=True)
class StatusEvent:
    """One immutable service-plane event.

    ``seq`` is a per-bus monotonic sequence number assigned at publish;
    a subscriber observing ``seq`` gaps lost exactly ``dropped`` events.
    ``t`` is model time (``Clock.virtual_elapsed`` at publish).
    """

    seq: int
    t: float
    type: str
    site_id: str = ""
    task_id: str = ""
    data: dict | None = None


class Subscription:
    """One subscriber's bounded event ring (see backpressure contract).

    Consumers either ``poll()`` (non-blocking drain) or ``next()``
    (block on the subscription's condition variable until an event
    arrives).  ``dropped`` counts events lost to drop-oldest; it is
    exact.  Close (or ``StatusBus.unsubscribe``) frees the buffer.
    """

    def __init__(self, bus: "StatusBus", capacity: int = 256,
                 types: tuple[str, ...] | None = None,
                 task_id: str | None = None):
        if capacity < 1:
            raise ValueError("subscription capacity must be >= 1")
        self._bus = bus
        self.capacity = capacity
        #: optional filters, applied at publish (misses cost nothing)
        self.types = tuple(types) if types else None
        self.task_id = task_id
        self._cv = threading.Condition()
        self._ring: deque[StatusEvent] = deque()
        #: exact count of events lost to drop-oldest backpressure
        self.dropped = 0
        #: events accepted into the ring (delivered or later dropped)
        self.delivered = 0
        self.closed = False

    # -- publisher side (called by the bus; never blocks) -------------
    def _wants(self, ev: StatusEvent) -> bool:
        if self.types is not None and ev.type not in self.types:
            return False
        if self.task_id is not None and ev.task_id != self.task_id:
            return False
        return True

    def _offer(self, ev: StatusEvent) -> None:
        with self._cv:
            if self.closed:
                return
            if len(self._ring) >= self.capacity:
                self._ring.popleft()
                self.dropped += 1
            self._ring.append(ev)
            self.delivered += 1
            self._cv.notify_all()

    # -- consumer side ------------------------------------------------
    def poll(self, max_events: int | None = None) -> list[StatusEvent]:
        """Drain up to ``max_events`` buffered events (all by default)
        without blocking."""
        with self._cv:
            if max_events is None or max_events >= len(self._ring):
                out = list(self._ring)
                self._ring.clear()
            else:
                out = [self._ring.popleft() for _ in range(max_events)]
            return out

    def next(self, timeout: float | None = None) -> StatusEvent | None:
        """Block until one event is available (or ``timeout`` wall
        seconds elapse / the subscription closes); pop and return it."""
        with self._cv:
            if not self._cv.wait_for(
                    lambda: self._ring or self.closed, timeout):
                return None
            if not self._ring:
                return None
            return self._ring.popleft()

    def __len__(self) -> int:
        with self._cv:
            return len(self._ring)

    def close(self) -> None:
        """Detach from the bus and free the buffer."""
        self._bus.unsubscribe(self)


class StatusBus:
    """Publish/subscribe hub for service-plane status events.

    One bus per manager (and one per coordinator).  ``publish`` stamps
    events with the bus clock's model time, assigns the per-bus ``seq``
    and fans out to every matching subscription under the bus lock;
    subscriptions do their own locking, so the only lock order is
    bus -> subscription (never the reverse) and publishing from inside
    the manager lock is safe.  With zero subscribers a publish is a
    counter increment — managers publish unconditionally.
    """

    def __init__(self, site_id: str = "", clock: Clock | None = None):
        self.site_id = site_id
        self.clock = clock or DEFAULT_CLOCK
        self._lock = threading.Lock()
        self._subs: list[Subscription] = []
        self._seq = itertools.count()
        #: total events published (including zero-subscriber publishes)
        self.published = 0

    # -- subscriber management ----------------------------------------
    def subscribe(self, capacity: int = 256,
                  types: tuple[str, ...] | None = None,
                  task_id: str | None = None) -> Subscription:
        """Attach a bounded-ring subscriber; optional event-type and
        task-id filters are applied at publish time."""
        sub = Subscription(self, capacity=capacity, types=types,
                           task_id=task_id)
        with self._lock:
            self._subs.append(sub)
        return sub

    def unsubscribe(self, sub: Subscription) -> None:
        """Detach ``sub`` and free its buffer; idempotent."""
        with self._lock:
            try:
                self._subs.remove(sub)
            except ValueError:
                pass
        with sub._cv:
            sub.closed = True
            sub._ring.clear()
            sub._cv.notify_all()

    @property
    def subscribers(self) -> int:
        with self._lock:
            return len(self._subs)

    # -- publishing ----------------------------------------------------
    def publish(self, etype: str, task_id: str = "",
                data: dict | None = None, t: float | None = None,
                site_id: str | None = None) -> StatusEvent:
        """Publish one event; never blocks (see backpressure contract).

        ``t`` defaults to the bus clock's model time; pass it explicitly
        when the event belongs to another site's clock (federation).
        """
        with self._lock:
            ev = StatusEvent(
                seq=next(self._seq),
                t=self.clock.virtual_elapsed if t is None else t,
                type=etype,
                site_id=self.site_id if site_id is None else site_id,
                task_id=task_id,
                data=data,
            )
            self.published += 1
            subs = [s for s in self._subs if s._wants(ev)]
        for sub in subs:
            sub._offer(ev)
        return ev
