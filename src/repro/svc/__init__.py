"""Service plane: subscription-based status streaming (see bus.py).

Re-exports the public surface so callers write ``from repro.svc import
StatusBus`` — the module layout stays an implementation detail.
"""

from .bus import EVENT_TYPES, StatusBus, StatusEvent, Subscription

__all__ = ["EVENT_TYPES", "StatusBus", "StatusEvent", "Subscription"]
