"""LLaVA-NeXT (Mistral-7B backbone): SWA 4096; anyres vision frontend
STUBBED — input_specs() provides pre-extracted patch embeddings
[hf:llava-hf/llava-v1.6-mistral-7b-hf]."""

from repro.models.common import ArchConfig, VLMConfig

CONFIG = ArchConfig(
    arch_id="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    swa_window=4096,
    vlm=VLMConfig(n_image_tokens=1152, patch_dim=1024),
)
