"""Qwen1.5-0.5B: MHA (kv=16), QKV bias, tied embeddings
[hf:Qwen/Qwen1.5-0.5B]."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
)
