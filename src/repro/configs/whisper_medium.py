"""Whisper-medium: 24L encoder + 24L decoder with cross-attention;
conv frontend STUBBED — input_specs() provides precomputed frame
embeddings (B, 1500, 1024) [arXiv:2212.04356]."""

from repro.models.common import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    arch_id="whisper-medium",
    family="audio",
    n_layers=24,                        # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    gated_mlp=False,                    # whisper MLP: GELU, biased
    encdec=EncDecConfig(n_encoder_layers=24, n_audio_ctx=1500),
)
