"""DBRX (132B total / 36B active): 16-expert top-4 fine-grained MoE
[hf:databricks/dbrx-base]."""

from repro.models.common import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    moe=MoEConfig(n_experts=16, top_k=4),
)
