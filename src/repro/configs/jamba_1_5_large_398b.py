"""Jamba-1.5-Large (398B): Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer (matches the 398B total / 94B active budget)
[arXiv:2403.19887]."""

from repro.models.common import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    attn_every=8,                       # 1 attention : 7 mamba per block
    moe=MoEConfig(n_experts=16, top_k=2, moe_every=2),
    # TPU-native SSD blocking: 512-token chunks, 128-wide MXU sub-chunks
    # (scalar-decay path materializes only (B,R,R,H) — VMEM-safe at 128)
    ssm=SSMConfig(kind="mamba2", d_state=64, head_dim=64, expand=2,
                  d_conv=4, chunk=512, subchunk=128),
)
