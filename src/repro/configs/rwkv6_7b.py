"""RWKV6-7B "Finch": attention-free, data-dependent decay
[arXiv:2404.05892]."""

from repro.models.common import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,                         # d_model / head_size(64)
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    ssm=SSMConfig(kind="rwkv6", d_state=64, head_dim=64, chunk=128,
                  decay_rank=64),
)
