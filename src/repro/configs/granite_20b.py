"""IBM Granite 20B (code): gpt_bigcode-style — MQA (kv=1), plain GELU
MLP (2-matrix, biased) [arXiv:2405.04324]."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    arch_id="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    gated_mlp=False,                    # bigcode MLP: wi+gelu+wo with bias
)
