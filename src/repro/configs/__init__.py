"""Assigned-architecture configs (one module per arch id) + registry."""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "jamba-1.5-large-398b",
    "dbrx-132b",
    "granite-moe-1b-a400m",
    "granite-20b",
    "h2o-danube-3-4b",
    "qwen1.5-110b",
    "qwen1.5-0.5b",
    "whisper-medium",
    "rwkv6-7b",
    "llava-next-mistral-7b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
