"""IBM Granite 3.0 1B-A400M: 32-expert top-8 MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""

from repro.models.common import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8),
)
