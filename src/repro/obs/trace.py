"""Model-time tracing riding the charge-attribution clock.

A :class:`Tracer` produces **spans** — named, categorized windows of a
task's timeline — without ever reading the wall clock.  The span
context lives in the same thread-local slot as the charge owner
(:mod:`repro.core.clock`), travels across worker/sender/pool threads
through ``bind_charge_owner``, and is charged by ``Clock.sleep`` itself:
every model-second a thread sleeps lands on the innermost span open on
that thread (``Span.self_seconds``) and on the tracer's per-task
category tally.  That tally is what makes ``TaskStats.time_budget()``
exact — it is fed by the very same ``sleep`` calls that feed
``Clock.charged``, so the decomposition and the total can never drift.

Two export formats:

* :meth:`Tracer.export_jsonl` — the canonical, deterministic form.  One
  span per line, sorted by a semantic key, carrying only seed-stable
  fields (ids, names, categories, attrs, per-span self seconds) — byte-
  identical across same-seed runs of a deterministic scenario.  Global
  virtual timestamps are deliberately excluded: concurrent tasks all
  advance the shared virtual clock, so start offsets depend on thread
  interleaving even when every per-task quantity is exact.
* :meth:`Tracer.export_chrome` — Chrome trace-event JSON (``ph: "X"``
  complete events over virtual microseconds), loadable in Perfetto /
  ``chrome://tracing`` for a visual timeline.  Interleaving-dependent by
  construction; no byte-stability claim.

Span discipline: ``Tracer.span(...)`` may only be used as a ``with``
context manager (lint rule R006) — a leaked open span would swallow
every later charge on its thread and corrupt the time-budget sum.
"""

from __future__ import annotations

import json
import threading
from collections import deque

from ..core.clock import (_swap_trace_context, current_trace_context,
                          trace_context)

#: span categories with a fixed place in ``TaskStats.time_budget()``;
#: spans may use other categories, but these are the vocabulary the
#: data/control planes charge under (and the budget reports in order)
CATEGORIES = ("startup", "overhead", "wire", "integrity", "backoff",
              "replica", "session", "queue", "other")


class Span:
    """One traced window.  ``self_seconds`` is the model time charged by
    the owning thread while this span was its innermost — the
    deterministic quantity; ``t0``/``t1`` are global virtual timestamps
    kept for the Chrome export only.

    A span is its own ``with`` guard AND its own thread-local trace
    context: entering swaps it into the clock's attribution slot,
    ``Clock.sleep`` calls :meth:`charge` on it directly, and exiting
    restores the parent context and records the span.  One object per
    span — this path runs per traced storage op, so the earlier
    three-object form (guard + span + child context) was measurable
    fleet CPU."""

    __slots__ = ("tracer", "trace_id", "task_id", "name", "category",
                 "attrs", "t0", "t1", "self_seconds", "thread", "_prev",
                 "_entered")

    #: duck-type marker for ``Clock.sleep``-compatible contexts: both
    #: Span and the root _SpanCtx expose ``span``/``charge``
    def __init__(self, tracer, trace_id, task_id, name, category,
                 attrs, t0, thread):
        self.tracer = tracer
        self.trace_id = trace_id
        self.task_id = task_id
        self.name = name
        self.category = category
        self.attrs = attrs
        self.t0 = t0
        self.t1 = None
        self.self_seconds = 0.0
        self.thread = thread
        self._prev = None
        self._entered = False

    @property
    def span(self):
        """As a trace context, a Span is its own innermost span."""
        return self

    def charge(self, model_seconds: float) -> None:
        # hot path: this runs on EVERY Clock.sleep under a span.  The
        # span is owned by the thread that opened it, so the owner
        # accumulates lock-free; only a charge from a thread the
        # context was rebound onto (bind_charge_owner inside an open
        # span) pays the lock.  The per-task tally is folded once, at
        # span close.
        if self.thread == threading.get_ident():
            self.self_seconds += model_seconds
        else:
            with self.tracer._lock:
                self.self_seconds += model_seconds

    def __enter__(self):
        self.thread = threading.get_ident()
        self.t0 = self.tracer._now()
        self._prev = _swap_trace_context(self)
        self._entered = True
        return self

    def __exit__(self, *exc):
        if self._entered:
            self._entered = False
            _swap_trace_context(self._prev)
            self.t1 = self.tracer._now()
            self.tracer._record_span(self)
        return False

    def key(self):
        """Deterministic sort key for the canonical export."""
        return (self.trace_id, self.task_id, self.category, self.name,
                json.dumps(self.attrs, sort_keys=True),
                self.self_seconds)


class _SpanCtx:
    """Root trace context for a task binding: which trace/task spans
    opened on this thread attach to, before any span is open.
    Installed via ``repro.core.clock.trace_context`` and captured
    across threads by ``bind_charge_owner``.  ``charge`` is the
    duck-typed hook ``Clock.sleep`` calls — at the root there is no
    open span, so the charge lands in the budget's ``other``
    remainder."""

    __slots__ = ("tracer", "trace_id", "task_id")

    #: a root context has no innermost span
    span = None

    def __init__(self, tracer, trace_id, task_id):
        self.tracer = tracer
        self.trace_id = trace_id
        self.task_id = task_id

    def charge(self, model_seconds: float) -> None:
        return


class _NullCM:
    """Shared no-op context manager: what a disabled tracer's ``bind``
    and ``span`` return, so instrumented code pays one attribute lookup
    and an empty ``with`` when tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CM = _NullCM()


class Tracer:
    """Fleet-wide span collector.  Thread-safe; bounded (``max_spans``
    ring with an exact ``spans_dropped`` counter, mirroring the
    StatusBus subscriber discipline).  ``clock`` is any object with a
    ``virtual_elapsed`` attribute — virtual timestamps only, never wall
    time."""

    MAX_SPANS = 65536

    def __init__(self, clock=None, enabled: bool = True,
                 max_spans: int = MAX_SPANS):
        self.enabled = enabled
        self.clock = clock
        self.max_spans = max_spans
        self._spans: deque = deque()
        #: task_id -> {category -> model seconds charged under a span}
        self._tally: dict[str, dict[str, float]] = {}
        self.spans_recorded = 0
        self.spans_dropped = 0
        self.binds = 0
        self._lock = threading.Lock()

    # ---- binding ---------------------------------------------------------
    def bind(self, trace_id: str, task_id: str):
        """Root binding for a task run: every span opened (on this
        thread or any ``bind_charge_owner``-crossed thread) while the
        block is active attaches to ``trace_id``/``task_id``."""
        if not self.enabled:
            return _NULL_CM
        self.binds += 1
        return trace_context(_SpanCtx(self, trace_id, task_id))

    def span(self, name: str, category: str = "other", **attrs):
        """Open a span; ``with`` context manager ONLY (lint R006).
        Outside any tracer binding (no task context on this thread)
        there is nothing to attach to, so the no-op guard comes back."""
        if not self.enabled:
            return _NULL_CM
        parent = current_trace_context()
        if parent is None or not isinstance(parent, (Span, _SpanCtx)):
            return _NULL_CM
        return Span(self, parent.trace_id, parent.task_id, name,
                    category, attrs, 0.0, 0)

    def record(self, name: str, category: str, t0: float, t1: float,
               trace_id: str = "", task_id: str = "", **attrs) -> None:
        """Record a retroactive window (queue wait, breaker state
        window, federation handoff) that was observed, not slept
        through: it appears in exports but charges nothing to the
        time-budget tallies."""
        if not self.enabled:
            return
        span = Span(self, trace_id, task_id, name, category, attrs,
                    t0, 0)
        span.t1 = t1
        self._record_span(span)

    # ---- charge plumbing -------------------------------------------------
    def _now(self) -> float:
        return self.clock.virtual_elapsed if self.clock is not None \
            else 0.0

    def _record_span(self, span: Span) -> None:
        with self._lock:
            if span.task_id and span.self_seconds:
                per = self._tally.setdefault(span.task_id, {})
                per[span.category] = per.get(span.category, 0.0) \
                    + span.self_seconds
            if len(self._spans) >= self.max_spans:
                self._spans.popleft()
                self.spans_dropped += 1
            self._spans.append(span)
            self.spans_recorded += 1

    # ---- tallies ---------------------------------------------------------
    def category_seconds(self, task_id: str) -> dict[str, float]:
        """Snapshot of the per-category model seconds charged under
        spans for ``task_id`` (cumulative across runs/resumes — callers
        wanting a per-run delta snapshot before and after)."""
        with self._lock:
            return dict(self._tally.get(task_id, {}))

    def forget(self, task_id: str) -> None:
        """Drop a finished/exported task's tally so the table stays
        bounded over a long-lived fleet (sibling of ``Clock.forget``)."""
        with self._lock:
            self._tally.pop(task_id, None)

    def spans(self) -> list:
        with self._lock:
            return list(self._spans)

    # ---- exports ---------------------------------------------------------
    def export_jsonl(self, path: str) -> int:
        """Canonical deterministic export: one span per line, sorted by
        semantic key, seed-stable fields only.  Returns the number of
        lines written."""
        spans = sorted(self.spans(), key=Span.key)
        with open(path, "w") as fh:
            for s in spans:
                fh.write(json.dumps(
                    {"trace_id": s.trace_id, "task_id": s.task_id,
                     "name": s.name, "category": s.category,
                     "attrs": s.attrs,
                     "self_seconds": round(s.self_seconds, 9)},
                    sort_keys=True) + "\n")
        return len(spans)

    def export_chrome(self, path: str) -> int:
        """Chrome trace-event JSON over *virtual* microseconds — open it
        in Perfetto (ui.perfetto.dev) or ``chrome://tracing``.  Complete
        (``ph: "X"``) events; pid = task, tid = a stable per-thread
        index in first-seen order."""
        spans = self.spans()
        tids: dict[int, int] = {}
        events = []
        for s in spans:
            tid = tids.setdefault(s.thread, len(tids))
            t1 = s.t1 if s.t1 is not None else s.t0
            events.append({
                "name": s.name, "cat": s.category, "ph": "X",
                "ts": round(s.t0 * 1e6, 3),
                "dur": round(max(0.0, t1 - s.t0) * 1e6, 3),
                "pid": s.task_id or s.trace_id or "fleet",
                "tid": tid,
                "args": dict(s.attrs, trace_id=s.trace_id,
                             self_seconds=round(s.self_seconds, 9)),
            })
        with open(path, "w") as fh:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, fh)
        return len(events)


#: shared disabled tracer: the default for a bare ``TransferService``
#: so un-instrumented construction paths pay (almost) nothing
NULL_TRACER = Tracer(enabled=False)
