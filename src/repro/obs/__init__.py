"""Observability plane: model-time tracing + labeled metrics.

Two halves, both wall-clock-free (contract R001 holds here too):

* :mod:`repro.obs.trace` — :class:`Tracer` spans riding the charge-
  attribution clock; deterministic JSONL + Perfetto-loadable Chrome
  trace exports; the per-task category tallies behind
  ``TaskStats.time_budget()``.
* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` counters / gauges
  / histograms with fixed bucket bounds, absorbing the scattered
  per-plane counters via snapshot-time collectors, scraped as sorted
  Prometheus-flavoured text.
"""

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      DEFAULT_BUCKETS)
from .trace import CATEGORIES, NULL_TRACER, Span, Tracer

__all__ = [
    "CATEGORIES", "Counter", "DEFAULT_BUCKETS", "Gauge", "Histogram",
    "MetricsRegistry", "NULL_TRACER", "Span", "Tracer",
]
