"""Labeled metrics registry — counters, gauges, histograms — with no
wall time anywhere.

The fleet already counts things in four ad-hoc places (``ManagerMetrics``
dataclass fields, ``EndpointHealth.snapshot()``, ``ReplicaCatalog.
stats()``, ``StatusBus.published``).  Those stay — tests and operators
read them directly — but :class:`MetricsRegistry` absorbs them behind
one labeled namespace: native instruments for the hot-path series
(``repro_tasks_total{site,tenant,status}``-style), plus **collectors**
(zero-arg callables returning ``{metric_name: value}`` or
``{metric_name: {label_key: value}}``) that pull the per-plane dataclass
counters in at snapshot/scrape time, so absorbing a plane costs one
``register_collector`` call and no churn in the plane itself.

Determinism: histogram bucket bounds are fixed at construction,
snapshots and scrapes are sorted by (name, labels) — two runs of a
deterministic scenario produce identical scrape text.
"""

from __future__ import annotations

import threading

#: default histogram bounds (model seconds): geometric-ish ladder wide
#: enough for both sub-second control-plane waits and hour-long chaos
#: tasks; fixed so same-seed runs bucket identically
DEFAULT_BUCKETS = (0.005, 0.02, 0.1, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
                   60.0, 300.0, 1800.0)


def _label_key(labels: dict) -> tuple:
    """Canonical hashable form: sorted (k, str(v)) pairs."""
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render_labels(key: tuple) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class Counter:
    """Monotonic labeled counter."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._samples: dict[tuple, float] = {}
        self._lock = threading.Lock()

    def inc(self, value: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._samples[key] = self._samples.get(key, 0.0) + value

    def value(self, **labels) -> float:
        with self._lock:
            return self._samples.get(_label_key(labels), 0.0)

    def samples(self) -> dict[tuple, float]:
        with self._lock:
            return dict(self._samples)


class Gauge(Counter):
    """Labeled point-in-time value (``set`` replaces, ``inc`` adjusts)."""

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._samples[_label_key(labels)] = value


class Histogram:
    """Labeled histogram over fixed, deterministic bucket bounds.
    Cumulative bucket counts plus sum/count, Prometheus-style."""

    def __init__(self, name: str, help: str = "",
                 buckets: tuple = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(buckets))
        #: label key -> [per-bucket counts..., +Inf count]
        self._counts: dict[tuple, list] = {}
        self._sums: dict[tuple, float] = {}
        self._lock = threading.Lock()

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            counts = self._counts.setdefault(
                key, [0] * (len(self.buckets) + 1))
            for i, bound in enumerate(self.buckets):
                if value <= bound:
                    counts[i] += 1
                    break
            else:
                counts[-1] += 1
            self._sums[key] = self._sums.get(key, 0.0) + value

    def snapshot(self) -> dict[tuple, dict]:
        """label key -> {"count", "sum", "buckets": {bound: cumulative}}
        (cumulative counts, le-style)."""
        out = {}
        with self._lock:
            for key, counts in self._counts.items():
                cum, buckets = 0, {}
                for bound, n in zip(self.buckets, counts):
                    cum += n
                    buckets[bound] = cum
                out[key] = {"count": cum + counts[-1],
                            "sum": self._sums.get(key, 0.0),
                            "buckets": buckets}
        return out


class MetricsRegistry:
    """One scrape surface for the whole fleet.

    Instruments are memoized by name (two ``counter("x")`` calls return
    the same object); collectors are pulled at snapshot/scrape time so
    legacy per-plane counters need no write-path changes."""

    def __init__(self, namespace: str = "repro"):
        self.namespace = namespace
        self._instruments: dict[str, object] = {}
        self._collectors: list = []
        self._lock = threading.Lock()

    def _full(self, name: str) -> str:
        return f"{self.namespace}_{name}" if self.namespace else name

    def _get(self, name: str, cls, **kw):
        full = self._full(name)
        with self._lock:
            inst = self._instruments.get(full)
            if inst is None:
                inst = cls(full, **kw)
                self._instruments[full] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {full!r} already registered as "
                    f"{type(inst).__name__}")
            return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple = DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, Histogram, help=help, buckets=buckets)

    def register_collector(self, fn) -> None:
        """``fn() -> {name: value}`` or ``{name: {label_key: value}}``;
        called at snapshot/scrape time.  Names are namespaced on the
        way out; a collector that raises is skipped (scraping must
        never take the fleet down)."""
        with self._lock:
            self._collectors.append(fn)

    # ---- read side -------------------------------------------------------
    def _collected(self) -> dict:
        out: dict = {}
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            try:
                got = fn() or {}
            except Exception:
                continue
            for name, value in got.items():
                out[self._full(name)] = value
        return out

    def snapshot(self) -> dict:
        """Deterministically-ordered nested dict of every sample:
        ``{metric: {label_string: value}}`` for counters/gauges,
        ``{metric: {label_string: {count, sum, buckets}}}`` for
        histograms, plus collector outputs."""
        out: dict = {}
        with self._lock:
            instruments = dict(self._instruments)
        for name in sorted(instruments):
            inst = instruments[name]
            if isinstance(inst, Histogram):
                snap = inst.snapshot()
                out[name] = {_render_labels(k): snap[k]
                             for k in sorted(snap)}
            else:
                samples = inst.samples()
                out[name] = {_render_labels(k): samples[k]
                             for k in sorted(samples)}
        collected = self._collected()
        for name in sorted(collected):
            out.setdefault(name, collected[name])
        return out

    def scrape(self) -> str:
        """Prometheus-flavoured text exposition, line-sorted within
        each metric — stable across same-seed runs."""
        lines: list[str] = []
        with self._lock:
            instruments = dict(self._instruments)
        for name in sorted(instruments):
            inst = instruments[name]
            if getattr(inst, "help", ""):
                lines.append(f"# HELP {name} {inst.help}")
            if isinstance(inst, Histogram):
                lines.append(f"# TYPE {name} histogram")
                snap = inst.snapshot()
                for key in sorted(snap):
                    s = snap[key]
                    base = dict(key)
                    for bound in inst.buckets:
                        lk = _render_labels(_label_key(
                            dict(base, le=f"{bound:g}")))
                        lines.append(
                            f"{name}_bucket{lk} {s['buckets'][bound]}")
                    lk = _render_labels(_label_key(
                        dict(base, le="+Inf")))
                    lines.append(f"{name}_bucket{lk} {s['count']}")
                    lines.append(
                        f"{name}_sum{_render_labels(key)} {s['sum']:g}")
                    lines.append(
                        f"{name}_count{_render_labels(key)} "
                        f"{s['count']}")
            else:
                kind = "gauge" if isinstance(inst, Gauge) else "counter"
                lines.append(f"# TYPE {name} {kind}")
                samples = inst.samples()
                for key in sorted(samples):
                    lines.append(
                        f"{name}{_render_labels(key)} "
                        f"{samples[key]:g}")
        collected = self._collected()
        for name in sorted(collected):
            value = collected[name]
            if isinstance(value, dict):
                for lk in sorted(value, key=str):
                    lines.append(f'{name}{{key="{lk}"}} '
                                 f"{value[lk]:g}")
            else:
                lines.append(f"{name} {value:g}")
        return "\n".join(lines) + "\n"
