"""Hardware model: TPU v5e (the assignment's target)."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops_bf16: float   # per chip
    hbm_bw: float            # per chip, B/s
    hbm_bytes: float         # per chip
    ici_bw_per_link: float   # B/s, one ICI link
    ici_links: int           # usable links per chip (2D torus)
    dcn_bw: float            # per-chip share of inter-pod DCN, B/s


HW_V5E = Hardware(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    hbm_bytes=16 * 1024**3,
    ici_bw_per_link=50e9,   # per assignment: ~50 GB/s/link
    ici_links=1,            # conservative single-link roofline term
    dcn_bw=6.25e9,          # ~50 Gb/s per-chip DCN share across pods
)
