from .hw import HW_V5E, Hardware
from .analysis import (cost_numbers, extrapolate, model_flops,
                       roofline_from_numbers, roofline_terms, Roofline)
from .hlo import collective_bytes

__all__ = ["HW_V5E", "Hardware", "cost_numbers", "extrapolate",
           "model_flops", "roofline_from_numbers", "roofline_terms",
           "Roofline", "collective_bytes"]
