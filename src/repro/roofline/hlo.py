"""Collective-byte extraction from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` has no collective numbers, so we parse the
per-device HLO module.  Post-SPMD operands are printed as ``%refs`` (no
shapes), so we read each collective instruction's *output* shape(s) and
convert to moved bytes per op type:

  all-reduce          bytes = out           (each ref sums operand sizes;
                                             ring wire cost ~2x, noted)
  all-gather          bytes = out           (device receives the gathered
                                             buffer; operand = out/G)
  reduce-scatter      bytes = out * G       (operand = full input shard)
  all-to-all          bytes = out           (sends+receives one buffer)
  collective-permute  bytes = out

G = replica-group size parsed from ``replica_groups=[n_groups,G]<=...``.
Shapes in post-SPMD HLO are per-device shard shapes, so totals here are
bytes per chip.  Async ``-start``/``-done`` pairs count once.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "u1": 1, "s1": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _out_bytes(line: str) -> int:
    """Sum of output-shape bytes on the lhs of the instruction."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    rhs = lhs[1]
    # output shape(s) = everything before the op name token
    for op in _OPS:
        idx = rhs.find(f" {op}")
        if idx >= 0:
            out_part = rhs[:idx + 1]
            return sum(_shape_bytes(d, dims)
                       for d, dims in _SHAPE_RE.findall(out_part))
    return 0


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _which_op(line: str) -> str | None:
    for op in _OPS:
        for form in (f" {op}(", f" {op}-start(", f" {op}("):
            if form in line:
                return op
        # dialect variants, e.g. "all-reduce-scatter" guard: exact match
    return None


def collective_bytes(hlo_text: str) -> dict:
    by_op: dict[str, int] = defaultdict(int)
    count: dict[str, int] = defaultdict(int)
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if "-done(" in line:
            continue  # async pair counted at -start
        op = None
        # reduce-scatter must be matched before all-reduce-ish confusion
        for cand in ("reduce-scatter", "all-reduce", "all-gather",
                     "all-to-all", "collective-permute"):
            if f" {cand}(" in line or f" {cand}-start(" in line:
                op = cand
                break
        if op is None:
            continue
        nbytes = _out_bytes(line)
        if op == "reduce-scatter":
            nbytes *= _group_size(line)
        by_op[op] += nbytes
        count[op] += 1
    return {"total": int(sum(by_op.values())),
            "by_op": {k: int(v) for k, v in by_op.items()},
            "count": dict(count)}


def collective_breakdown_table(hlo_text: str) -> str:
    info = collective_bytes(hlo_text)
    lines = ["op,count,bytes"]
    for op in sorted(info["by_op"]):
        lines.append(f"{op},{info['count'][op]},{info['by_op'][op]}")
    lines.append(f"TOTAL,,{info['total']}")
    return "\n".join(lines)
