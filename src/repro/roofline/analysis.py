"""Three-term roofline from compiled dry-run artifacts.

    compute    = HLO_FLOPs / peak_FLOPs            (per chip, seconds)
    memory     = HLO_bytes / HBM_bw                (per chip, seconds)
    collective = collective_bytes / ICI_bw         (per chip, seconds)

cost_analysis() and the parsed HLO are both per-device (post-SPMD), so
no further division by chip count is needed.

XLA's static cost analysis counts a while-loop body ONCE, so a model
lowered as ``lax.scan`` over N layer-blocks under-reports by ~N.  The
dry-run therefore performs *blockwise extrapolation*: it compiles the
same cell at depth 1 block and 2 blocks with every scan fully unrolled,
and extrapolates  total = c1 + (n_blocks - 1) * (c2 - c1)  for FLOPs,
bytes and collective bytes.  The full-depth compile (the deliverable)
still provides memory_analysis().

MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE; 2*N*D for inference)
measures how much of the compiled compute is "useful" — the ratio
catches remat and redundancy waste.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, field

from .hlo import collective_bytes
from .hw import HW_V5E, Hardware


def cost_numbers(compiled) -> dict:
    """{'flops', 'bytes', 'coll': {...}} for one compiled executable
    (per-device)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def extrapolate(c1: dict, c2: dict, n_blocks: int) -> dict:
    """total = c1 + (n_blocks - 1) * max(c2 - c1, 0) elementwise."""
    def lin(a, b):
        return a + (n_blocks - 1) * max(b - a, 0.0)

    by_op = {}
    ops = set(c1["coll"]["by_op"]) | set(c2["coll"]["by_op"])
    for op in ops:
        a = c1["coll"]["by_op"].get(op, 0)
        b = c2["coll"]["by_op"].get(op, 0)
        by_op[op] = int(lin(a, b))
    counts = {}
    for op in ops:
        a = c1["coll"]["count"].get(op, 0)
        b = c2["coll"]["count"].get(op, 0)
        counts[op] = int(lin(a, b))
    return {
        "flops": lin(c1["flops"], c2["flops"]),
        "bytes": lin(c1["bytes"], c2["bytes"]),
        "coll": {"total": int(sum(by_op.values())), "by_op": by_op,
                 "count": counts},
    }


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_detail: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_global: float
    useful_ratio: float           # MODEL_FLOPS / (HLO_FLOPs * n_devices)
    peak_fraction: float          # useful-flops time / dominant term
    bytes_per_dev_argument: float = 0.0
    bytes_per_dev_temp: float = 0.0
    note: str = ""

    def to_dict(self):
        return asdict(self)


def _count_params(cfg) -> tuple[float, float]:
    """(total, active) parameter counts from the abstract tree."""
    import jax
    import numpy as np
    from ..models.registry import build
    api = build(cfg)
    shapes = api.abstract_params()
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = active = 0.0
    for keypath, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", "")))
                        for k in keypath)
        n = float(np.prod(leaf.shape))
        total += n
        if ("moe/wi" in path or "moe/wg" in path or "moe/wo" in path) \
                and cfg.moe is not None:
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    return total, active


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs for the whole (global) step."""
    total, active = _count_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch


def roofline_from_numbers(numbers: dict, *, arch: str, shape_name: str,
                          mesh_name: str, n_devices: int, cfg, shape,
                          memory_analysis=None, hw: Hardware = HW_V5E,
                          note: str = "") -> Roofline:
    flops = numbers["flops"]
    bytes_accessed = numbers["bytes"]
    coll = numbers["coll"]

    t_compute = flops / hw.peak_flops_bf16
    t_memory = bytes_accessed / hw.hbm_bw
    ici = hw.ici_bw_per_link * hw.ici_links
    t_coll = coll["total"] / ici
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    useful = mf / max(flops * n_devices, 1.0)
    t_useful = mf / n_devices / hw.peak_flops_bf16
    peak_fraction = t_useful / max(max(terms.values()), 1e-30)

    r = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, n_devices=n_devices,
        hlo_flops_per_dev=flops, hlo_bytes_per_dev=bytes_accessed,
        coll_bytes_per_dev=float(coll["total"]), coll_detail=coll,
        t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
        bottleneck=bottleneck, model_flops_global=mf, useful_ratio=useful,
        peak_fraction=peak_fraction, note=note,
    )
    if memory_analysis is not None:
        r.bytes_per_dev_argument = float(memory_analysis.argument_size_in_bytes)
        r.bytes_per_dev_temp = float(memory_analysis.temp_size_in_bytes)
    return r


def roofline_terms(r: Roofline) -> str:
    return (f"{r.arch} x {r.shape} [{r.mesh}]: "
            f"compute {r.t_compute * 1e3:.1f} ms | "
            f"memory {r.t_memory * 1e3:.1f} ms | "
            f"collective {r.t_collective * 1e3:.1f} ms "
            f"-> {r.bottleneck}-bound; useful {r.useful_ratio:.2f}, "
            f"roofline fraction {r.peak_fraction:.2f}")
