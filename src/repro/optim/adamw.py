"""AdamW with a configurable state-dtype policy.

At production scale the first/second moments are stored in bf16 (the
update math still runs in fp32) so a 398B model fits 16 GB/chip HBM
under full ZeRO-3 — see DESIGN.md §4.  Moments inherit the parameter
sharding, so optimizer state is fully sharded too.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .schedule import cosine_schedule


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "bfloat16"   # bf16 moments at production scale


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def adamw_init(params, cfg: OptimizerConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, opt_state, cfg: OptimizerConfig,
                 lr=None):
    step = opt_state["step"] + 1
    if lr is None:
        lr = cosine_schedule(step, peak_lr=cfg.peak_lr,
                             warmup_steps=cfg.warmup_steps,
                             total_steps=cfg.total_steps)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    # bias correction in fp32
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / c1
        vhat = v32 / c2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p32)
        return (p32.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
