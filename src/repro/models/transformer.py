"""Unified decoder stack covering all ten assigned architectures.

A model is a stack of identical *blocks* run under ``lax.scan`` (small
HLO, fast SPMD compile).  Each block is ``attn_every`` layers; a layer is
(mixer, ffn) where mixer in {attention, mamba2, rwkv-time-mix} and ffn in
{dense MLP, MoE, rwkv-channel-mix}.  Whisper adds an encoder stack and
cross-attention; LLaVA swaps the first image-token embeddings for
projected patch embeddings (frontend stubbed per assignment).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..sharding.rules import logical_constraint
from .common import ArchConfig
from .layers import (_normal, apply_rope, attention_apply, attention_decode,
                     attention_init, chunked_xent, linear, linear_init,
                     mlp_apply, mlp_init, rmsnorm, rmsnorm_init)
from .moe import moe_apply, moe_init
from .ssm import ssm_decode_step, ssm_scan_chunked


# ===========================================================================
# mamba2 mixer (jamba's SSM layers; see DESIGN.md §5 hardware adaptation)
# ===========================================================================
def _mamba_dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def mamba_init(key, cfg: ArchConfig, dtype):
    s = cfg.ssm
    d, (d_inner, H) = cfg.d_model, _mamba_dims(cfg)
    K = s.d_state
    ks = jax.random.split(key, 8)
    scale = 1.0 / math.sqrt(d)
    return {
        "wx": linear_init(ks[0], d, d_inner, dtype),
        "wz": linear_init(ks[1], d, d_inner, dtype),
        "wB": linear_init(ks[2], d, K, dtype),
        "wC": linear_init(ks[3], d, K, dtype),
        "wdt": linear_init(ks[4], d, H, dtype),
        "out": linear_init(ks[5], d_inner, d, dtype,
                           scale=1.0 / math.sqrt(d_inner * 2 * cfg.n_layers)),
        "conv_w": _normal(ks[6], (s.d_conv, d_inner), dtype, 0.5),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.full((H,), -4.6, jnp.float32),  # softplus ~ 0.01
        "D": jnp.ones((H,), jnp.float32),
        "norm_y": rmsnorm_init(d_inner, dtype),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv via shifts.  x: (B,S,D); w: (k,D).
    state: (B, k-1, D) trailing inputs from the previous segment."""
    kk = w.shape[0]
    y = x * w[kk - 1]
    for i in range(1, kk):
        if state is None:
            shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :x.shape[1]]
        else:
            ext = jnp.concatenate([state, x], axis=1)
            shifted = lax.dynamic_slice_in_dim(
                ext, state.shape[1] - i, x.shape[1], axis=1)
        y = y + shifted * w[kk - 1 - i]
    return y


def mamba_apply(p, x, cfg: ArchConfig, state=None):
    """x: (B,S,d).  Returns (y, (ssm_state, conv_state))."""
    s = cfg.ssm
    B, S, d = x.shape
    d_inner, H = _mamba_dims(cfg)
    K, dh = s.d_state, s.head_dim
    xz = linear(p["wx"], x)
    z = linear(p["wz"], x)
    conv_state_in = None if state is None else state[1]
    xc = jax.nn.silu(_causal_conv(xz, p["conv_w"].astype(x.dtype),
                                  conv_state_in))
    xc = logical_constraint(xc, "batch", None, "model")
    Bt = linear(p["wB"], x)                     # (B,S,K)
    Ct = linear(p["wC"], x)                     # (B,S,K)
    dt = jax.nn.softplus(linear(p["wdt"], x).astype(jnp.float32)
                         + p["dt_bias"])        # (B,S,H)
    g = (-jnp.exp(p["A_log"]) * dt)[..., None]  # (B,S,H,1) log decay
    v = (xc.reshape(B, S, H, dh)
         * dt.astype(x.dtype)[..., None])       # dt-scaled input
    q = jnp.broadcast_to(Ct[:, :, None, :], (B, S, H, K))
    k = jnp.broadcast_to(Bt[:, :, None, :], (B, S, H, K))
    ssm_state_in = None if state is None else state[0]
    y, ssm_state = ssm_scan_chunked(q, k, v, g, initial_state=ssm_state_in,
                                    chunk=min(s.chunk, S),
                                    subchunk=min(s.subchunk, S),
                                    scalar_decay=True,
                                    unroll=cfg.unroll_scans,
                                    shard_constrain=cfg.ssm_shard_constraints,
                                    io_dtype=jnp.bfloat16 if cfg.ssm_bf16_io
                                    else jnp.float32)
    y = y + p["D"].astype(x.dtype)[None, None, :, None] \
        * xc.reshape(B, S, H, dh)
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(p["norm_y"], y * jax.nn.silu(z), cfg.norm_eps)
    out = linear(p["out"], y)
    conv_state = (xz[:, S - (s.d_conv - 1):, :] if state is None
                  else jnp.concatenate([conv_state_in, xz], axis=1)
                  [:, -(s.d_conv - 1):, :])
    return out, (ssm_state, conv_state)


def mamba_decode(p, x, cfg: ArchConfig, state):
    """One token.  x: (B,1,d); state = (ssm (B,H,K,V), conv (B,k-1,D))."""
    s = cfg.ssm
    B, _, d = x.shape
    d_inner, H = _mamba_dims(cfg)
    K, dh = s.d_state, s.head_dim
    ssm_state, conv_state = state
    xz = linear(p["wx"], x)                     # (B,1,d_inner)
    z = linear(p["wz"], x)
    ext = jnp.concatenate([conv_state, xz], axis=1)  # (B,k,d_inner)
    w = p["conv_w"].astype(x.dtype)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", ext, w))[:, None]
    Bt, Ct = linear(p["wB"], x), linear(p["wC"], x)
    dt = jax.nn.softplus(linear(p["wdt"], x).astype(jnp.float32)
                         + p["dt_bias"])[:, 0]  # (B,H)
    g = -jnp.exp(p["A_log"]) * dt               # (B,H)
    v = xc.reshape(B, H, dh) * dt.astype(x.dtype)[..., None]
    q = jnp.broadcast_to(Ct[:, 0, None, :], (B, H, K))
    k = jnp.broadcast_to(Bt[:, 0, None, :], (B, H, K))
    y, ssm_new = ssm_decode_step(q, k, v, g[..., None] *
                                 jnp.ones((1, 1, K), jnp.float32), ssm_state)
    y = y + p["D"].astype(x.dtype)[None, :, None] * xc.reshape(B, H, dh)
    y = y.reshape(B, 1, d_inner)
    y = rmsnorm(p["norm_y"], y * jax.nn.silu(z), cfg.norm_eps)
    return linear(p["out"], y), (ssm_new, ext[:, 1:, :])


# ===========================================================================
# rwkv6 mixer + channel mix ("Finch": data-dependent decay)
# ===========================================================================
def _rwkv_dims(cfg: ArchConfig):
    dh = cfg.ssm.head_dim
    return cfg.d_model // dh, dh


def rwkv_init(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    H, dh = _rwkv_dims(cfg)
    r = cfg.ssm.decay_rank
    ks = jax.random.split(key, 10)
    p = {
        "wr": linear_init(ks[0], d, d, dtype),
        "wk": linear_init(ks[1], d, d, dtype),
        "wv": linear_init(ks[2], d, d, dtype),
        "wg": linear_init(ks[3], d, d, dtype),
        "out": linear_init(ks[4], d, d, dtype,
                           scale=1.0 / math.sqrt(d * 2 * cfg.n_layers)),
        "decay_w1": _normal(ks[5], (d, r), dtype, 1.0 / math.sqrt(d)),
        "decay_w2": _normal(ks[6], (r, d), dtype, 1.0 / math.sqrt(r)),
        "decay_bias": jnp.full((d,), -2.0, jnp.float32),
        "u": _normal(ks[7], (H, dh), jnp.float32, 0.5),
        "ln_y": rmsnorm_init(d, dtype),
    }
    for name in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w"):
        p[name] = jnp.full((d,), 0.5, dtype)
    return p


def _token_shift(x, prev=None):
    """x_{t-1} stream; prev: (B,1,d) carried across segments."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :x.shape[1]]
    return jnp.concatenate([prev, x], axis=1)[:, :x.shape[1]]


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def rwkv_time_mix(p, x, cfg: ArchConfig, state=None):
    """Returns (y, (ssm_state, last_x)).  x: (B,S,d)."""
    B, S, d = x.shape
    H, dh = _rwkv_dims(cfg)
    prev = None if state is None else state[1]
    xs = _token_shift(x, prev)
    r = linear(p["wr"], _mix(x, xs, p["mu_r"])).reshape(B, S, H, dh)
    k = linear(p["wk"], _mix(x, xs, p["mu_k"])).reshape(B, S, H, dh)
    v = linear(p["wv"], _mix(x, xs, p["mu_v"])).reshape(B, S, H, dh)
    gate = jax.nn.silu(linear(p["wg"], _mix(x, xs, p["mu_g"])))
    if cfg.ssm_shard_constraints:
        # keep head-sharded activations head-sharded through the mixer
        r = logical_constraint(r, "batch", None, "model", None)
        k = logical_constraint(k, "batch", None, "model", None)
        v = logical_constraint(v, "batch", None, "model", None)
        gate = logical_constraint(gate, "batch", None, "model")
    # data-dependent decay (the Finch contribution)
    xw = _mix(x, xs, p["mu_w"])
    lora = jnp.tanh(xw @ p["decay_w1"].astype(x.dtype)) \
        @ p["decay_w2"].astype(x.dtype)
    log_w = -jnp.exp(p["decay_bias"] + lora.astype(jnp.float32))  # (B,S,d) <0
    log_w = log_w.reshape(B, S, H, dh)
    ssm_in = None if state is None else state[0]
    y, ssm_state = ssm_scan_chunked(r, k, v, log_w, u=p["u"],
                                    initial_state=ssm_in,
                                    chunk=min(cfg.ssm.chunk, S),
                                    subchunk=min(cfg.ssm.subchunk, S),
                                    unroll=cfg.unroll_scans,
                                    shard_constrain=cfg.ssm_shard_constraints,
                                    io_dtype=jnp.bfloat16 if cfg.ssm_bf16_io
                                    else jnp.float32)
    y = y.reshape(B, S, d)
    y = rmsnorm(p["ln_y"], y, cfg.norm_eps) * gate
    return linear(p["out"], y), (ssm_state, x[:, -1:, :])


def rwkv_time_mix_decode(p, x, cfg: ArchConfig, state):
    B, _, d = x.shape
    H, dh = _rwkv_dims(cfg)
    ssm_state, prev = state
    xs = prev
    r = linear(p["wr"], _mix(x, xs, p["mu_r"])).reshape(B, H, dh)
    k = linear(p["wk"], _mix(x, xs, p["mu_k"])).reshape(B, H, dh)
    v = linear(p["wv"], _mix(x, xs, p["mu_v"])).reshape(B, H, dh)
    gate = jax.nn.silu(linear(p["wg"], _mix(x, xs, p["mu_g"])))
    xw = _mix(x, xs, p["mu_w"])
    lora = jnp.tanh(xw @ p["decay_w1"].astype(x.dtype)) \
        @ p["decay_w2"].astype(x.dtype)
    log_w = -jnp.exp(p["decay_bias"] + lora.astype(jnp.float32))
    log_w = log_w.reshape(B, H, dh)
    y, ssm_new = ssm_decode_step(r, k, v, log_w, ssm_state, u=p["u"])
    y = y.reshape(B, 1, d)
    y = rmsnorm(p["ln_y"], y, cfg.norm_eps) * gate
    return linear(p["out"], y), (ssm_new, x)


def cmix_init(key, cfg: ArchConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wk": linear_init(ks[0], d, f, dtype),
        "wv": linear_init(ks[1], f, d, dtype,
                          scale=1.0 / math.sqrt(f * 2 * cfg.n_layers)),
        "wr": linear_init(ks[2], d, d, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
    }


def cmix_apply(p, x, cfg: ArchConfig, state=None):
    prev = state
    xs = _token_shift(x, prev)
    kk = jnp.square(jax.nn.relu(linear(p["wk"], _mix(x, xs, p["mu_k"]))))
    if cfg.ssm_shard_constraints:
        # the (B,S,d_ff) hidden must stay sharded over "model": without
        # this pin XLA re-gathers 2x 3.5 GiB per layer (measured)
        kk = logical_constraint(kk, "batch", None, "model")
    rr = jax.nn.sigmoid(linear(p["wr"], _mix(x, xs, p["mu_r"])))
    return rr * linear(p["wv"], kk), x[:, -1:, :]


# ===========================================================================
# block = attn_every x (mixer + ffn)
# ===========================================================================
def _layer_kinds(cfg: ArchConfig) -> list[tuple[str, str]]:
    """[(mixer, ffn)] per layer inside one scan block."""
    out = []
    for i, mixer in enumerate(cfg.block_pattern()):
        if mixer == "rwkv":
            out.append(("rwkv", "cmix"))
        else:
            out.append((mixer, cfg.ffn_kind(i)))
    return out


def block_init(key, cfg: ArchConfig, dtype, cross_attention=False):
    layers = []
    kinds = _layer_kinds(cfg)
    keys = jax.random.split(key, len(kinds))
    for kk, (mixer, ffn) in zip(keys, kinds):
        k1, k2, k3, k4 = jax.random.split(kk, 4)
        layer = {"norm1": rmsnorm_init(cfg.d_model, dtype),
                 "norm2": rmsnorm_init(cfg.d_model, dtype)}
        if mixer == "attn":
            layer["attn"] = attention_init(k1, cfg, dtype)
        elif mixer == "mamba":
            layer["mamba"] = mamba_init(k1, cfg, dtype)
        elif mixer == "rwkv":
            layer["rwkv"] = rwkv_init(k1, cfg, dtype)
        if ffn == "dense":
            layer["mlp"] = mlp_init(k2, cfg, dtype)
        elif ffn == "moe":
            layer["moe"] = moe_init(k2, cfg, dtype)
        elif ffn == "cmix":
            layer["cmix"] = cmix_init(k2, cfg, dtype)
        if cross_attention:
            layer["norm_x"] = rmsnorm_init(cfg.d_model, dtype)
            layer["xattn"] = attention_init(k3, cfg, dtype)
        layers.append(layer)
    return {"layers": layers}


def block_apply(bp, x, cfg: ArchConfig, *, causal=True, enc_out=None,
                collect_cache=False, states=None):
    """Full-sequence pass through one block.  Returns (x, cache, aux)."""
    kinds = _layer_kinds(cfg)
    aux = jnp.float32(0.0)
    cache = {"attn_k": [], "attn_v": [], "ssm": [], "conv": [],
             "shift_t": [], "shift_c": [], "cross_k": [], "cross_v": []}
    for i, (layer, (mixer, ffn)) in enumerate(zip(bp["layers"], kinds)):
        h = rmsnorm(layer["norm1"], x, cfg.norm_eps)
        if mixer == "attn":
            out, (k, v) = attention_apply(layer["attn"], h, cfg,
                                          causal=causal)
            if collect_cache:
                cache["attn_k"].append(k)
                cache["attn_v"].append(v)
        elif mixer == "mamba":
            out, (s_ssm, s_conv) = mamba_apply(layer["mamba"], h, cfg)
            if collect_cache:
                cache["ssm"].append(s_ssm)
                cache["conv"].append(s_conv)
        else:  # rwkv
            out, (s_ssm, last) = rwkv_time_mix(layer["rwkv"], h, cfg)
            if collect_cache:
                cache["ssm"].append(s_ssm)
                cache["shift_t"].append(last)
        x = x + out
        if enc_out is not None:
            h = rmsnorm(layer["norm_x"], x, cfg.norm_eps)
            out, (ck, cv) = attention_apply(layer["xattn"], h, cfg,
                                            causal=False, x_kv=enc_out)
            if collect_cache:
                cache["cross_k"].append(ck)
                cache["cross_v"].append(cv)
            x = x + out
        h = rmsnorm(layer["norm2"], x, cfg.norm_eps)
        if ffn == "dense":
            out = mlp_apply(layer["mlp"], h, cfg)
        elif ffn == "moe":
            out, moe_aux = moe_apply(layer["moe"], h, cfg)
            aux = aux + moe_aux["moe_aux"]
        else:  # cmix
            out, last_c = cmix_apply(layer["cmix"], h, cfg)
            if collect_cache:
                cache["shift_c"].append(last_c)
        x = x + out
        x = logical_constraint(x, "batch", None, None)
    cache = {k: jnp.stack(v) for k, v in cache.items() if v}
    return x, cache, aux


def block_decode(bp, x, pos, cfg: ArchConfig, cache):
    """One-token pass.  cache holds per-layer stacked state tensors."""
    kinds = _layer_kinds(cfg)
    counters = {k: 0 for k in ("attn", "ssm", "shift_t", "shift_c", "cross")}
    new_cache = {k: [] for k in cache}
    for i, (layer, (mixer, ffn)) in enumerate(zip(bp["layers"], kinds)):
        h = rmsnorm(layer["norm1"], x, cfg.norm_eps)
        if mixer == "attn":
            j = counters["attn"]
            out, ck, cv = attention_decode(
                layer["attn"], h, cache["attn_k"][j], cache["attn_v"][j],
                pos, cfg)
            new_cache["attn_k"].append(ck)
            new_cache["attn_v"].append(cv)
            counters["attn"] += 1
        elif mixer == "mamba":
            j = counters["ssm"]
            out, (s_ssm, s_conv) = mamba_decode(
                layer["mamba"], h, cfg, (cache["ssm"][j], cache["conv"][j]))
            new_cache["ssm"].append(s_ssm)
            new_cache["conv"].append(s_conv)
            counters["ssm"] += 1
        else:  # rwkv
            j = counters["ssm"]
            out, (s_ssm, last) = rwkv_time_mix_decode(
                layer["rwkv"], h, cfg, (cache["ssm"][j], cache["shift_t"][j]))
            new_cache["ssm"].append(s_ssm)
            new_cache["shift_t"].append(last)
            counters["ssm"] += 1
        x = x + out
        if "cross_k" in cache and "xattn" in layer:
            j = counters["cross"]
            h = rmsnorm(layer["norm_x"], x, cfg.norm_eps)
            out, _, _ = attention_decode(
                layer["xattn"], h, cache["cross_k"][j], cache["cross_v"][j],
                pos, cfg, cross_kv=(cache["cross_k"][j], cache["cross_v"][j]))
            new_cache["cross_k"].append(cache["cross_k"][j])
            new_cache["cross_v"].append(cache["cross_v"][j])
            counters["cross"] += 1
            x = x + out
        h = rmsnorm(layer["norm2"], x, cfg.norm_eps)
        if ffn == "dense":
            out = mlp_apply(layer["mlp"], h, cfg)
        elif ffn == "moe":
            out, _ = moe_apply(layer["moe"], h, cfg)
        else:
            j = counters["shift_c"]
            out, last_c = cmix_apply(layer["cmix"], h, cfg,
                                     state=cache["shift_c"][j])
            new_cache["shift_c"].append(last_c)
            counters["shift_c"] += 1
        x = x + out
    new_cache = {k: jnp.stack(v) for k, v in new_cache.items() if v}
    return x, new_cache


# ===========================================================================
# full model
# ===========================================================================
def init_params(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    scale = 1.0 / math.sqrt(cfg.d_model)
    params = {
        "embed": {"table": _normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                   dtype, scale)},
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    # stacked decoder blocks (scan axis = 0)
    block_keys = jax.random.split(ks[1], cfg.n_blocks)
    blocks = [block_init(k, cfg, dtype, cross_attention=cfg.is_encdec)
              for k in block_keys]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    if not cfg.tie_embeddings:
        params["lm_head"] = linear_init(ks[2], cfg.d_model, cfg.vocab_size,
                                        dtype, scale=scale)
    if cfg.is_encdec:
        enc_keys = jax.random.split(ks[3], cfg.encdec.n_encoder_layers)
        enc = [block_init(k, cfg, dtype) for k in enc_keys]
        params["enc_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
        params["enc_norm"] = rmsnorm_init(cfg.d_model, dtype)
    if cfg.vlm is not None:
        params["vision_proj"] = linear_init(ks[4], cfg.vlm.patch_dim,
                                            cfg.d_model, dtype)
    return params


def _embed(params, tokens, cfg: ArchConfig, batch=None):
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    if cfg.vlm is not None and batch is not None and "image_embeds" in batch:
        img = linear(params["vision_proj"], batch["image_embeds"]
                     .astype(x.dtype))
        n_img = img.shape[1]
        x = lax.dynamic_update_slice_in_dim(x, img, 0, axis=1)
    return logical_constraint(x, "batch", None, None)


def _scan_blocks(params, x, cfg: ArchConfig, *, causal=True, enc_out=None,
                 collect_cache=False):
    def body(carry, bp):
        x, aux = carry
        x, cache, aux_i = block_apply(bp, x, cfg, causal=causal,
                                      enc_out=enc_out,
                                      collect_cache=collect_cache)
        return (x, aux + aux_i), cache

    body_fn = body
    if cfg.remat == "block":
        body_fn = jax.checkpoint(body)
    elif cfg.remat == "dots":
        # selective: save matmul outputs, recompute elementwise — avoids
        # re-all-gathering FSDP weights in the backward recompute
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    (x, aux), caches = lax.scan(body_fn, (x, jnp.float32(0.0)),
                                params["blocks"],
                                unroll=cfg.n_blocks if cfg.unroll_blocks
                                else 1)
    return x, aux, caches


def _encode(params, audio_embeds, cfg: ArchConfig):
    x = audio_embeds.astype(jnp.dtype(cfg.compute_dtype))

    def body(carry, bp):
        h, _, _ = block_apply(bp, carry, cfg, causal=False)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat == "block" else body
    x, _ = lax.scan(body_fn, x, params["enc_blocks"],
                    unroll=(cfg.encdec.n_encoder_layers
                            if cfg.unroll_blocks else 1))
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def final_hidden(params, batch, cfg: ArchConfig, collect_cache=False):
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, batch["audio_embeds"], cfg)
    x = _embed(params, batch["tokens"], cfg, batch)
    x, aux, caches = _scan_blocks(params, x, cfg, causal=True,
                                  enc_out=enc_out,
                                  collect_cache=collect_cache)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux, caches


def loss_fn(params, batch, cfg: ArchConfig, aux_weight: float = 0.01):
    x, aux, _ = final_hidden(params, batch, cfg)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["w"].T)
    xent = chunked_xent(table, x, batch["labels"],
                        chunk=min(cfg.logit_chunk, x.shape[1]),
                        unroll=cfg.unroll_scans)
    return xent + aux_weight * aux, {"xent": xent, "moe_aux": aux}


def logits_last(params, x_last, cfg: ArchConfig):
    """x_last: (B, 1, d) -> (B, 1, V) fp32."""
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["w"].T)
    return (x_last @ table.astype(x_last.dtype).T).astype(jnp.float32)


def prefill(params, batch, cfg: ArchConfig, pad_to: int | None = None):
    """Builds a serving cache; returns (last-token logits, cache, pos).

    ``pad_to`` sizes the attention KV cache for subsequent decode."""
    x, aux, caches = final_hidden(params, batch, cfg, collect_cache=True)
    S = batch["tokens"].shape[1]
    if pad_to is not None and "attn_k" in caches and pad_to > S:
        pad = pad_to - S
        for key in ("attn_k", "attn_v"):
            c = caches[key]
            caches[key] = jnp.pad(
                c, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    lg = logits_last(params, x[:, -1:, :], cfg)
    return lg, caches, S


def decode_step(params, cache, token, pos, cfg: ArchConfig):
    """token: (B, 1) int32; pos: scalar int32.  Returns (logits, cache)."""
    x = _embed(params, token, cfg)

    def body(x, inp):
        bp, cache_b = inp
        x, new_cache = block_decode(bp, x, pos, cfg, cache_b)
        return x, new_cache

    x, new_caches = lax.scan(body, x, (params["blocks"], cache),
                             unroll=cfg.n_blocks if cfg.unroll_blocks else 1)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_last(params, x, cfg), new_caches


def make_decode_cache(cfg: ArchConfig, batch: int, max_seq: int,
                      dtype=None, enc_len: int | None = None):
    """Abstract/zero cache for serve_step lowering and serving."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    kinds = _layer_kinds(cfg)
    nb = cfg.n_blocks
    n_attn = sum(1 for m, _ in kinds if m == "attn")
    n_mamba = sum(1 for m, _ in kinds if m == "mamba")
    n_rwkv = sum(1 for m, _ in kinds if m == "rwkv")
    dh = cfg.head_dim
    cache = {}
    if n_attn:
        shape = (nb, n_attn, batch, max_seq, cfg.n_kv_heads, dh)
        cache["attn_k"] = jnp.zeros(shape, dtype)
        cache["attn_v"] = jnp.zeros(shape, dtype)
    if n_mamba:
        d_inner, H = _mamba_dims(cfg)
        K, hd = cfg.ssm.d_state, cfg.ssm.head_dim
        cache["ssm"] = jnp.zeros((nb, n_mamba, batch, H, K, hd), jnp.float32)
        cache["conv"] = jnp.zeros((nb, n_mamba, batch, cfg.ssm.d_conv - 1,
                                   d_inner), dtype)
    if n_rwkv:
        H, hd = _rwkv_dims(cfg)
        cache["ssm"] = jnp.zeros((nb, n_rwkv, batch, H, hd, hd), jnp.float32)
        cache["shift_t"] = jnp.zeros((nb, n_rwkv, batch, 1, cfg.d_model),
                                     dtype)
        cache["shift_c"] = jnp.zeros((nb, n_rwkv, batch, 1, cfg.d_model),
                                     dtype)
    if cfg.is_encdec:
        el = enc_len or cfg.encdec.n_audio_ctx
        shape = (nb, len(kinds), batch, el, cfg.n_kv_heads, dh)
        cache["cross_k"] = jnp.zeros(shape, dtype)
        cache["cross_v"] = jnp.zeros(shape, dtype)
    return cache
