"""Composable layer library shared by all ten architectures.

Functional style: every layer is (init_fn -> params pytree,
apply_fn(params, x, ...)).  Param-tree *path names* are load-bearing —
the sharding rules in ``repro.sharding.rules`` match on them.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .common import ArchConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def linear_init(key, d_in, d_out, dtype, bias=False, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": _normal(key, (d_in, d_out), dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * lax.rsqrt(var + eps)
    return (h * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA + optional bias + optional sliding window)
# ---------------------------------------------------------------------------
def attention_init(key, cfg: ArchConfig, dtype):
    dh = cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": linear_init(ks[0], cfg.d_model, cfg.n_heads * dh, dtype,
                          bias=cfg.qkv_bias),
        "wk": linear_init(ks[1], cfg.d_model, cfg.n_kv_heads * dh, dtype,
                          bias=cfg.qkv_bias),
        "wv": linear_init(ks[2], cfg.d_model, cfg.n_kv_heads * dh, dtype,
                          bias=cfg.qkv_bias),
        "wo": linear_init(ks[3], cfg.n_heads * dh, cfg.d_model, dtype,
                          scale=1.0 / math.sqrt(cfg.n_heads * dh * 2 * cfg.n_layers)),
    }


def _qkv(p, x, x_kv, cfg):
    B, S = x.shape[:2]
    dh = cfg.head_dim
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, dh)
    kv_src = x if x_kv is None else x_kv
    Skv = kv_src.shape[1]
    k = linear(p["wk"], kv_src).reshape(B, Skv, cfg.n_kv_heads, dh)
    v = linear(p["wv"], kv_src).reshape(B, Skv, cfg.n_kv_heads, dh)
    return q, k, v


def full_attention(q, k, v, *, causal, window=None, q_offset=0,
                   kv_positions=None):
    """Reference softmax attention.  q: (B,Sq,H,dh) k/v: (B,Skv,KV,dh)."""
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(dh)
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(k.shape[1]) if kv_positions is None else kv_positions
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, dh)


def chunked_attention(q, k, v, *, causal, window=None, chunk=1024,
                      q_offset=0, unroll=False, shard_constrain=False,
                      accum_bf16=False):
    """Online-softmax attention streamed over KV chunks — the memory
    behaviour of the flash kernel (never materializes Sq x Skv), used
    for large-shape lowering and as the Pallas oracle's outer loop."""
    B, Sq, H, dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    if Skv % chunk:
        pad = chunk - Skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    qg = (q.reshape(B, Sq, KV, G, dh).astype(jnp.float32)
          * (1.0 / math.sqrt(dh)))
    q_pos = jnp.arange(Sq) + q_offset

    kc = k.reshape(B, n_chunks, chunk, KV, dh)
    vc = v.reshape(B, n_chunks, chunk, KV, dh)
    if shard_constrain:
        from ..sharding.rules import logical_constraint
        kc = logical_constraint(kc, "batch", None, None, "kv_heads", None)
        vc = logical_constraint(vc, "batch", None, None, "kv_heads", None)

    def step(carry, inputs):
        m, l, acc = carry
        idx, k_i, v_i = inputs
        k_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_i.astype(jnp.float32))
        mask = k_pos[None, :] < Skv
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + p.sum(axis=-1)
        if accum_bf16:
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(jnp.bfloat16),
                            v_i.astype(jnp.bfloat16))
            acc_new = (acc * corr[..., None].astype(acc.dtype)
                       + pv.astype(acc.dtype))
        else:
            acc_new = (acc * corr[..., None]
                       + jnp.einsum("bkgqs,bskd->bkgqd", p,
                                    v_i.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, dh),
                   jnp.bfloat16 if accum_bf16 else jnp.float32)
    (m, l, acc), _ = lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
        unroll=n_chunks if unroll else 1)
    out = acc.astype(jnp.float32) / jnp.maximum(l, 1e-20)[..., None]
    out = jnp.moveaxis(out, 3, 1)  # (B, Sq, KV, G, dh)
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


def attention_apply(p, x, cfg: ArchConfig, *, causal=True, positions=None,
                    x_kv=None, use_rope=True):
    """Full-sequence (train/prefill) attention; returns (out, (k, v))."""
    B, S = x.shape[:2]
    q, k, v = _qkv(p, x, x_kv, cfg)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if use_rope and x_kv is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_shard_constraints:
        from ..sharding.rules import logical_constraint
        q = logical_constraint(q, "batch", None, "model", None)
        k = logical_constraint(k, "batch", None, "kv_heads", None)
        v = logical_constraint(v, "batch", None, "kv_heads", None)
    if cfg.attn_impl == "full" or x_kv is not None:
        out = full_attention(q, k, v, causal=causal, window=cfg.swa_window)
    else:
        from ..kernels.flash_attention.ops import flash_attention_auto
        out = flash_attention_auto(q, k, v, causal=causal,
                                   window=cfg.swa_window, cfg=cfg)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return linear(p["wo"], out), (k, v)


def attention_decode(p, x, cache_k, cache_v, pos, cfg: ArchConfig, *,
                     use_rope=True, cross_kv=None):
    """One-token decode against a fixed-size KV cache.

    x: (B, 1, d); cache_k/v: (B, S_max, KV, dh); pos: scalar int32.
    Returns (out, new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    dh = cfg.head_dim
    if cross_kv is not None:
        k, v = cross_kv
        q = linear(p["wq"], x).reshape(B, 1, cfg.n_heads, dh)
        kv_len = k.shape[1]
        mask_pos = jnp.arange(kv_len) < kv_len  # all visible
    else:
        q = linear(p["wq"], x).reshape(B, 1, cfg.n_heads, dh)
        k_new = linear(p["wk"], x).reshape(B, 1, cfg.n_kv_heads, dh)
        v_new = linear(p["wv"], x).reshape(B, 1, cfg.n_kv_heads, dh)
        if use_rope:
            pos_arr = jnp.full((B, 1), pos, jnp.int32)
            q = apply_rope(q, pos_arr, cfg.rope_theta)
            k_new = apply_rope(k_new, pos_arr, cfg.rope_theta)
        cache_k = lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), pos, axis=1)
        cache_v = lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), pos, axis=1)
        k, v = cache_k, cache_v
        kv_len = k.shape[1]
        mask_pos = jnp.arange(kv_len) <= pos
        if cfg.swa_window is not None:
            mask_pos &= jnp.arange(kv_len) > pos - cfg.swa_window
    KV = k.shape[2]
    G = cfg.n_heads // KV
    qg = q.reshape(B, KV, G, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32)
    s *= 1.0 / math.sqrt(dh)
    s = jnp.where(mask_pos[None, None, None, :], s, -1e30)
    p_attn = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p_attn, v)
    out = out.reshape(B, 1, cfg.n_heads * dh)
    return linear(p["wo"], out), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP (SwiGLU or GELU)
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / math.sqrt(cfg.d_ff * 2 * cfg.n_layers)
    if cfg.gated_mlp:
        return {
            "wi": linear_init(ks[0], cfg.d_model, cfg.d_ff, dtype),
            "wg": linear_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
            "wo": linear_init(ks[2], cfg.d_ff, cfg.d_model, dtype,
                              scale=out_scale),
        }
    return {
        "wi": linear_init(ks[0], cfg.d_model, cfg.d_ff, dtype, bias=True),
        "wo": linear_init(ks[2], cfg.d_ff, cfg.d_model, dtype, bias=True,
                          scale=out_scale),
    }


def mlp_apply(p, x, cfg: ArchConfig):
    if cfg.gated_mlp:
        h = jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x)
    else:
        h = jax.nn.gelu(linear(p["wi"], x))
    return linear(p["wo"], h)


# ---------------------------------------------------------------------------
# sequence-chunked cross-entropy (never materializes full logits)
# ---------------------------------------------------------------------------
def chunked_xent(embed_table, x, labels, *, chunk: int, z_weight: float = 0.0,
                 unroll: bool = False):
    """x: (B, S, d) final hidden; labels: (B, S) int32 (-1 = ignore).

    Computes mean token xent by scanning S in chunks so the (B, S, V)
    logits tensor never exists — the standard big-vocab memory trick.
    """
    B, S, D = x.shape
    V = embed_table.shape[0]
    n = S // chunk
    assert S % chunk == 0, (S, chunk)
    xc = jnp.moveaxis(x.reshape(B, n, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    table = embed_table.astype(x.dtype)

    def step(carry, inp):
        tot, cnt = carry
        xi, li = inp
        logits = (xi @ table.T).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.clip(li, 0, V - 1)[..., None], axis=-1)[..., 0]
        valid = li >= 0
        loss = jnp.where(valid, lse - gold, 0.0)
        if z_weight:
            loss = loss + jnp.where(valid, z_weight * lse * lse, 0.0)
        return (tot + loss.sum(), cnt + valid.sum()), None

    (tot, cnt), _ = lax.scan(step, (jnp.float32(0.0), jnp.int32(0)), (xc, lc),
                             unroll=n if unroll else 1)
    return tot / jnp.maximum(cnt, 1)
