"""Mixture-of-Experts FFN with top-k routing and scatter dispatch.

Dispatch is the scatter/gather formulation (GShard-style positions, but
without materializing the (T, E, C) one-hot dispatch tensor): tokens are
scatter-added into per-expert capacity buffers, expert FFNs run as one
batched einsum over (E, C, D), and outputs gather back weighted by the
renormalized router probabilities.  Experts shard over the "model" mesh
axis (expert parallelism); capacity shards over the data axes.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ArchConfig
from .layers import _normal


def moe_init(key, cfg: ArchConfig, dtype):
    m = cfg.moe
    ks = jax.random.split(key, 4)
    d, f, E = cfg.d_model, cfg.d_ff, m.n_experts
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f * 2 * cfg.n_layers)
    p = {
        "router": {"w": _normal(ks[0], (d, E), jnp.float32, scale_in)},
        "wi": _normal(ks[1], (E, d, f), dtype, scale_in),
        "wo": _normal(ks[2], (E, f, d), dtype, scale_out),
    }
    if cfg.gated_mlp:
        p["wg"] = _normal(ks[3], (E, d, f), dtype, scale_in)
    return p


def capacity(n_tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(n_tokens * m.top_k * m.capacity_factor / m.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_apply(p, x, cfg: ArchConfig):
    """x: (B, S, D) -> (y: (B, S, D), aux: dict with load-balance loss)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    C = capacity(T, cfg)
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate, idx = jax.lax.top_k(probs, K)                          # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)  # renorm

    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)             # (T, K, E)
    flat = onehot.reshape(T * K, E)
    pos = jnp.cumsum(flat, axis=0) - 1                           # (T*K, E)
    pos = jnp.sum(pos * flat, axis=-1)                           # (T*K,)
    expert = idx.reshape(T * K)
    keep = pos < C                                               # capacity drop

    # scatter tokens into (E, C, D) buffers
    token_idx = jnp.repeat(jnp.arange(T), K)
    src = jnp.take(xt, token_idx, axis=0)                        # (T*K, D)
    src = src * keep[:, None].astype(src.dtype)
    pos_c = jnp.where(keep, pos, C - 1)
    buf = jnp.zeros((E, C, D), x.dtype)
    if cfg.moe_shard_constraints == "expert":
        from ..sharding.rules import logical_constraint
        src = logical_constraint(src, "batch", None)
        buf = logical_constraint(buf, "expert", None, None)
    elif cfg.moe_shard_constraints == "capacity":
        from ..sharding.rules import logical_constraint
        src = logical_constraint(src, "batch", None)
        buf = logical_constraint(buf, "expert", "batch", None)
    buf = buf.at[expert, pos_c].add(src, mode="drop",
                                    unique_indices=False)
    if cfg.moe_shard_constraints == "expert":
        from ..sharding.rules import logical_constraint
        buf = logical_constraint(buf, "expert", None, None)
    elif cfg.moe_shard_constraints == "capacity":
        from ..sharding.rules import logical_constraint
        buf = logical_constraint(buf, "expert", "batch", None)

    # batched expert FFN on the MXU: (E, C, D) x (E, D, F)
    h_in = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(x.dtype))
    if "wg" in p:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                   p["wg"].astype(x.dtype))) * h_in
    else:
        h = jax.nn.gelu(h_in)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))
    if cfg.moe_shard_constraints == "expert":
        from ..sharding.rules import logical_constraint
        h = logical_constraint(h, "expert", None, None)
        out_buf = logical_constraint(out_buf, "expert", None, None)
    elif cfg.moe_shard_constraints == "capacity":
        from ..sharding.rules import logical_constraint
        h = logical_constraint(h, "expert", "batch", None)
        out_buf = logical_constraint(out_buf, "expert", "batch", None)

    # gather back + weighted combine over the K slots
    gathered = out_buf[expert, pos_c]                            # (T*K, D)
    gathered = gathered * (keep[:, None] * gate.reshape(T * K)[:, None]
                           ).astype(x.dtype)
    y = jnp.sum(gathered.reshape(T, K, D), axis=1)

    # GShard/Switch load-balance auxiliary loss
    me = probs.mean(axis=0)                                      # (E,)
    ce = (onehot.sum(axis=1).astype(jnp.float32)).mean(axis=0)   # fraction routed
    aux_loss = E * jnp.sum(me * ce) / K
    return y.reshape(B, S, D), {"moe_aux": aux_loss,
                                "moe_drop_frac":
                                    1.0 - keep.mean().astype(jnp.float32)}
