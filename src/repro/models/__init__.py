from .common import (ArchConfig, EncDecConfig, MoEConfig, SSMConfig,
                     ShapeConfig, SHAPES, VLMConfig, cells_for,
                     LONG_CONTEXT_OK)
from .registry import ModelApi, build, input_specs

__all__ = ["ArchConfig", "EncDecConfig", "MoEConfig", "SSMConfig",
           "ShapeConfig", "SHAPES", "VLMConfig", "cells_for",
           "LONG_CONTEXT_OK", "ModelApi", "build", "input_specs"]
