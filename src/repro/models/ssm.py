"""Chunked gated linear recurrence — the shared math under the Jamba
mamba layers (Mamba-2/SSD-style scalar-per-head decay) and RWKV6
(per-key data-dependent decay, "Finch").

Recurrence (per batch b, head h; K = key dim, V = value dim):

    S_t = diag(a_t) @ S_{t-1} + k_t^T v_t          S in R^{K x V}
    y_t = q_t @ S_t                                 (mamba2; inclusive)
    y_t = q_t @ (S_{t-1} + diag(u) k_t^T v_t)       (rwkv6; u = bonus)

with a_t = exp(g_t), g_t <= 0.  Two implementations:

* ``ssm_scan_ref``    — exact step recurrence via ``lax.scan`` (oracle).
* ``ssm_scan_chunked``— chunk-parallel form: intra-(sub)chunk pairwise
  term + inter-chunk state carry.  Every exponent is a difference
  z_i - z_j with j <= i of a *decreasing* cumulative log-decay, hence
  <= 0: numerically safe without clamping.  This is the formulation the
  Pallas ``ssm_scan`` kernel implements on the MXU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssm_scan_ref(q, k, v, log_decay, u=None, initial_state=None):
    """Exact recurrence.  Shapes:
      q, k: (B, T, H, K); v: (B, T, H, V); log_decay: (B, T, H, K)
      u: (H, K) or None; initial_state: (B, H, K, V) or None.
    Returns (y: (B, T, H, V), final_state: (B, H, K, V)).  float32 inside.
    """
    B, T, H, K = q.shape
    V = v.shape[-1]
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    gf = log_decay.astype(jnp.float32)
    S0 = (jnp.zeros((B, H, K, V), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(S, inp):
        qt, kt, vt, gt = inp  # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,K,V)
        if u is None:
            S_new = jnp.exp(gt)[..., None] * S + kv
            y = jnp.einsum("bhk,bhkv->bhv", qt, S_new)
        else:
            y = jnp.einsum("bhk,bhkv->bhv", qt,
                           S + u.astype(jnp.float32)[None, :, :, None] * kv)
            S_new = jnp.exp(gt)[..., None] * S + kv
        return S_new, y

    xs = (jnp.moveaxis(qf, 1, 0), jnp.moveaxis(kf, 1, 0),
          jnp.moveaxis(vf, 1, 0), jnp.moveaxis(gf, 1, 0))
    S_fin, ys = lax.scan(step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,T,H,V)
    return y.astype(q.dtype), S_fin


def ssm_scan_chunked(q, k, v, log_decay, u=None, initial_state=None,
                     chunk: int = 128, subchunk: int = 16,
                     scalar_decay: bool = False, unroll: bool = False,
                     shard_constrain: bool = False,
                     io_dtype=jnp.float32):
    """Chunk-parallel equivalent of :func:`ssm_scan_ref`.

    ``scalar_decay=True`` asserts log_decay is constant over K (mamba2's
    per-head scalar), enabling the cheap (R, R) pairwise path instead of
    the per-key (R, R, K) one.
    """
    B, T, H, K = q.shape
    V = v.shape[-1]
    L = min(chunk, T)
    pad = (-T) % L
    if pad:  # zero k/v/g padding is inert to the recurrence
        pc = ((0, 0), (0, pad), (0, 0), (0, 0))
        y_pad, s_fin = ssm_scan_chunked(
            jnp.pad(q, pc), jnp.pad(k, pc), jnp.pad(v, pc),
            jnp.pad(log_decay, pc), u=u, initial_state=initial_state,
            chunk=L, subchunk=subchunk, scalar_decay=scalar_decay,
            unroll=unroll, shard_constrain=shard_constrain,
            io_dtype=io_dtype)
        return y_pad[:, :T], s_fin
    R = min(subchunk, L)
    if L % R:
        raise ValueError(f"chunk={L} must divide by subchunk={R}")
    NC, NS = T // L, L // R

    qf = q.astype(io_dtype).reshape(B, NC, L, H, K)
    kf = k.astype(io_dtype).reshape(B, NC, L, H, K)
    vf = v.astype(io_dtype).reshape(B, NC, L, H, V)
    Kg = log_decay.shape[-1]  # 1 for scalar-per-head decay (broadcasts)
    gf = log_decay.astype(jnp.float32).reshape(B, NC, L, H, Kg)
    if shard_constrain:
        from ..sharding.rules import logical_constraint
        spec = ("batch", None, None, "model", None)
        qf = logical_constraint(qf, *spec)
        kf = logical_constraint(kf, *spec)
        vf = logical_constraint(vf, *spec)
        gf = logical_constraint(gf, *spec)
    uf = None if u is None else u.astype(jnp.float32)
    S0 = (jnp.zeros((B, H, K, V), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    causal_incl = jnp.tril(jnp.ones((R, R), jnp.float32))
    causal_strict = jnp.tril(jnp.ones((R, R), jnp.float32), k=-1)

    def chunk_step(S, inp):
        qc, kc, vc, gc = inp           # (B,L,H,K/V)
        qc, kc, vc = (t.astype(jnp.float32) for t in (qc, kc, vc))
        z = jnp.cumsum(gc, axis=1)     # inclusive cumulative log decay
        # rwkv6 reads the state *before* the current step's decay: the
        # q-side exponent uses the exclusive cumsum z - g.
        zq_all = z - gc if uf is not None else z
        ys = []
        for s in range(NS):
            sl = slice(s * R, (s + 1) * R)
            qs, ks, vs = qc[:, sl], kc[:, sl], vc[:, sl]
            zs, zqs = z[:, sl], zq_all[:, sl]
            z_start = (z[:, s * R - 1] if s > 0
                       else jnp.zeros_like(z[:, 0]))  # (B,H,K)
            z_end = z[:, (s + 1) * R - 1]
            # inter: contribution of the running state S
            q_dec = qs * jnp.exp(zqs - z_start[:, None])     # exp <= 1
            y = jnp.einsum("brhk,bhkv->brhv", q_dec, S)
            # intra: pairwise within the sub-chunk
            if scalar_decay:
                zh, zqh = zs[..., 0], zqs[..., 0]            # (B,R,H)
                E = jnp.exp(zqh[:, :, None] - zh[:, None])   # (B,R,R,H), j<=i safe
                A = jnp.einsum("bihk,bjhk->bijh", qs, ks) * E
                mask = causal_strict if uf is not None else causal_incl
                A = A * mask[None, :, :, None]
                y = y + jnp.einsum("bijh,bjhv->bihv", A, vs)
            else:
                # per-key decay: (R,R,K) pairwise in sub-chunks only
                Ez = jnp.exp(zqs[:, :, None] - zs[:, None])  # (B,R,R,H,K)
                A = jnp.einsum("bihk,bjhk,bijhk->bijh", qs, ks, Ez)
                mask = causal_strict if uf is not None else causal_incl
                A = A * mask[None, :, :, None]
                y = y + jnp.einsum("bijh,bjhv->bihv", A, vs)
            if uf is not None:  # rwkv6 current-token bonus
                bonus = jnp.einsum("brhk,hk,brhk->brh", qs, uf, ks)
                y = y + bonus[..., None] * vs
            ys.append(y)
            # state carry to next sub-chunk (all exponents <= 0)
            k_dec = ks * jnp.exp(z_end[:, None] - zs)
            S = (jnp.exp(z_end - z_start)[..., None] * S
                 + jnp.einsum("brhk,brhv->bhkv", k_dec, vs))
        return S, jnp.concatenate(ys, axis=1)

    S_fin, yc = lax.scan(chunk_step, S0,
                         tuple(jnp.moveaxis(t, 1, 0)
                               for t in (qf, kf, vf, gf)),
                         unroll=NC if unroll else 1)
    y = jnp.moveaxis(yc, 0, 1).reshape(B, T, H, V)
    return y.astype(q.dtype), S_fin


def ssm_decode_step(q, k, v, log_decay, state, u=None):
    """One-token decode: q,k: (B,H,K); v: (B,H,V); state: (B,H,K,V)."""
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    gf = log_decay.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    if u is None:
        S_new = jnp.exp(gf)[..., None] * state + kv
        y = jnp.einsum("bhk,bhkv->bhv", qf, S_new)
    else:
        y = jnp.einsum("bhk,bhkv->bhv", qf,
                       state + u.astype(jnp.float32)[None, :, :, None] * kv)
        S_new = jnp.exp(gf)[..., None] * state + kv
    return y.astype(q.dtype), S_new
