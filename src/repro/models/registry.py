"""Public model API: build any assigned architecture from its config and
get (init / train_step loss / prefill / decode) functions plus
ShapeDtypeStruct ``input_specs`` for dry-run lowering."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from . import transformer as tf
from .common import ArchConfig, ShapeConfig, SHAPES


@dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    init: Callable          # key -> params
    loss: Callable          # (params, batch) -> (loss, metrics)
    prefill: Callable       # (params, batch, pad_to) -> (logits, cache, pos)
    decode: Callable        # (params, cache, token, pos) -> (logits, cache)

    def abstract_params(self, key=None):
        key = jax.random.PRNGKey(0) if key is None else key
        return jax.eval_shape(self.init, key)

    def make_cache(self, batch: int, max_seq: int, abstract: bool = False,
                   enc_len: int | None = None):
        fn = lambda: tf.make_decode_cache(self.cfg, batch, max_seq,
                                          enc_len=enc_len)
        return jax.eval_shape(fn) if abstract else fn()


def build(cfg: ArchConfig) -> ModelApi:
    def init(key):
        return tf.init_params(key, cfg)

    def loss(params, batch):
        return tf.loss_fn(params, batch, cfg)

    def prefill(params, batch, pad_to=None):
        return tf.prefill(params, batch, cfg, pad_to=pad_to)

    def decode(params, cache, token, pos):
        return tf.decode_step(params, cache, token, pos, cfg)

    return ModelApi(cfg=cfg, init=init, loss=loss, prefill=prefill,
                    decode=decode)


# ---------------------------------------------------------------------------
# input specs per (arch, shape) cell — ShapeDtypeStructs, no allocation
# ---------------------------------------------------------------------------
def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.is_encdec:
        specs["audio_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encdec.n_audio_ctx, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    if cfg.vlm is not None:
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vlm.n_image_tokens, cfg.vlm.patch_dim),
            jnp.dtype(cfg.compute_dtype))
    return specs


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    specs = train_batch_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_specs(cfg: ArchConfig, shape: ShapeConfig):
    """(token, pos, cache) specs for serve_step lowering: one new token
    against a KV/state cache of seq_len."""
    B, S = shape.global_batch, shape.seq_len
    api = build(cfg)
    cache = api.make_cache(B, S, abstract=True)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return token, pos, cache


def input_specs(cfg: ArchConfig, shape_name: str):
    """Everything dryrun needs for one (arch x shape) cell."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    token, pos, cache = decode_specs(cfg, shape)
    return {"token": token, "pos": pos, "cache": cache}
