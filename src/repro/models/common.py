"""Architecture configuration for the model zoo.

One config dataclass covers all ten assigned architectures; family-
specific sub-configs are optional.  Exact full-size configs live in
``repro.configs.<arch_id>``; smoke tests build reduced configs with
``scaled_down``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    moe_every: int = 1          # apply MoE FFN every k-th layer (jamba: 2)
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    """Chunked gated-linear-recurrence family (Mamba-2-style SSD for the
    jamba layers, RWKV6 'Finch' for rwkv).  See DESIGN.md §5 for the
    TPU adaptation rationale."""

    kind: str = "mamba2"        # "mamba2" | "rwkv6"
    d_state: int = 64           # key dim per head
    head_dim: int = 64          # value dim per head
    expand: int = 2             # d_inner = expand * d_model (mamba)
    d_conv: int = 4             # causal depthwise conv width (mamba)
    chunk: int = 128            # chunked-scan block length
    subchunk: int = 16          # intra-chunk pairwise tile (TPU: 128)
    decay_rank: int = 64        # low-rank data-dependent decay (rwkv6)


@dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int
    n_audio_ctx: int = 1500     # whisper: 30 s of 10 ms frames / 2 (conv stub)


@dataclass(frozen=True)
class VLMConfig:
    n_image_tokens: int = 1152  # anyres tiling stub: pre-projected patches
    patch_dim: int = 1024       # frontend embedding dim before projector


@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    swa_window: int | None = None
    gated_mlp: bool = True      # SwiGLU vs plain GELU MLP
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # hybrid interleave: one attention layer per `attn_every` layers
    attn_every: int = 1         # jamba: 8 (1 attn : 7 mamba)
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    encdec: EncDecConfig | None = None
    vlm: VLMConfig | None = None
    # dtype policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # loss
    logit_chunk: int = 512      # sequence-chunked xent (memory control)
    # implementation toggles
    attn_impl: str = "chunked"  # full | chunked | pallas
    attn_chunk: int = 1024      # KV block for chunked/online-softmax attn
    remat: str = "block"        # none | block
    # dry-run costing: fully unroll inner lax.scans (attention/ssm/xent
    # chunks) so XLA cost_analysis counts all iterations; unroll_blocks
    # additionally unrolls the layer-block scan (used by the 1/2-block
    # extrapolation compiles).  Inner unrolling also avoids XLA's
    # pathological nested-while SPMD compile times for hybrid archs.
    unroll_scans: bool = False
    unroll_blocks: bool = False
    # §Perf iteration: pin q/k/v and the chunked-attention KV blocks to
    # (batch, kv_heads) shardings so scan xs slicing doesn't reshard
    # every iteration (fixes the SPMD 'involuntary full remat' path)
    attn_shard_constraints: bool = False
    # §Perf iteration: carry the online-softmax accumulator/probabilities
    # in bf16 (statistics m/l stay fp32) — halves the attention-chunk
    # intermediate traffic
    attn_accum_bf16: bool = False
    # §Perf iteration: pin ssm-chunk scan operands to (batch, heads)
    # shardings (same involuntary-remat fix as attention)
    ssm_shard_constraints: bool = False
    # §Perf iteration: keep ssm-chunk operands in bf16 in HBM (state and
    # accumulation stay fp32 — the Pallas kernel's VMEM behaviour)
    ssm_bf16_io: bool = False
    # §Perf iteration: pin MoE dispatch buffers — "" (off), "expert"
    # (E over model; kills the replicated-buffer all-reduce but XLA
    # rewrites the scatter densely), or "capacity" (E over model + C
    # over data)
    moe_shard_constraints: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.encdec is not None

    def block_pattern(self) -> list[str]:
        """Per-layer kind within one scan block.

        For homogeneous stacks the block is one layer; for jamba the
        block is ``attn_every`` layers (1 attention + N-1 mamba), so
        ``lax.scan`` runs over n_layers // attn_every identical blocks.
        """
        if self.family == "ssm":
            return ["rwkv"]
        if self.attn_every == 1:
            return ["attn"]
        pat = ["mamba"] * self.attn_every
        pat[self.attn_every - 1] = "attn"  # attention closes each block
        return pat

    @property
    def n_blocks(self) -> int:
        if self.n_layers % self.attn_every:
            raise ValueError("n_layers must divide by attn_every")
        if self.family == "ssm":
            return self.n_layers
        return self.n_layers // self.attn_every

    def ffn_kind(self, layer_in_block: int, block_idx: int = 0) -> str:
        """'moe' or 'dense' for a given layer position."""
        if self.moe is None:
            return "dense"
        # global layer index = block_idx * attn_every + layer_in_block;
        # inside a scan block the pattern must not depend on block_idx,
        # so moe_every must divide attn_every (or be 1).
        if self.moe.moe_every == 1:
            return "moe"
        return "moe" if (layer_in_block % self.moe.moe_every
                         == self.moe.moe_every - 1) else "dense"

    def scaled_down(self, **overrides) -> "ArchConfig":
        """Reduced config of the same family for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 * self.attn_every),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // self.n_heads)),
            d_ff=256,
            vocab_size=512,
            d_head=32,
            param_dtype="float32",
            compute_dtype="float32",
            logit_chunk=64,
            attn_chunk=64,
        )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2))
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=16, decay_rank=8)
        if self.encdec is not None:
            small["encdec"] = dataclasses.replace(
                self.encdec, n_encoder_layers=2, n_audio_ctx=24)
        if self.vlm is not None:
            small["vlm"] = dataclasses.replace(
                self.vlm, n_image_tokens=16, patch_dim=64)
        if self.swa_window is not None:
            small["swa_window"] = 64
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""

    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

#: archs whose long_500k cell is skipped (pure full-attention; see
#: DESIGN.md §3) — sub-quadratic archs run it.
LONG_CONTEXT_OK = {"jamba-1.5-large-398b", "rwkv6-7b", "h2o-danube-3-4b",
                   "llava-next-mistral-7b"}


def cells_for(arch_id: str) -> list[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_id in LONG_CONTEXT_OK:
        names.append("long_500k")
    return names
