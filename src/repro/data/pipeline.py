"""Connector-backed training data pipeline.

Tokenized shards are fixed-record binary objects behind a Connector
(paper §3): each host session reads only its slice using ranged reads —
the ``get_read_range`` machinery — so the same code path serves POSIX
scratch, the emulated cloud stores, or anything else with a Connector.

Features needed at 1000-node scale:
* deterministic host sharding: shard s belongs to host (s mod n_hosts),
* resumable: iterator state is (epoch, shard_cursor, record_cursor) and
  round-trips through the train checkpoint,
* background prefetch (double buffering) with a bounded queue,
* straggler mitigation: hedged reads — if a shard read exceeds
  ``hedge_factor`` x the trailing-median latency, a second request is
  issued (to the replica connector when configured) and the first
  response wins (paper §2.2's retry machinery, applied to reads).
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from ..core.connector import Connector, Credential
from .. import ckpt as _ckpt
from ..ckpt.io import get_bytes, put_bytes

RECORD_DTYPE = np.int32


@dataclass
class DataPipelineConfig:
    seq_len: int = 1024
    batch_size: int = 8            # per-host sequences per step
    host_id: int = 0
    n_hosts: int = 1
    prefetch: int = 2
    seed: int = 0
    hedge_factor: float = 4.0
    hedge_min_samples: int = 8


class TokenShardWriter:
    """Writes fixed-length token records as shard objects."""

    def __init__(self, connector: Connector, base: str, seq_len: int,
                 records_per_shard: int = 256,
                 credential: Credential | None = None):
        self.connector = connector
        self.base = base
        self.seq_len = seq_len
        self.records_per_shard = records_per_shard
        self.credential = credential
        self._buf: list[np.ndarray] = []
        self._shard_idx = 0

    def add(self, tokens: np.ndarray) -> None:
        assert tokens.shape == (self.seq_len,), tokens.shape
        self._buf.append(tokens.astype(RECORD_DTYPE))
        if len(self._buf) >= self.records_per_shard:
            self.flush()

    def flush(self) -> None:
        if not self._buf:
            return
        blob = np.stack(self._buf).tobytes()
        session = self.connector.start(self.credential)
        try:
            name = f"{self.base}/shard_{self._shard_idx:05d}.bin"
            put_bytes(self.connector, session, name, blob)
        finally:
            self.connector.destroy(session)
        self._shard_idx += 1
        self._buf = []


def synthetic_corpus(connector: Connector, base: str, *, vocab_size: int,
                     seq_len: int, n_records: int, seed: int = 0,
                     records_per_shard: int = 64,
                     credential: Credential | None = None) -> None:
    """Zipf-ish synthetic token corpus for the examples/benchmarks."""
    rng = np.random.default_rng(seed)
    w = TokenShardWriter(connector, base, seq_len, records_per_shard,
                         credential)
    for _ in range(n_records):
        z = rng.zipf(1.3, size=seq_len).astype(np.int64)
        w.add((z % vocab_size).astype(RECORD_DTYPE))
    w.flush()


class ShardedTokenDataset:
    """Deterministic, resumable, prefetching reader."""

    def __init__(self, connector: Connector, base: str,
                 cfg: DataPipelineConfig,
                 credential: Credential | None = None,
                 replica: Connector | None = None):
        self.connector = connector
        self.replica = replica
        self.base = base
        self.cfg = cfg
        self.credential = credential
        session = connector.start(credential)
        try:
            names = sorted(s.name for s in connector.listdir(session, base)
                           if not s.is_dir)
        finally:
            connector.destroy(session)
        # deterministic host partition
        self.shards = [n for i, n in enumerate(names)
                       if i % cfg.n_hosts == cfg.host_id]
        if not self.shards:
            raise ValueError(f"no shards for host {cfg.host_id}")
        self.record_bytes = cfg.seq_len * np.dtype(RECORD_DTYPE).itemsize
        self._state = {"epoch": 0, "shard": 0, "record": 0}
        self._latencies: list[float] = []
        self._hedges = 0

    # ---- resume ----------------------------------------------------------
    def state(self) -> dict:
        return dict(self._state)

    def restore(self, state: dict) -> None:
        self._state = dict(state)

    # ---- reading ---------------------------------------------------------
    def _read_records(self, shard: str, start: int, count: int) -> np.ndarray:
        def fetch(conn):
            session = conn.start(self.credential)
            try:
                data = get_bytes(conn, session, shard,
                                 offset=start * self.record_bytes,
                                 length=count * self.record_bytes)
            finally:
                conn.destroy(session)
            return data

        t0 = time.monotonic()  # lint: disable=R001(hedge trigger needs the real fetch latency — a wedged connector does not advance the model clock)
        use_hedge = (len(self._latencies) >= self.cfg.hedge_min_samples)
        if not use_hedge:
            data = fetch(self.connector)
        else:
            med = sorted(self._latencies)[len(self._latencies) // 2]
            deadline = med * self.cfg.hedge_factor
            result: dict = {}
            done = threading.Event()

            def primary():
                try:
                    r = fetch(self.connector)
                    result.setdefault("data", r)
                    done.set()
                except Exception as e:
                    result.setdefault("err", e)
                    done.set()

            t = threading.Thread(target=primary, daemon=True)
            t.start()
            if not done.wait(timeout=max(deadline, 0.005)):
                # straggler: hedge on the replica (or same connector)
                self._hedges += 1
                alt = self.replica or self.connector
                try:
                    r = fetch(alt)
                    result.setdefault("data", r)
                    done.set()
                except Exception:
                    done.wait()
            else:
                pass
            done.wait()
            if "data" not in result:
                raise result["err"]
            data = result["data"]
        self._latencies.append(time.monotonic() - t0)  # lint: disable=R001(hedge trigger needs the real fetch latency — a wedged connector does not advance the model clock)
        if len(self._latencies) > 256:
            del self._latencies[:128]
        arr = np.frombuffer(data, dtype=RECORD_DTYPE)
        return arr.reshape(count, self.cfg.seq_len)

    def _shard_records(self, shard: str) -> int:
        session = self.connector.start(self.credential)
        try:
            size = self.connector.stat(session, shard).size
        finally:
            self.connector.destroy(session)
        return size // self.record_bytes

    def batches(self):
        """Yields {'tokens': (B, S), 'labels': (B, S)} forever."""
        cfg = self.cfg
        while True:
            shard = self.shards[self._state["shard"]]
            n_rec = self._shard_records(shard)
            at = self._state["record"]
            while at + cfg.batch_size <= n_rec:
                recs = self._read_records(shard, at, cfg.batch_size)
                at += cfg.batch_size
                self._state["record"] = at
                tokens = recs
                labels = np.concatenate(
                    [recs[:, 1:], np.full((cfg.batch_size, 1), -1,
                                          RECORD_DTYPE)], axis=1)
                yield {"tokens": tokens, "labels": labels}
            self._state["record"] = 0
            self._state["shard"] += 1
            if self._state["shard"] >= len(self.shards):
                self._state["shard"] = 0
                self._state["epoch"] += 1

    def prefetching_batches(self):
        """Double-buffered batches via a bounded background queue."""
        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop = threading.Event()

        def producer():
            try:
                for b in self.batches():
                    if stop.is_set():
                        return
                    q.put(b)
            except Exception as e:
                q.put(e)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                item = q.get()
                if isinstance(item, Exception):
                    raise item
                yield item
        finally:
            stop.set()

    @property
    def hedged_reads(self) -> int:
        return self._hedges
