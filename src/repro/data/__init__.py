from .pipeline import (DataPipelineConfig, ShardedTokenDataset,
                       TokenShardWriter, synthetic_corpus)

__all__ = ["DataPipelineConfig", "ShardedTokenDataset", "TokenShardWriter",
           "synthetic_corpus"]
