"""Deterministic failure-scenario harness for the transfer service.

A scenario is ``(source tree, connector route, fault schedule, transfer
options)``.  :class:`ScenarioRunner` materializes the tree at the source,
wraps either end of the route in a
:class:`~repro.connectors.faultproxy.FaultProxyConnector`, runs the
managed :class:`~repro.core.transfer.TransferService`, and verifies the
end-state invariants that make a transfer fabric trustworthy under
chaos:

* the task always *finishes* (never wedges), within a wall-clock bound;
* on success the destination tree is byte-exact, every file result is
  ``ok``, ``bytes_done == bytes_total``, and the restart-marker journal
  is cleared;
* on failure every failed file carries an error, and every file the
  task *did* mark ok is still byte-exact at the destination;
* with an empty schedule no faults are retried (the fabric doesn't
  invent failures).

Determinism: trees are generated from a seeded RNG and schedules make
hash-based decisions (see :mod:`repro.core.faults`), so the same seed
replays the same fault sequence into the same ``TaskStats`` — that is
what makes a chaos failure reproducible enough to debug.
"""

from __future__ import annotations

import os
import random
import threading
from dataclasses import dataclass, field
from typing import Callable

from ..catalog import ReplicaCatalog
from ..connectors import (MemoryConnector, ObjectStoreConnector,
                          PosixConnector, make_cloud)
from ..connectors.faultproxy import FaultProxyConnector
from ..core import (ConnectorError, Credential, CredentialStore, Endpoint,
                    EndpointHealth, HealthConfig, RouteCandidate,
                    TransferManager, TransferOptions, TransferService)
from ..core.clock import Clock, wall_now, wall_sleep
from ..core.faults import FaultSchedule
from ..fed import FederatedCoordinator, TransferSpec

KB = 1024
MB = 1024 * 1024

#: every generated tree lives under this source root and lands under "out"
SRC_ROOT = "data"
DST_ROOT = "out"


# --------------------------------------------------------------------------
# canonical source trees
# --------------------------------------------------------------------------
def _tree_many_small(rng: random.Random):
    files = {f"{SRC_ROOT}/sub{i % 4}/f{i:03d}.bin":
             rng.randbytes(rng.randint(1, 8 * KB)) for i in range(24)}
    return files, []


def _tree_few_large(rng: random.Random):
    files = {f"{SRC_ROOT}/big{i}.bin":
             rng.randbytes(rng.randint(1 * MB, 2 * MB + 4097))
             for i in range(3)}
    return files, []


def _tree_mixed(rng: random.Random):
    sizes = [0, 1, 137, 4 * KB, 64 * KB, 300 * KB, 3 * MB // 2]
    files = {}
    for i in range(14):
        d = rng.choice(["", "a/", "a/b/"])
        files[f"{SRC_ROOT}/{d}m{i:02d}.bin"] = rng.randbytes(rng.choice(sizes))
    return files, [f"{SRC_ROOT}/hollow"]


def _tree_deep(rng: random.Random):
    files = {}
    for i in range(8):
        depth = rng.randint(1, 5)
        d = "/".join(f"lvl{j}" for j in range(depth))
        files[f"{SRC_ROOT}/{d}/deep{i}.bin"] = \
            rng.randbytes(rng.randint(1, 16 * KB))
    return files, [f"{SRC_ROOT}/lvl0/empty", f"{SRC_ROOT}/void"]


def _tree_zero_byte(rng: random.Random):
    files = {f"{SRC_ROOT}/z{i}.bin": b"" for i in range(4)}
    files.update({f"{SRC_ROOT}/s{i}.bin":
                  rng.randbytes(rng.randint(1, 2 * KB)) for i in range(4)})
    return files, []


def _tree_unicode(rng: random.Random):
    names = [f"{SRC_ROOT}/ünïcødé/файл-1.bin",
             f"{SRC_ROOT}/数据/ファイル 2.bin",
             f"{SRC_ROOT}/emoji-✨/naïve 3.bin",
             f"{SRC_ROOT}/ünïcødé/plain.bin"]
    return {n: rng.randbytes(rng.randint(1, 8 * KB)) for n in names}, []


TREES: dict[str, Callable] = {
    "many-small": _tree_many_small,
    "few-large": _tree_few_large,
    "mixed": _tree_mixed,
    "deep": _tree_deep,
    "zero-byte": _tree_zero_byte,
    "unicode": _tree_unicode,
}

#: connector routes; "cloud" is the emulated object store behind the
#: Connector (paper §4) — posix / memory / conn coverage
ROUTES = ("posix->memory", "memory->posix", "posix->cloud",
          "cloud->memory", "cloud->cloud", "posix->posix")


def canonical_tree(kind: str, seed: int = 0):
    """(files, empty_dirs) for one canonical tree, deterministic in
    ``seed``.  ``files`` maps ``data/...`` paths to payload bytes.
    (String seeding is deterministic across processes, unlike hashing a
    tuple under PYTHONHASHSEED randomization.)"""
    return TREES[kind](random.Random(f"{kind}|{seed}"))


# --------------------------------------------------------------------------
# results + invariants
# --------------------------------------------------------------------------
@dataclass
class ScenarioResult:
    task: object
    schedule: FaultSchedule | None
    expected: dict[str, bytes]          # rel path -> bytes
    dest: dict[str, bytes]              # rel path -> bytes (as landed)
    violations: list[str] = field(default_factory=list)
    route: str = ""
    tree: str = ""

    @property
    def ok(self) -> bool:
        return not self.violations

    def fingerprint(self) -> dict:
        """Thread-order-independent digest of the run, for comparing
        same-seed replays (wall time deliberately excluded)."""
        st = self.task.stats
        return {
            "status": self.task.status,
            "files_total": st.files_total,
            "files_done": st.files_done,
            "files_failed": st.files_failed,
            "bytes_total": st.bytes_total,
            "bytes_done": st.bytes_done,
            "faults_retried": st.faults_retried,
            "integrity_failures": st.integrity_failures,
            "batch_fallbacks": st.batch_fallbacks,
            "retries_by_kind": dict(sorted(st.retries_by_kind.items())),
            "events": tuple(self.schedule.sorted_events())
            if self.schedule is not None else (),
        }


def check_invariants(task, expected: dict[str, bytes],
                     dest: dict[str, bytes], schedule: FaultSchedule | None,
                     markers_after: dict, finished: bool,
                     integrity: bool) -> list[str]:
    """End-state invariants every chaos run must satisfy.  Returns a
    list of human-readable violations (empty = all held)."""
    v: list[str] = []
    if not finished:
        v.append("wedged: task did not finish within the timeout")
        return v
    st = task.stats
    if st.files_done + st.files_failed != st.files_total:
        v.append(f"accounting: done {st.files_done} + failed "
                 f"{st.files_failed} != total {st.files_total}")
    if not 0 <= st.bytes_done <= st.bytes_total:
        v.append(f"accounting: bytes_done {st.bytes_done} outside "
                 f"[0, {st.bytes_total}]")
    if schedule is not None and not schedule.rules and st.faults_retried:
        v.append(f"phantom faults: {st.faults_retried} retries with an "
                 f"empty schedule")
    if task.status == task.SUCCEEDED:
        if st.files_failed:
            v.append("succeeded with failed files")
        if st.bytes_done != st.bytes_total:
            v.append(f"succeeded with bytes_done {st.bytes_done} != "
                     f"bytes_total {st.bytes_total}")
        if dest != expected:
            missing = sorted(set(expected) - set(dest))[:3]
            extra = sorted(set(dest) - set(expected))[:3]
            diff = sorted(k for k in set(dest) & set(expected)
                          if dest[k] != expected[k])[:3]
            v.append(f"dest tree not byte-exact (missing={missing} "
                     f"extra={extra} differing={diff})")
        if markers_after != {"files": {}}:
            v.append(f"markers not cleared after success: {markers_after}")
        for fr in task.files:
            if not fr.ok:
                v.append(f"succeeded but file result not ok: {fr.src}")
            elif integrity and fr.checksum is None:
                v.append(f"integrity on but no checksum recorded: {fr.src}")
    else:
        for fr in task.files:
            if not fr.ok and not fr.error:
                v.append(f"failed file without recorded error: {fr.src}")
            if fr.ok:
                rel = fr.dst[len(DST_ROOT) + 1:] if fr.dst.startswith(
                    DST_ROOT + "/") else fr.dst
                if dest.get(rel) != expected.get(rel):
                    v.append(f"file marked ok but not byte-exact: {fr.src}")
    return v


# --------------------------------------------------------------------------
# federation instrumentation
# --------------------------------------------------------------------------
class _MeteredRecvChannel:
    """AppChannel wrapper that reports every byte a connector pulls from
    the application (i.e. bytes about to be written to storage)."""

    def __init__(self, inner, on_read):
        self._inner = inner
        self._on_read = on_read

    def __getattr__(self, item):
        return getattr(self._inner, item)

    def read(self, offset: int, length: int) -> bytes:
        data = self._inner.read(offset, length)
        self._on_read(len(data))
        return data


class _InstrumentedDst:
    """Transparent wrapper around a destination connector that counts
    bytes written to storage per path — the evidence behind the "every
    byte written exactly once, even across a handoff" invariant."""

    def __init__(self, inner):
        self.inner = inner
        self._lock = threading.Lock()
        self.bytes_by_path: dict[str, int] = {}

    def __getattr__(self, item):
        # stat/listdir/send/start/... all forward to the inner connector
        return getattr(self.inner, item)

    def written(self, prefix: str = "") -> int:
        with self._lock:
            return sum(n for p, n in self.bytes_by_path.items()
                       if p.startswith(prefix))

    def _on_read(self, path: str, n: int) -> None:
        with self._lock:
            self.bytes_by_path[path] = self.bytes_by_path.get(path, 0) + n

    def _meter(self, path: str, channel):
        return _MeteredRecvChannel(
            channel, lambda n, p=path: self._on_read(p, n))

    def recv(self, session, path, channel):
        self.inner.recv(session, path, self._meter(path, channel))

    def recv_batch(self, session, paths, channel_factory):
        def factory(path):
            ch = channel_factory(path)
            return None if ch is None else self._meter(path, ch)

        self.inner.recv_batch(session, paths, factory)


class _HeldWriteChannel:
    """Send-side AppChannel wrapper gating each block before it enters
    the pipe."""

    def __init__(self, inner, gate):
        self._inner = inner
        self._gate = gate

    def __getattr__(self, item):
        return getattr(self._inner, item)

    def write(self, offset: int, data: bytes) -> None:
        self._gate(len(data))
        self._inner.write(offset, data)


class _HoldSrc:
    """Wrapper around a *source* connector that, once ``after_bytes``
    cumulative bytes have streamed under the watched prefixes, blocks
    every further send-side block until :meth:`release`.

    This is the deterministic "mid-flight" hook for federation tests:
    blocking on the send side (before the block enters the pipe) means
    the held task still has unclaimed byte ranges when the control
    plane pauses it — the pause lands before release, so the resulting
    checkpoint is guaranteed to carry real partial progress AND real
    holes.  The crossing block itself is let through, so at least
    ``after_bytes`` of durable, marker-checkpointed progress exists to
    travel with a handoff.
    """

    def __init__(self, inner):
        self.inner = inner
        self._lock = threading.Lock()
        self._prefixes: tuple[str, ...] = ()
        self._after = 0
        self._total = 0
        self.engaged = threading.Event()
        self.released = threading.Event()
        self.released.set()

    def __getattr__(self, item):
        return getattr(self.inner, item)

    def arm_hold(self, prefixes, after_bytes: int) -> None:
        self._prefixes = tuple(prefixes)
        self._after = after_bytes
        self._total = 0
        self.engaged.clear()
        self.released.clear()

    def release(self) -> None:
        self.released.set()

    def _gate(self, path: str, n: int) -> None:
        hold = False
        with self._lock:
            if self._after and any(path.startswith(p)
                                   for p in self._prefixes):
                # threshold checked BEFORE adding: the crossing block
                # passes, everything after it blocks
                hold = self._total >= self._after
                self._total += n
        if hold and not self.released.is_set():
            self.engaged.set()
            self.released.wait(timeout=60.0)

    def _held(self, path: str, channel):
        return _HeldWriteChannel(channel,
                                 lambda n, p=path: self._gate(p, n))

    def send(self, session, path, channel):
        self.inner.send(session, path, self._held(path, channel))

    def send_batch(self, session, paths, channel_factory):
        def factory(path):
            ch = channel_factory(path)
            return None if ch is None else self._held(path, ch)

        self.inner.send_batch(session, paths, factory)


class _MeteredSendChannel:
    """Send-side AppChannel wrapper counting every byte a source
    connector pushes into the pipe."""

    def __init__(self, inner, on_write):
        self._inner = inner
        self._on_write = on_write

    def __getattr__(self, item):
        return getattr(self._inner, item)

    def write(self, offset: int, data: bytes) -> None:
        self._on_write(len(data))
        self._inner.write(offset, data)


class _MeteredSrc:
    """Transparent wrapper around a *source* connector that counts
    bytes streamed out per path — the evidence behind the fan-out
    dedupe invariant: N identical submissions must read the source
    ~once, with the other N-1 satisfied by catalog replica reads (which
    stream from the destination connector and so never show up here)."""

    def __init__(self, inner):
        self.inner = inner
        self._lock = threading.Lock()
        self.bytes_by_path: dict[str, int] = {}

    def __getattr__(self, item):
        return getattr(self.inner, item)

    def sent(self, prefix: str = "") -> int:
        with self._lock:
            return sum(n for p, n in self.bytes_by_path.items()
                       if p.startswith(prefix))

    def _on_write(self, path: str, n: int) -> None:
        with self._lock:
            self.bytes_by_path[path] = self.bytes_by_path.get(path, 0) + n

    def _meter(self, path: str, channel):
        return _MeteredSendChannel(
            channel, lambda n, p=path: self._on_write(p, n))

    def send(self, session, path, channel):
        self.inner.send(session, path, self._meter(path, channel))

    def send_batch(self, session, paths, channel_factory):
        def factory(path):
            ch = channel_factory(path)
            return None if ch is None else self._meter(path, ch)

        self.inner.send_batch(session, paths, factory)


class _FlakyDigest:
    """Site-manager proxy whose ``digest()`` raises while ``down`` is
    set — the heartbeat-miss injection for flapping-site scenarios.
    Every other call forwards to the real manager, so the site's data
    plane keeps working while its control channel looks dead (exactly
    the partition the heartbeat monitor must not over-react to)."""

    def __init__(self, inner):
        self.inner = inner
        self.down = threading.Event()

    def __getattr__(self, item):
        return getattr(self.inner, item)

    def digest(self):
        if self.down.is_set():
            raise ConnectorError("site unreachable: digest poll failed")
        return self.inner.digest()


# --------------------------------------------------------------------------
# the runner
# --------------------------------------------------------------------------
class ScenarioRunner:
    """Builds a route, seeds a tree, runs the service under a schedule,
    and checks invariants.  Each ``run`` gets a fresh subdirectory of
    ``base_dir`` (posix roots + restart markers), so runs are isolated
    and a seeded run replays exactly."""

    def __init__(self, base_dir: str, clock: Clock | None = None):
        self.base_dir = base_dir
        self.clock = clock or Clock()
        self._n = 0
        self._lock = threading.Lock()

    # ---- route construction -------------------------------------------
    def _make_end(self, kind: str, run_dir: str, sub: str, provider: str):
        """One side of a route: (connector, seed_fn, read_fn)."""
        if kind == "posix":
            root = os.path.join(run_dir, sub)
            conn = PosixConnector(root)

            def seed(files, empty_dirs):
                for name, payload in files.items():
                    p = os.path.join(root, name)
                    os.makedirs(os.path.dirname(p), exist_ok=True)
                    with open(p, "wb") as f:
                        f.write(payload)
                for d in empty_dirs:
                    os.makedirs(os.path.join(root, d), exist_ok=True)

            def read():
                out = {}
                base = os.path.join(root, DST_ROOT)
                for dirpath, _, filenames in os.walk(base):
                    for fn in filenames:
                        p = os.path.join(dirpath, fn)
                        rel = os.path.relpath(p, base).replace(os.sep, "/")
                        with open(p, "rb") as f:
                            out[rel] = f.read()
                return out

            return conn, seed, read

        if kind == "memory":
            conn = MemoryConnector(clock=self.clock)

            def seed(files, empty_dirs):
                for name, payload in files.items():
                    conn.store.put(name, payload)

            def read():
                pfx = DST_ROOT + "/"
                return {k[len(pfx):]: conn.store.get(k)
                        for k in conn.store.keys() if k.startswith(pfx)}

            return conn, seed, read

        if kind == "cloud":
            storage = make_cloud(provider, clock=self.clock)
            placement = "cloud" if provider == "gcs" else "local"
            conn = ObjectStoreConnector(storage, placement=placement,
                                        clock=self.clock)

            def seed(files, empty_dirs):
                for name, payload in files.items():
                    storage.blobs.put(name, payload)

            def read():
                pfx = DST_ROOT + "/"
                return {k[len(pfx):]: storage.blobs.get(k)
                        for k in storage.blobs.keys() if k.startswith(pfx)}

            return conn, seed, read

        raise ValueError(f"unknown route end {kind!r}")

    # ---- one scenario ---------------------------------------------------
    def run(self, tree="mixed", route: str = "posix->memory",
            schedule: FaultSchedule | None = None,
            options: TransferOptions | None = None, proxy: str = "dst",
            seed: int = 0, timeout: float = 120.0,
            strict: bool = False) -> ScenarioResult:
        """Run one scenario.  ``tree`` is a canonical-tree name or a
        literal ``{data/...: bytes}`` mapping; ``proxy`` picks which
        route end(s) get the fault proxy: "src" | "dst" | "both" |
        "none".  ``strict=True`` raises AssertionError on any invariant
        violation."""
        with self._lock:
            self._n += 1
            run_dir = os.path.join(self.base_dir, f"run{self._n:03d}")
        os.makedirs(run_dir, exist_ok=True)

        if isinstance(tree, str):
            files, empty_dirs = canonical_tree(tree, seed)
        else:
            files, empty_dirs, tree = dict(tree), [], "<literal>"
        src_kind, dst_kind = route.split("->")
        src_conn, seed_src, _ = self._make_end(src_kind, run_dir, "srcfs",
                                               provider="s3")
        dst_conn, _, read_dst = self._make_end(
            dst_kind, run_dir, "dstfs",
            provider="gcs" if src_kind == "cloud" else "s3")
        seed_src(files, empty_dirs)

        if schedule is not None and schedule.clock is None:
            schedule.clock = self.clock
        if schedule is not None and proxy in ("src", "both"):
            src_conn = FaultProxyConnector(src_conn, schedule)
        if schedule is not None and proxy in ("dst", "both"):
            dst_conn = FaultProxyConnector(dst_conn, schedule)

        creds = CredentialStore()
        for ep_id, conn in (("src-ep", src_conn), ("dst-ep", dst_conn)):
            creds.register(ep_id, Credential(
                conn.credential_scheme or "local-user", {"token": "t"}))
        service = TransferService(
            credential_store=creds,
            marker_root=os.path.join(run_dir, "markers"), clock=self.clock)

        options = options or TransferOptions(
            startup_cost=0.0, retry_backoff=0.01, concurrency=2)
        task = service.submit(Endpoint(src_conn, SRC_ROOT, "src-ep"),
                              Endpoint(dst_conn, DST_ROOT, "dst-ep"),
                              options, task_id=f"chaos-{self._n:03d}")
        finished = task.wait(timeout=timeout)

        expected = {name[len(SRC_ROOT) + 1:]: payload
                    for name, payload in files.items()}
        dest = read_dst() if finished else {}
        markers_after = service.markers.load(task.task_id) if finished \
            else {"files": {"unfinished": True}}
        violations = check_invariants(task, expected, dest, schedule,
                                      markers_after, finished,
                                      options.integrity)
        result = ScenarioResult(task=task, schedule=schedule,
                                expected=expected, dest=dest,
                                violations=violations, route=route, tree=tree)
        if strict and violations:
            raise AssertionError(
                f"scenario {tree} over {route} violated invariants:\n  "
                + "\n  ".join(violations)
                + f"\n  last events: {task.events[-5:]}")
        return result

    # ---- a fleet of tasks under one manager ------------------------------
    def run_multi(self, n_tasks: int = 4, tenants=("alice", "bob"),
                  trees=("mixed", "many-small"),
                  route: str = "posix->memory",
                  schedule: FaultSchedule | None = None,
                  options: TransferOptions | None = None,
                  proxy: str = "dst", max_workers: int = 4,
                  per_endpoint_cap: int | None = 2,
                  pause_resume=(), seed: int = 0,
                  timeout: float = 240.0,
                  advisor=None, refit_every: int = 4,
                  strict: bool = False) -> "MultiScenarioResult":
        """Run ``n_tasks`` concurrent transfers through ONE
        :class:`TransferManager` sharing one route's endpoints.

        Task ``i`` belongs to ``tenants[i % len(tenants)]``, moves
        canonical tree ``trees[i % len(trees)]`` seeded from
        ``seed + i`` under ``data/t{i}``, and lands under ``out/t{i}``
        — so per-endpoint caps, tenant fairness, and session sharing
        are all exercised on live shared state.  ``pause_resume`` names
        task indexes to pause (best-effort mid-flight; deterministic
        while queued) and then resume before the final wait.  Per-task
        end-state invariants are checked exactly as in :meth:`run`,
        plus manager-level ones: worker budget and per-endpoint caps
        never exceeded, and the whole fleet finishes.

        With ``advisor`` given, every submission is routed through its
        first route (per-task workload hints from the generated trees)
        and the manager's online refit loop runs every ``refit_every``
        completions.  One more invariant then applies: once at least one
        refit fired, the median prediction error of post-refit tasks
        must be *smaller* than the seed model's — charge-accounted
        observations under multi-tenant chaos traffic must converge the
        model, not corrupt it."""
        with self._lock:
            self._n += 1
            run_dir = os.path.join(self.base_dir, f"multi{self._n:03d}")
        os.makedirs(run_dir, exist_ok=True)

        src_kind, dst_kind = route.split("->")
        src_conn, seed_src, _ = self._make_end(src_kind, run_dir, "srcfs",
                                               provider="s3")
        dst_conn, _, read_dst = self._make_end(
            dst_kind, run_dir, "dstfs",
            provider="gcs" if src_kind == "cloud" else "s3")

        per_task_files: list[dict[str, bytes]] = []
        all_files: dict[str, bytes] = {}
        all_empty: list[str] = []
        for i in range(n_tasks):
            files, empty_dirs = canonical_tree(trees[i % len(trees)],
                                               seed + i)
            remapped = {f"{SRC_ROOT}/t{i}/" + name[len(SRC_ROOT) + 1:]: data
                        for name, data in files.items()}
            per_task_files.append(remapped)
            all_files.update(remapped)
            all_empty.extend(f"{SRC_ROOT}/t{i}/" + d[len(SRC_ROOT) + 1:]
                             for d in empty_dirs)
        seed_src(all_files, all_empty)

        if schedule is not None and schedule.clock is None:
            schedule.clock = self.clock
        if schedule is not None and proxy in ("src", "both"):
            src_conn = FaultProxyConnector(src_conn, schedule)
        if schedule is not None and proxy in ("dst", "both"):
            dst_conn = FaultProxyConnector(dst_conn, schedule)

        creds = CredentialStore()
        for tenant in tenants:
            creds.register(f"src-{tenant}", Credential(
                src_conn.credential_scheme or "local-user",
                {"identity": tenant}))
            creds.register(f"dst-{tenant}", Credential(
                dst_conn.credential_scheme or "local-user",
                {"identity": tenant}))
        manager = TransferManager(
            max_workers=max_workers, per_endpoint_cap=per_endpoint_cap,
            credential_store=creds, advisor=advisor,
            refit_every=refit_every,
            marker_root=os.path.join(run_dir, "markers"), clock=self.clock)

        options = options or TransferOptions(
            startup_cost=0.0, retry_backoff=0.01, concurrency=2)
        tasks = []
        for i in range(n_tasks):
            tenant = tenants[i % len(tenants)]
            src_ep = Endpoint(src_conn, f"{SRC_ROOT}/t{i}", f"src-{tenant}")
            dst_ep = Endpoint(dst_conn, f"{DST_ROOT}/t{i}", f"dst-{tenant}")
            if advisor is not None:
                tasks.append(manager.submit(
                    candidates=[RouteCandidate(advisor.routes[0].name,
                                               src_ep, dst_ep)],
                    options=options, task_id=f"multi-{self._n:03d}-t{i}",
                    n_files=len(per_task_files[i]),
                    nbytes=sum(len(d) for d in per_task_files[i].values())))
            else:
                tasks.append(manager.submit(
                    src_ep, dst_ep, options,
                    task_id=f"multi-{self._n:03d}-t{i}"))

        for i in pause_resume:
            manager.pause(tasks[i].task_id)
        for i in pause_resume:
            tasks[i].wait_idle(timeout)
        for i in pause_resume:
            manager.resume(tasks[i].task_id)

        finished = manager.wait_all(timeout=timeout)
        dest_all = read_dst() if finished else {}

        results: list[ScenarioResult] = []
        violations: list[str] = []
        for i, task in enumerate(tasks):
            # keys keep the t{i}/ prefix: check_invariants resolves a
            # FileResult.dst relative to DST_ROOT, so per-task keys must
            # be "t{i}/rel" or the ok-but-not-byte-exact check could
            # never find (and thus never fail) a file
            pfx = f"t{i}/"
            expected = {name[len(SRC_ROOT) + 1:]: data
                        for name, data in per_task_files[i].items()}
            dest = {k: v for k, v in dest_all.items() if k.startswith(pfx)}
            markers_after = manager.service.markers.load(task.task_id) \
                if finished else {"files": {"unfinished": True}}
            task_done = finished and task._done.is_set()
            v = check_invariants(task, expected, dest, schedule,
                                 markers_after, task_done, options.integrity)
            results.append(ScenarioResult(
                task=task, schedule=schedule, expected=expected, dest=dest,
                violations=v, route=route, tree=trees[i % len(trees)]))
            violations.extend(f"task {i}: {x}" for x in v)

        m = manager.metrics
        if m.peak_active > max_workers:
            violations.append(f"worker budget exceeded: peak_active "
                              f"{m.peak_active} > {max_workers}")
        if per_endpoint_cap is not None:
            for ep_id, peak in m.peak_by_endpoint.items():
                if peak > per_endpoint_cap:
                    violations.append(f"endpoint cap exceeded on {ep_id}: "
                                      f"{peak} > {per_endpoint_cap}")
        if advisor is not None and m.refits:
            # refit convergence: once the online loop has fired, tasks
            # predicted by a refit model must beat the seed model
            pre = manager.prediction_error(generation=0)
            post = manager.prediction_error(min_generation=1)
            if pre is not None and post is not None and post >= pre:
                violations.append(
                    f"refit did not converge: median prediction error "
                    f"{post:.3f} after refit >= {pre:.3f} before")
        manager.shutdown(wait=False)
        result = MultiScenarioResult(results=results, manager=manager,
                                     violations=violations)
        if strict and violations:
            raise AssertionError(
                f"multi-task scenario over {route} violated invariants:\n  "
                + "\n  ".join(violations))
        return result

    # ---- a federation of sites with a mid-flight site failure ------------
    def run_federated(self, n_sites: int = 2, n_tasks: int = 4,
                      tenants=("alice", "bob"),
                      trees=("few-large", "many-small", "mixed"),
                      placement: str = "owner",
                      schedule: FaultSchedule | None = None,
                      options: TransferOptions | None = None,
                      fail_site: bool = True, victim: int = 1,
                      max_workers: int = 3, hold_after: int = 4096,
                      seed: int = 0, timeout: float = 240.0,
                      strict: bool = False) -> "FederatedScenarioResult":
        """Run ``n_tasks`` transfers through a
        :class:`~repro.fed.FederatedCoordinator` over ``n_sites`` site
        control planes, then kill one site mid-flight and assert the
        federation contract end-to-end.

        Topology: site ``i`` owns source endpoint ``src-s{i}`` (its own
        seeded connector); a single destination endpoint ``dst-ep`` —
        owned by site 0, reachable by all — collects every task's tree
        under ``out/t{j}``.  Task ``j`` sources from site
        ``j % n_sites``, so the owner placement policy must scatter the
        fleet across sites.  Every submission goes through the
        ``TransferSpec`` JSON wire form (serializability is part of
        what's under test).  A byte-threshold hold on the victim site's
        destination paths guarantees at least one of its tasks is
        genuinely mid-flight when :meth:`FederatedCoordinator.fail_site`
        fires; the fault ``schedule`` (if any) proxies the *source*
        side only, so the destination write-once invariant stays exact.

        Invariants, on top of the per-task :func:`check_invariants`:

        * every submission was initially placed at its source's owner;
        * the failed site hands off at least one task with traveled
          partial progress, and every handed-off task completes on its
          new site with the originating tenant (and origin site) still
          attributed — including charge-accounted model seconds;
        * with integrity off, every byte lands exactly once fleet-wide
          (``written == bytes_total`` per task): a handoff re-sends
          only the holes;
        * the coordinator never accrues model time (third-party
          semantics via the charge clock);
        * per-site worker budgets hold.
        """
        with self._lock:
            self._n += 1
            run_dir = os.path.join(self.base_dir, f"fed{self._n:03d}")
        os.makedirs(run_dir, exist_ok=True)
        n_sites = max(2, n_sites) if fail_site else max(1, n_sites)
        victim_site = f"s{victim % n_sites}"

        # one seeded source connector per site; one shared destination
        src_inners = [MemoryConnector(clock=self.clock) for _ in range(n_sites)]
        per_task_files: list[dict[str, bytes]] = []
        specs: list[TransferSpec] = []
        for j in range(n_tasks):
            files, _empty = canonical_tree(trees[j % len(trees)], seed + j)
            remapped = {f"{SRC_ROOT}/t{j}/" + name[len(SRC_ROOT) + 1:]: data
                        for name, data in files.items()}
            per_task_files.append(remapped)
            store = src_inners[j % n_sites].store
            for name, data in remapped.items():
                store.put(name, data)

        if schedule is not None and schedule.clock is None:
            schedule.clock = self.clock
        src_conns = [FaultProxyConnector(c, schedule)
                     if schedule is not None else c for c in src_inners]
        hold = None
        if fail_site:
            # gate the victim's SOURCE streams: once the threshold
            # crosses, its tasks stop making progress until the kill
            # has landed its pause requests — so the checkpoint that
            # travels is guaranteed mid-flight (progress AND holes)
            hold = _HoldSrc(src_conns[victim % n_sites])
            src_conns[victim % n_sites] = hold
            hold.arm_hold([SRC_ROOT + "/"], hold_after)
        dst_inner = MemoryConnector(clock=self.clock)
        dst_conn = _InstrumentedDst(dst_inner)

        endpoints = {f"src-s{i}": src_conns[i] for i in range(n_sites)}
        endpoints["dst-ep"] = dst_conn
        coord = FederatedCoordinator(placement=placement)
        for i in range(n_sites):
            creds = CredentialStore()
            for tenant in tenants:
                creds.register(f"src-s{i}", Credential(
                    "local-user", {"identity": tenant}))
            owns = {f"src-s{i}"} | ({"dst-ep"} if i == 0 else set())
            manager = TransferManager(
                max_workers=max_workers, per_endpoint_cap=None,
                credential_store=creds,
                marker_root=os.path.join(run_dir, f"site{i}", "markers"),
                clock=self.clock, site_id=f"s{i}")
            coord.register_site(f"s{i}", manager, endpoints, owns=owns)

        options = options or TransferOptions(
            startup_cost=0.0, retry_backoff=0.01, concurrency=2)
        victim_ids: list[str] = []
        for j in range(n_tasks):
            spec = TransferSpec.new(
                f"fed-{self._n:03d}-t{j}",
                f"src-s{j % n_sites}", f"{SRC_ROOT}/t{j}",
                "dst-ep", f"{DST_ROOT}/t{j}",
                tenant=tenants[j % len(tenants)], options=options,
                n_files=len(per_task_files[j]),
                nbytes=sum(len(d) for d in per_task_files[j].values()))
            specs.append(spec)
            if j % n_sites == victim % n_sites:
                victim_ids.append(spec.task_id)
        # the wire form IS the submission: serializability under test
        for spec in specs:
            coord.submit(spec.to_json())

        violations: list[str] = []
        moved: list[tuple[str, str]] = []
        if fail_site:
            if not hold.engaged.wait(timeout=min(60.0, timeout)):
                violations.append("hold never engaged: the victim site "
                                  "had no mid-flight task to kill")
                hold.release()
            else:
                victim_tasks = [coord.task(tid) for tid in victim_ids]
                # the crossing block _HoldSrc let through is still in
                # flight on the receive side, and a pause stops the
                # receiver at block granularity — killing the site
                # before that block lands durable would checkpoint zero
                # progress.  Wait for its write (fast: the dst is not
                # gated) before pulling the plug.  Harness kill window:
                # real threads may wedge, so the bound is wall time via
                # the sanctioned clock helpers.
                t_end = wall_now() + min(60.0, timeout)
                while wall_now() < t_end:
                    if any(t.stats.bytes_done > 0 for t in victim_tasks):
                        break
                    wall_sleep(0.002)
                fail_err: list[Exception] = []

                def do_fail():
                    try:
                        moved.extend(coord.fail_site(victim_site,
                                                     timeout=timeout))
                    except Exception as e:  # surfaced as a violation
                        fail_err.append(e)

                failer = threading.Thread(target=do_fail, daemon=True)
                failer.start()
                # release the held stream only once every victim task has
                # its pause landed (or finished): the site's checkpoint
                # is guaranteed to happen while the task was mid-flight
                t_end = wall_now() + min(60.0, timeout)
                while wall_now() < t_end:
                    if all(t._done.is_set() or t._pause_req.is_set()
                           or t.status == t.PAUSED for t in victim_tasks):
                        break
                    wall_sleep(0.005)
                hold.release()
                failer.join(timeout)
                if failer.is_alive():
                    violations.append("fail_site wedged: failover did "
                                      "not complete within the timeout")
                for e in fail_err:
                    violations.append(f"fail_site raised: "
                                      f"{type(e).__name__}: {e}")

        finished = coord.wait_all(timeout=timeout)
        dest_all = {}
        if finished:
            pfx = DST_ROOT + "/"
            dest_all = {k[len(pfx):]: dst_inner.store.get(k)
                        for k in dst_inner.store.keys()
                        if k.startswith(pfx)}

        results: list[ScenarioResult] = []
        for j, spec in enumerate(specs):
            task = coord.task(spec.task_id)
            site_id = coord.site_of(spec.task_id)
            mgr = coord.sites()[site_id].manager
            pfx = f"t{j}/"
            expected = {name[len(SRC_ROOT) + 1:]: data
                        for name, data in per_task_files[j].items()}
            dest = {k: v for k, v in dest_all.items() if k.startswith(pfx)}
            task_done = finished and task._done.is_set()
            markers_after = mgr.service.markers.load(spec.task_id) \
                if task_done else {"files": {"unfinished": True}}
            v = check_invariants(task, expected, dest, schedule,
                                 markers_after, task_done,
                                 options.integrity)
            results.append(ScenarioResult(
                task=task, schedule=schedule, expected=expected, dest=dest,
                violations=v, route=f"fed:{site_id}",
                tree=trees[j % len(trees)]))
            violations.extend(f"task {j}: {x}" for x in v)

        # federation-level invariants --------------------------------------
        if placement == "owner":
            first_place = {}
            for tid, sid, reason in coord.metrics.placement_log:
                if reason == "submit" and tid not in first_place:
                    first_place[tid] = sid
            for j, spec in enumerate(specs):
                owner = f"s{j % n_sites}"
                if first_place.get(spec.task_id) != owner:
                    violations.append(
                        f"task {j}: placed at "
                        f"{first_place.get(spec.task_id)!r}, but "
                        f"{owner!r} owns its source endpoint")
        if fail_site and hold.engaged.is_set():
            if not moved:
                violations.append("site failure moved no tasks (all "
                                  "finished before the kill?)")
            if not any(coord.last_spec(tid) is not None
                       and coord.last_spec(tid).done_bytes() > 0
                       for tid, _ in moved):
                violations.append("no handed-off task carried partial "
                                  "progress (hole map did not travel)")
            for tid, new_site in moved:
                task = coord.task(tid)
                if task.status != task.SUCCEEDED:
                    violations.append(f"handed-off {tid} ended "
                                      f"{task.status} on {new_site}")
                if task.stats.site != new_site:
                    violations.append(f"{tid}: stats.site "
                                      f"{task.stats.site!r} != adopting "
                                      f"site {new_site!r}")
                if task.stats.origin_site != victim_site:
                    violations.append(f"{tid}: origin_site "
                                      f"{task.stats.origin_site!r} lost "
                                      f"across the handoff")
        for j, spec in enumerate(specs):
            task = coord.task(spec.task_id)
            want = tenants[j % len(tenants)]
            if task.stats.tenant != want:
                violations.append(f"task {j}: tenant attribution "
                                  f"{task.stats.tenant!r} != {want!r}")
            if task.status == task.SUCCEEDED \
                    and task.stats.bytes_total > 0 \
                    and task.stats.actual_model_seconds <= 0:
                violations.append(f"task {j}: no model seconds charged "
                                  f"to it (attribution broken)")
            if not options.integrity and finished:
                written = dst_conn.written(f"{DST_ROOT}/t{j}/")
                if task.status == task.SUCCEEDED \
                        and written != task.stats.bytes_total:
                    violations.append(
                        f"task {j}: {written} bytes written at dst for "
                        f"{task.stats.bytes_total} byte tree — a handoff "
                        f"must re-send only the holes")
        try:
            coord.assert_third_party()
        except AssertionError as e:
            violations.append(str(e))
        for site_id, handle in coord.sites().items():
            peak = handle.manager.metrics.peak_active
            if peak > max_workers:
                violations.append(f"site {site_id}: worker budget "
                                  f"exceeded ({peak} > {max_workers})")
        if not finished:
            violations.append("wedged: the federation did not finish "
                              "within the timeout")

        coord.shutdown(wait=False)
        result = FederatedScenarioResult(
            results=results, coordinator=coord, moved=moved,
            violations=violations)
        if strict and violations:
            raise AssertionError(
                "federated scenario violated invariants:\n  "
                + "\n  ".join(violations))
        return result

    # ---- fan-out dedupe through the replica catalog ----------------------
    def run_fanout(self, n_fanout: int = 4, tree="many-small",
                   chaos: str = "none",
                   options: TransferOptions | None = None,
                   byte_budget: int | None = None, max_workers: int = 4,
                   seed: int = 0, timeout: float = 240.0,
                   strict: bool = False) -> "FanoutScenarioResult":
        """Submit the SAME source tree ``n_fanout`` times (distinct
        destination prefixes) through one manager sharing a
        :class:`~repro.catalog.ReplicaCatalog`, and assert the dedupe
        contract: the first task moves the tree, the other N-1 are
        satisfied by verified replica reads at the destination — bytes
        leaving the *source* stay ~1x the tree, and write-once
        destination accounting still holds.

        ``chaos`` injects a catalog betrayal between the first transfer
        and the fan-out, and the invariant flips to "fall back to a
        real transfer, never serve wrong bytes":

        * ``"evict"`` — every entry is evicted before the fan-out: all
          lookups must miss and every file is source-read again;
        * ``"stale"`` — every source file is rewritten (mtime forced
          forward): traveled signatures mismatch, entries are
          invalidated, and the fan-out lands the NEW bytes;
        * ``"corrupt"`` — the landed replica bytes are flipped in
          place: the replica read's checksum fold must catch it,
          invalidate the entry, and fall back.

        Integrity must stay on (the default here): the catalog only
        trusts §7-folded content keys.
        """
        if chaos not in ("none", "evict", "stale", "corrupt"):
            raise ValueError(f"unknown fanout chaos {chaos!r}")
        with self._lock:
            self._n += 1
            run_dir = os.path.join(self.base_dir, f"fanout{self._n:03d}")
        os.makedirs(run_dir, exist_ok=True)

        if isinstance(tree, str):
            files, empty_dirs = canonical_tree(tree, seed)
        else:
            files, empty_dirs, tree = dict(tree), [], "<literal>"
        # posix source: stat signatures (size, mtime) are live, so the
        # stale mutation below is visible to the catalog's freshness
        # check.  memory destination: replica bytes are reachable for
        # the corrupt mutation.
        src_root = os.path.join(run_dir, "srcfs")
        for name, payload in files.items():
            p = os.path.join(src_root, name)
            os.makedirs(os.path.dirname(p), exist_ok=True)
            with open(p, "wb") as f:
                f.write(payload)
        for d in empty_dirs:
            os.makedirs(os.path.join(src_root, d), exist_ok=True)
        src_conn = _MeteredSrc(PosixConnector(src_root))
        dst_inner = MemoryConnector(clock=self.clock)
        dst_conn = _InstrumentedDst(dst_inner)

        creds = CredentialStore()
        for ep_id, conn in (("src-ep", src_conn), ("dst-ep", dst_conn)):
            creds.register(ep_id, Credential(
                conn.credential_scheme or "local-user", {"token": "t"}))
        catalog = ReplicaCatalog(byte_budget=byte_budget)
        manager = TransferManager(
            max_workers=max_workers, per_endpoint_cap=None,
            credential_store=creds, catalog=catalog,
            marker_root=os.path.join(run_dir, "markers"), clock=self.clock)
        options = options or TransferOptions(
            integrity=True, startup_cost=0.0, retry_backoff=0.01,
            concurrency=2)

        def submit(k: int):
            return manager.submit(
                Endpoint(src_conn, SRC_ROOT, "src-ep"),
                Endpoint(dst_conn, f"{DST_ROOT}/t{k}", "dst-ep"),
                options, task_id=f"fanout-{self._n:03d}-t{k}")

        def read_dst(k: int) -> dict[str, bytes]:
            pfx = f"{DST_ROOT}/t{k}/"
            return {key[len(pfx):]: dst_inner.store.get(key)
                    for key in dst_inner.store.keys()
                    if key.startswith(pfx)}

        expected = {name[len(SRC_ROOT) + 1:]: payload
                    for name, payload in files.items()}
        results: list[ScenarioResult] = []
        violations: list[str] = []

        # --- the one real transfer, checked BEFORE any chaos mutates
        # the source or its landed bytes
        tasks = [submit(0)]
        finished0 = tasks[0].wait(timeout=timeout)
        dest0 = read_dst(0) if finished0 else {}
        markers0 = manager.service.markers.load(tasks[0].task_id) \
            if finished0 else {"files": {"unfinished": True}}
        v0 = check_invariants(tasks[0], expected, dest0, None, markers0,
                              finished0, options.integrity)
        results.append(ScenarioResult(
            task=tasks[0], schedule=None, expected=expected, dest=dest0,
            violations=v0, route="posix->memory", tree=tree))
        violations.extend(f"task 0: {x}" for x in v0)

        # zero-byte files are never cataloged (no content to replicate)
        n_cat = sum(1 for payload in files.values() if payload)

        # --- chaos injection between first transfer and fan-out
        if chaos == "evict":
            for e in catalog.entries():
                catalog.invalidate(e, reason="evicted")
            if catalog.entries():
                violations.append("evict chaos left catalog entries behind")
        elif chaos == "stale":
            for name, payload in list(files.items()):
                p = os.path.join(src_root, name)
                mutated = bytes(b ^ 0xFF for b in payload)
                with open(p, "wb") as f:
                    f.write(mutated)
                st = os.stat(p)
                os.utime(p, (st.st_atime + 100, st.st_mtime + 100))
                files[name] = mutated
            # the fan-out must land the NEW bytes, never the cataloged old
            expected = {name[len(SRC_ROOT) + 1:]: payload
                        for name, payload in files.items()}
        elif chaos == "corrupt":
            pfx = f"{DST_ROOT}/t0/"
            for key in list(dst_inner.store.keys()):
                if key.startswith(pfx):
                    data = dst_inner.store.get(key)
                    if data:
                        dst_inner.store.put(
                            key, bytes([data[0] ^ 0xFF]) + data[1:])

        # --- the fan-out
        for k in range(1, n_fanout):
            tasks.append(submit(k))
        finished = manager.wait_all(timeout=timeout)
        for k, task in enumerate(tasks[1:], start=1):
            dest = read_dst(k) if finished else {}
            markers_after = manager.service.markers.load(task.task_id) \
                if finished else {"files": {"unfinished": True}}
            task_done = finished and task._done.is_set()
            v = check_invariants(task, expected, dest, None, markers_after,
                                 task_done, options.integrity)
            results.append(ScenarioResult(
                task=task, schedule=None, expected=expected, dest=dest,
                violations=v, route="posix->memory", tree=tree))
            violations.extend(f"task {k}: {x}" for x in v)

        source_bytes = src_conn.sent(SRC_ROOT)
        tree_bytes = sum(len(payload) for payload in files.values())
        fan = tasks[1:]
        hits = sum(t.stats.replica_hits for t in fan)
        fallbacks = sum(t.stats.replica_fallbacks for t in fan)
        if finished:
            if chaos == "none":
                if source_bytes > int(1.05 * tree_bytes):
                    violations.append(
                        f"fan-out of {n_fanout} moved {source_bytes} source "
                        f"bytes for a {tree_bytes} byte tree — dedupe must "
                        f"collapse N submissions to ~1 real transfer")
                want = (n_fanout - 1) * n_cat
                if hits != want:
                    violations.append(f"expected {want} replica hits "
                                      f"across the fan-out, saw {hits}")
                for k, task in enumerate(tasks):
                    written = dst_conn.written(f"{DST_ROOT}/t{k}/")
                    if written != task.stats.bytes_total:
                        violations.append(
                            f"task {k}: {written} bytes written for a "
                            f"{task.stats.bytes_total} byte tree — a "
                            f"replica read must write each byte once")
            elif chaos == "evict" and source_bytes < 2 * tree_bytes:
                violations.append(
                    f"catalog was emptied but the source streamed only "
                    f"{source_bytes} of >= {2 * tree_bytes} bytes — "
                    f"evicted entries must fall back to real transfers")
            elif chaos == "stale":
                if catalog.stale_invalidations < n_cat:
                    violations.append(
                        f"only {catalog.stale_invalidations} of {n_cat} "
                        f"stale entries were invalidated")
                if source_bytes < 2 * tree_bytes:
                    violations.append(
                        f"source streamed {source_bytes} < "
                        f"{2 * tree_bytes} bytes after mutation — stale "
                        f"replicas must never be served")
            elif chaos == "corrupt":
                if catalog.corrupt_invalidations < n_cat:
                    violations.append(
                        f"only {catalog.corrupt_invalidations} of {n_cat} "
                        f"corrupted entries were invalidated")
                if fallbacks < n_cat:
                    violations.append(
                        f"only {fallbacks} replica fallbacks for {n_cat} "
                        f"corrupted replicas — the fold must catch every "
                        f"corrupt read and fall back")
        manager.shutdown(wait=False)
        result = FanoutScenarioResult(
            chaos=chaos, results=results, manager=manager, catalog=catalog,
            source_bytes=source_bytes, tree_bytes=tree_bytes,
            replica_hits=hits, replica_fallbacks=fallbacks,
            violations=violations)
        if strict and violations:
            raise AssertionError(
                f"fan-out scenario (chaos={chaos}) violated invariants:"
                "\n  " + "\n  ".join(violations))
        return result

    # ---- degraded-mode scenarios (health plane) --------------------------
    def run_degraded(self, mode: str = "brownout",
                     n_tasks: int | None = None,
                     health: HealthConfig | None = None, storm: int = 6,
                     miss_threshold: int = 3, victim: int = 1,
                     seed: int = 0, timeout: float = 240.0,
                     strict: bool = False) -> "DegradedScenarioResult":
        """Run the fleet against *degrading* (not just failing) storage
        and assert the health plane's contract.  Three modes:

        * ``"brownout"`` — the destination endpoint fails every recv for
          a bounded global storm, then recovers.  The breaker must open
          on the error burst, hold the fleet off with fast-fail
          :class:`~repro.core.EndpointUnavailable` denials, probe
          half-open, re-open while the storm lasts, close on the first
          probe that succeeds — and every task must still finish
          byte-exact.  Both ``"EndpointUnavailable"`` and
          ``"HalfOpenProbe"`` must appear in the fleet's
          ``retries_by_kind`` (the taxonomy is observable).
        * ``"death"`` — the destination endpoint is permanently dead.
          A 20-task fleet through one :class:`TransferManager` must
          finish (FAILED, never wedged) with total storage attempts
          bounded by the shared retry budget — O(budget), not
          O(n_tasks * max_retries): no retry storm.
        * ``"flapping-site"`` — a federation site's digest channel flaps
          below ``miss_threshold`` consecutive misses (no failover may
          fire), then goes permanently dark: the heartbeat monitor in
          :meth:`~repro.fed.FederatedCoordinator.beat` must auto-invoke
          the failover path (the caller never calls ``fail_site``),
          re-homed tasks must finish byte-exact with write-once
          destination bytes, and the coordinator must stay zero-charge.
        """
        with self._lock:
            self._n += 1
            run_dir = os.path.join(self.base_dir, f"deg{self._n:03d}")
        os.makedirs(run_dir, exist_ok=True)

        if mode == "brownout":
            return self._degraded_endpoint(
                run_dir, mode, n_tasks or 4, health, storm, seed, timeout,
                strict)
        if mode == "death":
            return self._degraded_endpoint(
                run_dir, mode, n_tasks or 20, health, storm, seed, timeout,
                strict)
        if mode == "flapping-site":
            return self._degraded_federation(
                run_dir, n_tasks or 4, miss_threshold, victim, seed,
                timeout, strict)
        raise ValueError(f"unknown degraded mode {mode!r}")

    def _degraded_endpoint(self, run_dir: str, mode: str, n: int,
                           health: HealthConfig | None, storm: int,
                           seed: int, timeout: float,
                           strict: bool) -> "DegradedScenarioResult":
        """Brownout / permanent-death of the destination endpoint."""
        if mode == "brownout":
            cfg = health or HealthConfig(
                error_threshold=0.5, ewma_alpha=0.6, min_samples=2,
                cooldown=0.15, probe_successes=1,
                retry_budget_rate=2.0, retry_budget_capacity=12.0)
            schedule = FaultSchedule(seed=seed).brownout(storm, op="recv*")
            #: real (admitted) attempts only — fast-fail denials are
            #: bounded by ``unavailable_patience`` on the model clock,
            #: not by this count
            max_retries = 12
            files_per_task = 2
            #: unbounded: a brownout ends by construction (the storm is
            #: a finite ``times=storm``), and this scenario's invariant
            #: is that NO task gives up — give-up behavior is the death
            #: mode's test.  At time-scale 0 the waiter crowd's denial
            #: sleeps advance the shared model clock arbitrarily fast
            #: relative to thread scheduling, so any finite patience
            #: here would be a scheduling race.
            patience = float("inf")
        else:
            cfg = health or HealthConfig(
                error_threshold=0.5, ewma_alpha=0.4, min_samples=3,
                cooldown=0.05, probe_successes=1,
                retry_budget_rate=0.0, retry_budget_capacity=4.0)
            schedule = FaultSchedule(seed=seed).dead_endpoint(op="recv*")
            max_retries = 6
            files_per_task = 1
            #: a dead endpoint never recovers: give up on fast-fail
            #: denials after a short model-clock wait so the fleet
            #: drains FAILED instead of waiting out a long patience
            patience = 2.0
        schedule.clock = self.clock

        src_inner = MemoryConnector(clock=self.clock)
        per_task_files: list[dict[str, bytes]] = []
        for i in range(n):
            rng = random.Random(f"degraded|{seed}|{i}")
            files = {f"{SRC_ROOT}/t{i}/f{k}.bin":
                     rng.randbytes(rng.randint(1 * KB, 2 * KB))
                     for k in range(files_per_task)}
            per_task_files.append(files)
            for name, data in files.items():
                src_inner.store.put(name, data)
        dst_inner = MemoryConnector(clock=self.clock)
        dst_conn = FaultProxyConnector(dst_inner, schedule)

        creds = CredentialStore()
        for ep_id in ("src-ep", "dst-ep"):
            creds.register(ep_id, Credential("local-user", {"token": "t"}))
        hp = EndpointHealth(cfg, clock=self.clock)
        # batching off: the per-file path's admit() gate is the budget
        # enforcement under test
        options = TransferOptions(
            startup_cost=0.0, retry_backoff=0.01, concurrency=2,
            max_retries=max_retries, coalesce_threshold=0,
            unavailable_patience=patience)

        per_endpoint_cap = 2
        manager = None
        if mode == "death":
            # the fleet goes through ONE control plane: dispatch must
            # defer around the open breaker and never wedge
            options.concurrency = 1
            manager = TransferManager(
                max_workers=4, per_endpoint_cap=per_endpoint_cap,
                credential_store=creds,
                marker_root=os.path.join(run_dir, "markers"),
                clock=self.clock, health=hp)
            submit = manager.submit
            service = manager.service
        else:
            service = TransferService(
                credential_store=creds,
                marker_root=os.path.join(run_dir, "markers"),
                clock=self.clock, health=hp)
            submit = service.submit

        tasks = []
        for i in range(n):
            tasks.append(submit(
                Endpoint(src_inner, f"{SRC_ROOT}/t{i}", "src-ep"),
                Endpoint(dst_conn, f"{DST_ROOT}/t{i}", "dst-ep"),
                options, task_id=f"deg-{mode}-t{i}"))
        if manager is not None:
            finished = manager.wait_all(timeout=timeout)
        else:
            finished = all(t.wait(timeout=timeout) for t in tasks)

        pfx = DST_ROOT + "/"
        dest_all = {k[len(pfx):]: dst_inner.store.get(k)
                    for k in dst_inner.store.keys() if k.startswith(pfx)}

        results: list[ScenarioResult] = []
        violations: list[str] = []
        for i, task in enumerate(tasks):
            tp = f"t{i}/"
            expected = {name[len(SRC_ROOT) + 1:]: data
                        for name, data in per_task_files[i].items()}
            dest = {k: v for k, v in dest_all.items() if k.startswith(tp)}
            task_done = task._done.is_set()
            markers_after = service.markers.load(task.task_id) \
                if task_done else {"files": {"unfinished": True}}
            v = check_invariants(task, expected, dest, schedule,
                                 markers_after, task_done, options.integrity)
            results.append(ScenarioResult(
                task=task, schedule=schedule, expected=expected, dest=dest,
                violations=v, route=f"degraded:{mode}", tree="degraded"))
            violations.extend(f"task {i}: {x}" for x in v)

        if not finished:
            violations.append(f"wedged: the {mode} fleet did not finish "
                              f"within the timeout")
        agg: dict[str, int] = {}
        for t in tasks:
            for k, c in t.stats.retries_by_kind.items():
                agg[k] = agg.get(k, 0) + c
        names = hp.transition_names("dst-ep")
        attempts = schedule.count("transient")

        if mode == "brownout":
            for i, t in enumerate(tasks):
                if t.status != t.SUCCEEDED:
                    violations.append(
                        f"task {i} ended {t.status} — a brownout (bounded "
                        f"storm) must not fail the fleet")
            if not names or names[0] != "closed->open":
                violations.append(
                    f"breaker never opened on the error burst: {names}")
            elif names[-1] != "half-open->closed":
                violations.append(
                    f"breaker did not close after recovery: {names}")
            if not agg.get("EndpointUnavailable"):
                violations.append("no EndpointUnavailable fast-fail was "
                                  "recorded: the fleet hammered the sick "
                                  "endpoint through the open breaker")
            if not agg.get("HalfOpenProbe"):
                violations.append("no half-open probe was recorded: the "
                                  "breaker cannot have closed legally")
        else:  # death
            for i, t in enumerate(tasks):
                if t.status == t.SUCCEEDED and t.stats.bytes_total > 0:
                    violations.append(
                        f"task {i} SUCCEEDED against a dead endpoint")
            # O(budget) bound: pre-open evidence window + concurrent
            # in-flight attempts + budget-funded probes + slack — NOT
            # O(n_tasks * max_retries)
            bound = (cfg.min_samples + per_endpoint_cap
                     + int(cfg.retry_budget_capacity) + 2)
            if attempts > bound:
                violations.append(
                    f"retry storm: {attempts} storage attempts against "
                    f"the dead endpoint, budget bound is {bound}")
            if attempts >= n * (max_retries + 1):
                violations.append(
                    f"unbounded retries: {attempts} >= "
                    f"n_tasks*max_retries = {n * (max_retries + 1)}")
            if not names or names[0] != "closed->open":
                violations.append(
                    f"breaker never opened on the dead endpoint: {names}")
            if not agg.get("EndpointUnavailable"):
                violations.append("no fast-fail denials recorded against "
                                  "the dead endpoint")
        if manager is not None:
            manager.shutdown(wait=False)

        result = DegradedScenarioResult(
            mode=mode, results=results, health=hp, schedule=schedule,
            transitions=names, attempts=attempts, retries_by_kind=agg,
            violations=violations)
        if strict and violations:
            raise AssertionError(
                f"degraded scenario ({mode}) violated invariants:\n  "
                + "\n  ".join(violations))
        return result

    def _degraded_federation(self, run_dir: str, n_tasks: int,
                             miss_threshold: int, victim: int, seed: int,
                             timeout: float,
                             strict: bool) -> "DegradedScenarioResult":
        """Flapping then permanently-dark federation site: heartbeat
        misses below threshold must NOT fail the site; sustained misses
        must auto-trigger failover with no caller ``fail_site``."""
        n_sites = 2
        victim_site = f"s{victim % n_sites}"

        src_inners = [MemoryConnector(clock=self.clock) for _ in range(n_sites)]
        per_task_files: list[dict[str, bytes]] = []
        specs: list[TransferSpec] = []
        for j in range(n_tasks):
            rng = random.Random(f"degraded-fed|{seed}|{j}")
            files = {f"{SRC_ROOT}/t{j}/f{k}.bin":
                     rng.randbytes(rng.randint(4 * KB, 8 * KB))
                     for k in range(3)}
            per_task_files.append(files)
            store = src_inners[j % n_sites].store
            for name, data in files.items():
                store.put(name, data)

        src_conns: list = list(src_inners)
        # gate the victim's source streams so at least one of its tasks
        # is genuinely mid-flight when the site goes dark (same idiom as
        # run_federated)
        hold = _HoldSrc(src_conns[victim % n_sites])
        src_conns[victim % n_sites] = hold
        hold.arm_hold([SRC_ROOT + "/"], 2048)
        dst_inner = MemoryConnector(clock=self.clock)
        dst_conn = _InstrumentedDst(dst_inner)

        endpoints = {f"src-s{i}": src_conns[i] for i in range(n_sites)}
        endpoints["dst-ep"] = dst_conn
        coord = FederatedCoordinator(placement="owner",
                                     miss_threshold=miss_threshold)
        flaky: _FlakyDigest | None = None
        for i in range(n_sites):
            creds = CredentialStore()
            creds.register(f"src-s{i}", Credential(
                "local-user", {"identity": "alice"}))
            manager = TransferManager(
                max_workers=3, per_endpoint_cap=None,
                credential_store=creds,
                marker_root=os.path.join(run_dir, f"site{i}", "markers"),
                clock=self.clock, site_id=f"s{i}")
            handle = manager
            if i == victim % n_sites:
                flaky = _FlakyDigest(manager)
                handle = flaky
            coord.register_site(f"s{i}", handle, endpoints,
                                owns={f"src-s{i}"}
                                | ({"dst-ep"} if i == 0 else set()))

        options = TransferOptions(
            startup_cost=0.0, retry_backoff=0.01, concurrency=2)
        victim_ids: list[str] = []
        for j in range(n_tasks):
            spec = TransferSpec.new(
                f"deg-fed-t{j}",
                f"src-s{j % n_sites}", f"{SRC_ROOT}/t{j}",
                "dst-ep", f"{DST_ROOT}/t{j}",
                tenant="alice", options=options,
                n_files=len(per_task_files[j]),
                nbytes=sum(len(d) for d in per_task_files[j].values()))
            specs.append(spec)
            if j % n_sites == victim % n_sites:
                victim_ids.append(spec.task_id)
            coord.submit(spec.to_json())

        violations: list[str] = []
        if not hold.engaged.wait(timeout=min(60.0, timeout)):
            violations.append("hold never engaged: the victim site had "
                              "no mid-flight task to strand")
            hold.release()

        # phase 1: flap BELOW the threshold — no failover may fire
        flaky.down.set()
        for _ in range(miss_threshold - 1):
            coord.beat(timeout=timeout)
        flaky.down.clear()
        coord.beat(timeout=timeout)  # recovery beat resets the misses
        vh = coord.sites()[victim_site]
        if coord.metrics.auto_failovers or not vh.alive:
            violations.append(
                "flapping below miss_threshold triggered a failover: "
                "the monitor has no hysteresis")
        if vh.missed_beats != 0:
            violations.append(
                f"recovered heartbeat did not reset the miss counter "
                f"({vh.missed_beats} != 0)")

        # phase 2: permanently dark — beat() must auto-fail the site.
        # The releaser frees the held streams only once every victim
        # task has its pause landed (or finished), so the traveled
        # checkpoint is guaranteed mid-flight.
        flaky.down.set()
        victim_tasks = [coord.task(tid) for tid in victim_ids]

        def do_release():
            t_end = wall_now() + min(60.0, timeout)
            while wall_now() < t_end:
                if all(t._done.is_set() or t._pause_req.is_set()
                       or t.status == t.PAUSED for t in victim_tasks):
                    break
                wall_sleep(0.005)
            hold.release()

        releaser = threading.Thread(target=do_release, daemon=True)
        releaser.start()
        t0 = self.clock.virtual_elapsed
        failed_sites: list[str] = []
        for _ in range(miss_threshold + 2):
            failed_sites = coord.beat(timeout=timeout)
            if failed_sites:
                break
        failover_model_s = self.clock.virtual_elapsed - t0
        releaser.join(timeout=min(60.0, timeout))

        finished = coord.wait_all(timeout=timeout)
        pfx = DST_ROOT + "/"
        dest_all = {k[len(pfx):]: dst_inner.store.get(k)
                    for k in dst_inner.store.keys()
                    if k.startswith(pfx)} if finished else {}

        moved = [(tid, sid) for tid, sid, reason
                 in coord.metrics.placement_log if reason == "failover"]
        results: list[ScenarioResult] = []
        for j, spec in enumerate(specs):
            task = coord.task(spec.task_id)
            site_id = coord.site_of(spec.task_id)
            mgr = coord.sites()[site_id].manager
            tp = f"t{j}/"
            expected = {name[len(SRC_ROOT) + 1:]: data
                        for name, data in per_task_files[j].items()}
            dest = {k: v for k, v in dest_all.items() if k.startswith(tp)}
            task_done = finished and task._done.is_set()
            markers_after = mgr.service.markers.load(spec.task_id) \
                if task_done else {"files": {"unfinished": True}}
            v = check_invariants(task, expected, dest, None,
                                 markers_after, task_done,
                                 options.integrity)
            results.append(ScenarioResult(
                task=task, schedule=None, expected=expected, dest=dest,
                violations=v, route=f"fed:{site_id}", tree="degraded"))
            violations.extend(f"task {j}: {x}" for x in v)

        if not finished:
            violations.append("wedged: the federation did not finish "
                              "within the timeout")
        if failed_sites != [victim_site]:
            violations.append(
                f"heartbeat monitor failed over {failed_sites!r}, "
                f"expected [{victim_site!r}]")
        if coord.metrics.auto_failovers != 1:
            violations.append(
                f"auto_failovers = {coord.metrics.auto_failovers}, "
                f"expected exactly 1 (heartbeat-driven)")
        if coord.sites()[victim_site].alive:
            violations.append("victim site still alive after sustained "
                              "heartbeat loss")
        if hold.engaged.is_set() and not moved:
            violations.append("auto-failover re-homed no tasks (all "
                              "finished before the site went dark?)")
        if coord.metrics.stranded:
            violations.append(
                f"auto-failover stranded {coord.metrics.stranded!r}")
        for j, spec in enumerate(specs):
            task = coord.task(spec.task_id)
            if task.status != task.SUCCEEDED:
                violations.append(f"task {j} ended {task.status} after "
                                  f"auto-failover")
            elif finished and not options.integrity:
                written = dst_conn.written(f"{DST_ROOT}/t{j}/")
                if written != task.stats.bytes_total:
                    violations.append(
                        f"task {j}: {written} bytes written for a "
                        f"{task.stats.bytes_total} byte tree — failover "
                        f"must re-send only the holes")
        try:
            coord.assert_third_party()
        except AssertionError as e:
            violations.append(str(e))

        coord.shutdown(wait=False)
        result = DegradedScenarioResult(
            mode="flapping-site", results=results, health=None,
            schedule=None, coordinator=coord, moved=moved,
            failover_model_seconds=failover_model_s,
            violations=violations)
        if strict and violations:
            raise AssertionError(
                "degraded scenario (flapping-site) violated invariants:"
                "\n  " + "\n  ".join(violations))
        return result


@dataclass
class MultiScenarioResult:
    """Outcome of :meth:`ScenarioRunner.run_multi`."""

    results: list[ScenarioResult]
    manager: TransferManager
    violations: list[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def tasks(self):
        return [r.task for r in self.results]


@dataclass
class FederatedScenarioResult:
    """Outcome of :meth:`ScenarioRunner.run_federated`."""

    results: list[ScenarioResult]
    coordinator: FederatedCoordinator
    #: (task_id, new_site_id) for every task the site failure re-homed
    moved: list = field(default_factory=list)
    violations: list[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def tasks(self):
        return [r.task for r in self.results]


@dataclass
class FanoutScenarioResult:
    """Outcome of :meth:`ScenarioRunner.run_fanout`."""

    chaos: str
    results: list[ScenarioResult]
    manager: TransferManager
    catalog: ReplicaCatalog
    #: bytes that actually left the source (send-side meter) vs the
    #: tree's size — the fan-out dedupe ratio
    source_bytes: int = 0
    tree_bytes: int = 0
    replica_hits: int = 0
    replica_fallbacks: int = 0
    violations: list[str] = field(default_factory=list)

    @property
    def moved_ratio(self) -> float:
        """source bytes moved per tree byte: ~1.0 means the fan-out
        collapsed to one real transfer."""
        return self.source_bytes / self.tree_bytes if self.tree_bytes \
            else 0.0

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def tasks(self):
        return [r.task for r in self.results]


@dataclass
class DegradedScenarioResult:
    """Outcome of :meth:`ScenarioRunner.run_degraded`."""

    mode: str
    results: list[ScenarioResult]
    #: the shared health registry (endpoint modes; None for fed mode)
    health: EndpointHealth | None
    schedule: FaultSchedule | None
    coordinator: FederatedCoordinator | None = None
    #: (task_id, new_site_id) re-homed by the heartbeat-driven failover
    moved: list = field(default_factory=list)
    #: breaker transition names for the sick endpoint, in order
    transitions: list = field(default_factory=list)
    #: storage-level fault firings against the sick endpoint (the
    #: number the shared retry budget bounds)
    attempts: int = 0
    #: fleet-aggregated ``TaskStats.retries_by_kind``
    retries_by_kind: dict = field(default_factory=dict)
    #: model seconds from the first dark beat to the automatic failover
    failover_model_seconds: float = 0.0
    violations: list[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def tasks(self):
        return [r.task for r in self.results]
