"""Failure-scenario simulation harness (chaos lab).

The paper sells the Connector abstraction on *managed* transfer —
automatic retries, restart markers, end-to-end integrity (§2.2, §4, §7)
— and the ROADMAP north-star demands a fabric that "handles as many
scenarios as you can imagine".  This package is where those scenarios
live:

* :mod:`repro.sim.scenarios` — canonical source trees (many-small,
  few-large, mixed, deep/empty dirs, zero-byte files, unicode names),
  connector routes (posix / memory / emulated cloud, in every pairing),
  and a :class:`~repro.sim.scenarios.ScenarioRunner` that drives
  :class:`~repro.core.transfer.TransferService` under a seed-
  deterministic :class:`~repro.core.faults.FaultSchedule` and checks
  end-state invariants: the destination tree is byte-exact on success,
  restart markers are cleared, ``TaskStats`` accounting is consistent,
  and failures are clean (recorded per file), never wedged.

Everything runs on the model :class:`~repro.core.clock.Clock`, so a
scenario with seconds of injected latency still finishes instantly under
``REPRO_TIME_SCALE=0``, and the same seed replays the same fault
sequence into the same ``TaskStats``.
"""

from .scenarios import (ROUTES, TREES, DegradedScenarioResult,
                        FanoutScenarioResult, FederatedScenarioResult,
                        MultiScenarioResult, ScenarioResult, ScenarioRunner,
                        canonical_tree)

__all__ = ["ROUTES", "TREES", "DegradedScenarioResult",
           "FanoutScenarioResult", "FederatedScenarioResult",
           "MultiScenarioResult", "ScenarioResult", "ScenarioRunner",
           "canonical_tree"]
