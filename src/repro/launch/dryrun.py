import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input shape) cell, on the 16x16 single-pod
mesh and the 2x16x16 multi-pod mesh:

    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...) \
            .lower(**input_specs(arch))
        compiled = lowered.compile()
        print(compiled.memory_analysis())
        print(compiled.cost_analysis())

plus the three-term roofline (repro.roofline) parsed from the compiled
HLO.  Results cache as JSON under results/ so EXPERIMENTS.md tables are
regenerable.  Any failure here is a bug in the sharding config.

Usage:
    python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only | --single-pod-only]
"""

import argparse
import json
import sys
import time
import traceback


def _depth_config(cfg, n_units: int):
    """Config truncated to ``n_units`` scan blocks with every scan
    unrolled — used for the blockwise cost extrapolation.  SSM chunking
    switches to the TPU-native (L=512, R=128) MXU blocking so the
    counted FLOPs reflect the kernel's real operating point (and the
    unrolled sub-chunk graph stays small)."""
    import dataclasses
    unit = cfg.attn_every if cfg.family != "ssm" else 1
    kw = dict(n_layers=n_units * unit, unroll_scans=True, unroll_blocks=True)
    if cfg.encdec is not None:
        kw["encdec"] = dataclasses.replace(cfg.encdec,
                                           n_encoder_layers=n_units)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, chunk=512, subchunk=128)
    return dataclasses.replace(cfg, **kw)


def estimate_cost(arch_id: str, shape_name: str, mesh, cfg) -> dict:
    """Blockwise extrapolation (see repro.roofline.analysis docstring):
    compile 1-block and 2-block unrolled variants, extrapolate the
    marginal block cost to full depth."""
    from ..launch.cells import build_cell, lower_cell
    from ..roofline import cost_numbers, extrapolate

    nums = []
    for units in (1, 2):
        c = _depth_config(cfg, units)
        cell = build_cell(arch_id, shape_name, mesh, cfg=c)
        # cost compiles never execute: skip LLVM optimization of the
        # unrolled bodies (HLO-level cost/collective numbers unchanged)
        compiled = lower_cell(cell, mesh).compile(
            {"xla_backend_optimization_level": 0})
        nums.append(cost_numbers(compiled))
    return extrapolate(nums[0], nums[1], cfg.n_blocks)


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             results_dir: str = "results", skip_cost: bool = False) -> dict:
    import jax
    from ..configs import get_config
    from ..launch.cells import build_cell, lower_cell
    from ..launch.mesh import make_production_mesh, mesh_info
    from ..models.common import SHAPES
    from ..roofline import (cost_numbers, roofline_from_numbers,
                            roofline_terms)

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get_config(arch_id)
    compiler_options = None
    if cfg.family == "hybrid":
        # The hybrid stack hits a pathological CPU-backend codegen path;
        # skip LLVM optimization (host codegen only — HLO, SPMD
        # partitioning, memory_analysis and cost_analysis unchanged).
        # For train, additionally unroll the inner ssm/attn chunk scans:
        # the backward of nested whiles is the worst case; at 4k train
        # the unrolled bodies stay small.  (Prefill at 32k keeps inner
        # scans — 8k unrolled sub-units would explode the module.)
        import dataclasses
        compiler_options = {"xla_backend_optimization_level": 0}
        if SHAPES[shape_name].kind == "train":
            cfg = dataclasses.replace(cfg, unroll_scans=True)
    t0 = time.time()  # lint: disable=R001(measures real XLA lowering wall time — outside the transfer model entirely)
    cell = build_cell(arch_id, shape_name, mesh, cfg=cfg)
    lowered = lower_cell(cell, mesh)
    t_lower = time.time() - t0  # lint: disable=R001(measures real XLA lowering wall time)
    t0 = time.time()  # lint: disable=R001(measures real XLA compile wall time)
    compiled = (lowered.compile(compiler_options) if compiler_options
                else lowered.compile())
    t_compile = time.time() - t0  # lint: disable=R001(measures real XLA compile wall time)

    ma = compiled.memory_analysis()
    print(f"[{arch_id} x {shape_name} @ {mesh_name}] memory_analysis: "
          f"args={ma.argument_size_in_bytes/1e9:.2f}GB "
          f"temps={ma.temp_size_in_bytes/1e9:.2f}GB "
          f"out={ma.output_size_in_bytes/1e9:.2f}GB per device")
    raw = cost_numbers(compiled)
    print(f"  cost_analysis (scan-counted-once): flops/dev={raw['flops']:.3e} "
          f"bytes/dev={raw['bytes']:.3e} coll/dev={raw['coll']['total']:.3e}")

    # blockwise extrapolation for honest totals
    if skip_cost:
        numbers = raw
        note = "raw cost_analysis (scan bodies counted once)"
    else:
        numbers = estimate_cost(arch_id, shape_name, mesh, cfg)
        note = "blockwise extrapolation (1/2-block unrolled compiles)"
    roof = roofline_from_numbers(numbers, arch=arch_id,
                                 shape_name=shape_name, mesh_name=mesh_name,
                                 n_devices=mesh.size, cfg=cfg,
                                 shape=SHAPES[shape_name],
                                 memory_analysis=ma, note=note)
    print("  " + roofline_terms(roof))

    rec = roof.to_dict()
    rec.update({
        "ok": True,
        "lower_seconds": t_lower,
        "compile_seconds": t_compile,
        "bytes_per_dev_output": float(ma.output_size_in_bytes),
        "raw_cost": {"flops": raw["flops"], "bytes": raw["bytes"],
                     "coll_total": raw["coll"]["total"]},
        "mesh_info": mesh_info(mesh),
        "fits_hbm": (ma.argument_size_in_bytes + ma.temp_size_in_bytes)
        < 16 * 1024**3,
        "kind": cell.kind,
    })
    os.makedirs(results_dir, exist_ok=True)
    out = os.path.join(results_dir,
                       f"dryrun_{arch_id}_{shape_name}_{mesh_name}.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--results-dir", default="results")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--skip-cost", action="store_true",
                    help="skip the 1/2-block cost extrapolation (multi-pod"
                         " runs prove sharding; the roofline table is"
                         " single-pod)")
    args = ap.parse_args()

    from ..configs import ARCH_IDS
    from ..models.common import cells_for

    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in cells_for(a)]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all) required")
        cells = [(args.arch, args.shape)]

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)
    if args.multi_pod and False not in meshes:
        meshes = [True]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            path = os.path.join(args.results_dir,
                                f"dryrun_{arch}_{shape}_{mesh_name}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        print(f"[skip cached] {arch} x {shape} @ {mesh_name}")
                        continue
            try:
                run_cell(arch, shape, mp, args.results_dir,
                         skip_cost=args.skip_cost or mp)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape, mesh_name, repr(e)))
                os.makedirs(args.results_dir, exist_ok=True)
                with open(path, "w") as f:
                    json.dump({"ok": False, "arch": arch, "shape": shape,
                               "mesh": mesh_name, "error": repr(e)}, f)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f4 in failures:
            print("  ", *f4)
        return 1
    print("\nALL DRY-RUN CELLS PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
