"""Serving launcher: batched prefill + decode over any arch config.

``python -m repro.launch.serve --arch qwen1.5-0.5b --requests 8``
runs a scaled-down model on CPU; the same ``serve_step`` is what the
decode dry-run shapes lower on the production meshes.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--scaled-down", action="store_true", default=True)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from ..configs import get_config
    from ..models.registry import build
    from ..runtime.steps import make_serve_step

    cfg = get_config(args.arch)
    if args.scaled_down:
        cfg = cfg.scaled_down()
    api = build(cfg)
    params = jax.jit(api.init)(jax.random.PRNGKey(0))

    B, S = args.requests, args.prompt_len
    max_seq = S + args.gen_len
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["audio_embeds"] = jax.random.normal(
            key, (B, cfg.encdec.n_audio_ctx, cfg.d_model), jnp.float32)
    if cfg.vlm is not None:
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.vlm.n_image_tokens, cfg.vlm.patch_dim), jnp.float32)

    t0 = time.time()  # lint: disable=R001(benchmarks real prefill wall time — outside the transfer model entirely)
    logits, cache, pos = jax.jit(
        lambda p, b: api.prefill(p, b, pad_to=max_seq))(params, batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    print(f"prefill {B}x{S} in {time.time()-t0:.2f}s")  # lint: disable=R001(benchmarks real prefill wall time)

    serve_step = jax.jit(make_serve_step(api), donate_argnums=(1,))
    out = [tok]
    t0 = time.time()  # lint: disable=R001(benchmarks real decode wall time)
    for i in range(args.gen_len - 1):
        tok, cache = serve_step(params, cache, tok, jnp.int32(S + i))
        out.append(tok)
    dt = time.time() - t0  # lint: disable=R001(benchmarks real decode wall time)
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.gen_len - 1} steps x {B} seqs in {dt:.2f}s "
          f"({B * (args.gen_len - 1) / dt:.1f} tok/s)")
    print("sample token ids:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
