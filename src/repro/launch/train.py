"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Small-scale (CPU) end-to-end driver over the full stack: Connector-
backed data, jitted train step, async checkpoints, optional third-party
replication.  On a real pod, the same entry point runs per host with
``--mesh single|multi`` and jax.distributed initialization.
"""

from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--scaled-down", action="store_true", default=True)
    ap.add_argument("--full-size", dest="scaled_down", action="store_false")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train")
    ap.add_argument("--replicate-to", default=None,
                    help="cloud provider id (s3|gcs|...) for third-party "
                         "checkpoint replication")
    ap.add_argument("--data-records", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    import jax
    from ..configs import get_config
    from ..connectors import PosixConnector, ObjectStoreConnector, make_cloud
    from ..core import Credential, CredentialStore, Endpoint, TransferService
    from ..ckpt import CheckpointManager, replicate_checkpoint
    from ..data import DataPipelineConfig, ShardedTokenDataset, synthetic_corpus
    from ..models.registry import build
    from ..optim import OptimizerConfig
    from ..runtime.train import TrainLoopConfig, run_training

    cfg = get_config(args.arch)
    if args.scaled_down:
        cfg = cfg.scaled_down()
    api = build(cfg)

    root = os.path.abspath(args.ckpt_dir)
    store = PosixConnector(root)
    # data through the Connector interface
    synthetic_corpus(store, "corpus", vocab_size=cfg.vocab_size,
                     seq_len=args.seq_len, n_records=args.data_records,
                     records_per_shard=64)
    ds = ShardedTokenDataset(store, "corpus", DataPipelineConfig(
        seq_len=args.seq_len, batch_size=args.batch_size))

    ckpt_mgr = CheckpointManager(store, "ckpt")
    replicator = None
    if args.replicate_to:
        cloud = make_cloud(args.replicate_to)
        conn = ObjectStoreConnector(cloud, placement="cloud")
        creds = CredentialStore()
        creds.register("mirror", Credential(conn.credential_scheme, {}))
        svc = TransferService(credential_store=creds)

        def replicator(step):
            task = replicate_checkpoint(
                svc, Endpoint(store, "ckpt"),
                Endpoint(conn, "mirror", "mirror"), step, sync=True)
            print(f"  replicated step {step}: {task.status} "
                  f"({task.stats.bytes_done / 1e6:.1f} MB)")

    opt = OptimizerConfig(peak_lr=args.lr, warmup_steps=20,
                          total_steps=args.steps, state_dtype="float32")
    loop = TrainLoopConfig(total_steps=args.steps, log_every=10,
                           ckpt_every=args.ckpt_every,
                           replicate_every=args.ckpt_every
                           if args.replicate_to else 0)
    result = run_training(api, opt, loop, ds, ckpt_mgr=ckpt_mgr,
                          replicator=replicator)
    print(f"done: {result.steps_run} steps, final loss "
          f"{result.final_loss:.4f}, {result.tokens_per_second:.0f} tok/s"
          + (f", restored from step {result.restored_from}"
             if result.restored_from else ""))


if __name__ == "__main__":
    main()
