"""Cell assembly: for one (architecture x input-shape x mesh) cell,
build the step function, in/out shardings, and abstract inputs.

This is the single source of truth used by the dry-run, the launcher
and the serving driver, so "it compiled in the dry-run" means the real
entry points get exactly the same lowering.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.common import ArchConfig, SHAPES, ShapeConfig
from ..models.registry import (build, input_specs, train_batch_specs,
                               prefill_batch_specs)
from ..optim import OptimizerConfig
from ..runtime.steps import (abstract_train_state, make_prefill_step,
                             make_serve_step, make_train_step)
from ..sharding.rules import (AxisRules, axis_rules, batch_spec,
                              param_specs, production_rules)


def _axes_dividing(mesh, names: tuple[str, ...], size: int):
    """Largest prefix-combination of mesh axes whose product divides
    ``size``; returns tuple (possibly empty)."""
    chosen = []
    prod = 1
    for n in names:
        if n in mesh.shape and size % (prod * mesh.shape[n]) == 0:
            chosen.append(n)
            prod *= mesh.shape[n]
    return tuple(chosen)


def _maybe(axes):
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


@dataclass
class Cell:
    arch_id: str
    shape_name: str
    cfg: ArchConfig
    shape: ShapeConfig
    rules: AxisRules
    step_fn: Any
    in_shardings: Any
    out_shardings: Any
    abstract_inputs: tuple
    kind: str  # train | prefill | decode


def _dp_axes(mesh):
    return tuple(n for n in ("pod", "data") if n in mesh.shape)


def make_rules(cfg: ArchConfig, shape: ShapeConfig, mesh) -> AxisRules:
    multi_pod = "pod" in mesh.shape
    dp = _dp_axes(mesh)
    dp_size = 1
    for n in dp:
        dp_size *= mesh.shape[n]
    rules = production_rules(multi_pod,
                             batch_divisible=shape.global_batch % dp_size == 0,
                             mesh=mesh)
    return rules


def batch_sharding_tree(specs: dict, mesh, rules: AxisRules):
    """NamedShardings for a train/prefill batch dict."""
    def leaf(s):
        bspec = batch_spec(s.shape[0], mesh)
        full = P(*(list(bspec) + [None] * (len(s.shape) - 1)))
        return NamedSharding(mesh, full)

    return jax.tree.map(leaf, specs)


def cache_sharding_tree(cache_specs, cfg: ArchConfig, shape: ShapeConfig,
                        mesh):
    """Per-leaf cache shardings (see DESIGN.md §4): batch over data axes
    when divisible; KV heads over "model" when divisible, else the cache
    sequence dim; long-context (B=1) shards sequence over everything."""
    dp = _dp_axes(mesh)
    B = shape.global_batch

    def leaf_spec(path, s):
        name = path[-1] if path else ""
        dims = [None] * len(s.shape)
        batch_axes = _axes_dividing(mesh, dp, B)
        if name in ("attn_k", "attn_v", "cross_k", "cross_v"):
            # (nb, n, B, S, KV, dh)
            dims[2] = _maybe(batch_axes)
            S_dim, KV_dim = s.shape[3], s.shape[4]
            rem = [a for a in ("model",) + dp if a not in batch_axes
                   or a == "model"]
            # prefer sharding KV heads on "model"
            if KV_dim % mesh.shape.get("model", 1) == 0:
                dims[4] = "model"
                seq_axes = _axes_dividing(
                    mesh, tuple(a for a in dp if a not in batch_axes), S_dim)
                dims[3] = _maybe(seq_axes)
            else:
                seq_pool = tuple(a for a in ("data", "model", "pod")
                                 if a in mesh.shape and a not in batch_axes)
                seq_axes = _axes_dividing(mesh, seq_pool, S_dim)
                dims[3] = _maybe(seq_axes)
        elif name == "ssm":
            # (nb, n, B, H, K, V)
            dims[2] = _maybe(batch_axes)
            if s.shape[3] % mesh.shape.get("model", 1) == 0:
                dims[3] = "model"
        elif name in ("conv", "shift_t", "shift_c"):
            dims[2] = _maybe(batch_axes)
            if s.shape[-1] % mesh.shape.get("model", 1) == 0:
                dims[-1] = "model"
        return NamedSharding(mesh, P(*dims))

    flat = jax.tree_util.tree_flatten_with_path(cache_specs)[0]
    treedef = jax.tree_util.tree_structure(cache_specs)
    out = []
    for keypath, leafval in flat:
        parts = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in keypath]
        out.append(leaf_spec(parts, leafval))
    return jax.tree_util.tree_unflatten(treedef, out)


def state_sharding_tree(state_specs, mesh, rules: AxisRules):
    with axis_rules(rules):
        pspecs = param_specs(state_specs["params"])
        mspecs = param_specs(state_specs["opt"]["m"])
        vspecs = param_specs(state_specs["opt"]["v"])
    to_sh = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    return {
        "params": to_sh(pspecs),
        "opt": {"m": to_sh(mspecs), "v": to_sh(vspecs),
                "step": NamedSharding(mesh, P())},
    }


def build_cell(arch_id: str, shape_name: str, mesh, cfg: ArchConfig | None = None,
               opt_cfg: OptimizerConfig | None = None) -> Cell:
    from ..configs import get_config
    cfg = cfg or get_config(arch_id)
    shape = SHAPES[shape_name]
    rules = make_rules(cfg, shape, mesh)
    api = build(cfg)
    opt_cfg = opt_cfg or OptimizerConfig()
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        state_specs = abstract_train_state(api, opt_cfg)
        state_sh = state_sharding_tree(state_specs, mesh, rules)
        bspecs = train_batch_specs(cfg, shape)
        batch_sh = batch_sharding_tree(bspecs, mesh, rules)
        raw_step = make_train_step(api, opt_cfg)

        def step(state, batch):
            with axis_rules(rules):
                return raw_step(state, batch)

        return Cell(arch_id, shape_name, cfg, shape, rules, step,
                    (state_sh, batch_sh),
                    (state_sh, jax.tree.map(lambda _: repl,
                                            _metric_specs())),
                    (state_specs, bspecs), "train")

    if shape.kind == "prefill":
        pspecs = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        with axis_rules(rules):
            psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               param_specs(pspecs))
        bspecs = prefill_batch_specs(cfg, shape)
        batch_sh = batch_sharding_tree(bspecs, mesh, rules)
        raw_step = make_prefill_step(api)

        def step(params, batch):
            with axis_rules(rules):
                return raw_step(params, batch)

        cache_specs = jax.eval_shape(
            lambda p, b: raw_step(p, b)[1], pspecs, bspecs)
        cache_sh = cache_sharding_tree(cache_specs, cfg, shape, mesh)
        vmodel = ("model" if cfg.vocab_size % mesh.shape.get("model", 1) == 0
                  else None)
        logits_sh = NamedSharding(
            mesh, P(*(list(batch_spec(shape.global_batch, mesh))
                      + [None, vmodel])))
        return Cell(arch_id, shape_name, cfg, shape, rules, step,
                    (psh, batch_sh), (logits_sh, cache_sh),
                    (pspecs, bspecs), "prefill")

    # decode
    pspecs = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    with axis_rules(rules):
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           param_specs(pspecs))
    specs = input_specs(cfg, shape_name)
    token_spec, pos_spec, cache_specs = (specs["token"], specs["pos"],
                                         specs["cache"])
    cache_sh = cache_sharding_tree(cache_specs, cfg, shape, mesh)
    tok_sh = NamedSharding(mesh, P(*(list(batch_spec(shape.global_batch,
                                                     mesh)) + [None])))
    raw_step = make_serve_step(api, greedy=True)

    def step(params, cache, token, pos):
        with axis_rules(rules):
            return raw_step(params, cache, token, pos)

    return Cell(arch_id, shape_name, cfg, shape, rules, step,
                (psh, cache_sh, tok_sh, repl),
                (tok_sh, cache_sh),
                (pspecs, cache_specs, token_spec, pos_spec), "decode")


def _metric_specs():
    f32 = jax.ShapeDtypeStruct((), jnp.float32)
    return {"loss": f32, "xent": f32, "moe_aux": f32, "grad_norm": f32,
            "lr": f32}


def lower_cell(cell: Cell, mesh, donate: bool = True):
    """jit + lower the cell with its shardings (the dry-run entry)."""
    donate_argnums = ()
    if donate:
        donate_argnums = (0,) if cell.kind == "train" else \
            ((1,) if cell.kind == "decode" else ())
    jitted = jax.jit(cell.step_fn,
                     in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=donate_argnums)
    with mesh:  # mesh context: bare PartitionSpec constraints resolve
        lowered = jitted.lower(*cell.abstract_inputs)
    return lowered
