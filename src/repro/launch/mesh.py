"""Production mesh construction.

Functions, not module-level constants: importing this module never
touches jax device state.  The dry-run process sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so ``jax.make_mesh`` can build these meshes on CPU.

Hardware target: TPU v5e pods — 16x16 = 256 chips per pod; the
multi-pod mesh adds a leading "pod" axis (2 pods = 512 chips) that
composes with "data" for batch/FSDP sharding (DCN between pods, ICI
within).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2, pods: int = 0):
    """Small mesh for CI-scale sharding tests (needs
    xla_force_host_platform_device_count >= n_data * n_model * pods)."""
    if pods:
        return jax.make_mesh((pods, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def mesh_info(mesh) -> dict:
    return {
        "axis_names": list(mesh.axis_names),
        "shape": {k: int(v) for k, v in mesh.shape.items()},
        "n_devices": int(mesh.size),
    }
