"""Fault-tolerant training loop.

Wires together the Connector-backed data pipeline, the jitted train
step, async Connector checkpointing, and third-party checkpoint
replication — the paper's storage abstraction as the framework's
data/ckpt substrate.  Restart is crash-consistent: (model state,
data-iterator cursor) restore from the latest committed checkpoint.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from ..ckpt import CheckpointManager
from ..ckpt.io import get_bytes, put_bytes
from ..core.errors import NotFound
from ..models.registry import ModelApi
from ..optim import OptimizerConfig
from .steps import make_train_state, make_train_step


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    replicate_every: int = 0      # 0 = off
    seed: int = 0
    fail_at_step: int = -1        # fault injection for tests


@dataclass
class TrainResult:
    steps_run: int
    final_loss: float
    losses: list = field(default_factory=list)
    restored_from: int | None = None
    tokens_per_second: float = 0.0


def run_training(api: ModelApi, opt_cfg: OptimizerConfig,
                 loop_cfg: TrainLoopConfig, data_iter,
                 ckpt_mgr: CheckpointManager | None = None,
                 replicator=None, mesh=None, state_shardings=None) -> TrainResult:
    train_step = make_train_step(api, opt_cfg)
    jit_kwargs = {}
    if state_shardings is not None:
        jit_kwargs = dict(in_shardings=(state_shardings, None),
                          out_shardings=(state_shardings, None))
    step_fn = jax.jit(train_step, donate_argnums=(0,), **jit_kwargs)

    state = make_train_state(api, opt_cfg, jax.random.PRNGKey(loop_cfg.seed))
    start_step = 0
    restored_from = None
    if ckpt_mgr is not None:
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        restored, step = ckpt_mgr.restore_latest(abstract,
                                                 shardings=state_shardings)
        if restored is not None:
            state = restored
            start_step = step
            restored_from = step
            # resume the data cursor
            try:
                session = ckpt_mgr.connector.start(ckpt_mgr.credential)
                cursor = json.loads(get_bytes(
                    ckpt_mgr.connector, session,
                    f"{ckpt_mgr.base}/step_{step}/data_state.json"))
                ckpt_mgr.connector.destroy(session)
                if hasattr(data_iter, "restore"):
                    data_iter.restore(cursor)
            except NotFound:
                pass

    batches = (data_iter.prefetching_batches()
               if hasattr(data_iter, "prefetching_batches") else data_iter)
    losses = []
    t0 = time.time()  # lint: disable=R001(tokens/s is a real training-throughput stat — outside the transfer model entirely)
    tokens = 0
    step = start_step
    for step in range(start_step + 1, loop_cfg.total_steps + 1):
        batch = next(batches) if hasattr(batches, "__next__") \
            else next(iter(batches))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if loop_cfg.fail_at_step == step:
            raise RuntimeError(f"injected failure at step {step}")
        state, metrics = step_fn(state, batch)
        tokens += int(np.prod(batch["tokens"].shape))
        if step % loop_cfg.log_every == 0 or step == loop_cfg.total_steps:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            print(f"step {step}: loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
        if ckpt_mgr is not None and (step % loop_cfg.ckpt_every == 0
                                     or step == loop_cfg.total_steps):
            ckpt_mgr.save_async(state, step)
            ckpt_mgr.wait()
            if hasattr(data_iter, "state"):
                session = ckpt_mgr.connector.start(ckpt_mgr.credential)
                put_bytes(ckpt_mgr.connector, session,
                          f"{ckpt_mgr.base}/step_{step}/data_state.json",
                          json.dumps(data_iter.state()).encode())
                ckpt_mgr.connector.destroy(session)
            if replicator is not None and loop_cfg.replicate_every and \
                    step % loop_cfg.replicate_every == 0:
                replicator(step)
    dt = max(time.time() - t0, 1e-9)  # lint: disable=R001(tokens/s is a real training-throughput stat)
    final_loss = losses[-1][1] if losses else float("nan")
    return TrainResult(steps_run=step - start_step, final_loss=final_loss,
                       losses=losses, restored_from=restored_from,
                       tokens_per_second=tokens / dt)
