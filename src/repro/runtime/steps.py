"""jit-able train / prefill / serve step functions.

``train_step`` is the canonical (state, batch) -> (state, metrics)
update: loss, grads, global-norm clip, AdamW with sharded bf16 moments.
``serve_step`` consumes one token against a fixed-size cache (decode
shapes lower exactly this, per the assignment).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..models.registry import ModelApi
from ..optim import OptimizerConfig, adamw_init, adamw_update


def make_train_state(api: ModelApi, opt_cfg: OptimizerConfig, key=None):
    params = api.init(key if key is not None else jax.random.PRNGKey(0))
    return {"params": params, "opt": adamw_init(params, opt_cfg)}


def abstract_train_state(api: ModelApi, opt_cfg: OptimizerConfig):
    return jax.eval_shape(lambda k: make_train_state(api, opt_cfg, k),
                          jax.random.PRNGKey(0))


def make_train_step(api: ModelApi, opt_cfg: OptimizerConfig,
                    accum_steps: int = 1) -> Callable:
    def loss_fn(params, batch):
        loss, metrics = api.loss(params, batch)
        return loss, metrics

    def train_step(state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"], batch)
        else:
            # gradient accumulation over microbatches (leading split)
            def micro(carry, mb):
                acc, ltot = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state["params"], mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, ltot + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            mbs = jax.tree.map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch)
            (grads, ltot), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = ltot / accum_steps
            metrics = {}
        params, opt, om = adamw_update(state["params"], grads,
                                       state["opt"], opt_cfg)
        metrics = dict(metrics, loss=loss, **om)
        return {"params": params, "opt": opt}, metrics

    return train_step


def make_prefill_step(api: ModelApi, pad_to: int | None = None) -> Callable:
    def prefill_step(params, batch):
        logits, cache, pos = api.prefill(params, batch, pad_to=pad_to)
        return logits, cache

    return prefill_step


def make_serve_step(api: ModelApi, greedy: bool = True) -> Callable:
    def serve_step(params, cache, token, pos):
        logits, cache = api.decode(params, cache, token, pos)
        if greedy:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt[:, None], cache
        return logits, cache

    return serve_step
