"""jit-ready wrappers around the flash-attention kernel.

``flash_attention`` pads/permutes (B,S,H,D) inputs to the kernel's
MXU-aligned (B,H,S,D) layout and runs the Pallas kernel
(``interpret=True`` on CPU — this container has no TPU).

``flash_attention_auto`` is what the model layer calls: it dispatches on
``cfg.attn_impl`` between the Pallas kernel and the memory-equivalent
chunked-jnp path used for dry-run lowering (roofline numbers then
reflect flash-style blocking, not an S^2 score tensor).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = size % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = True):
    """q: (B, Sq, H, dh); k/v: (B, Skv, KV, dh) -> (B, Sq, H, dh)."""
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, max(8, 1 << (Sq - 1).bit_length()))
    bkv = min(block_kv, max(8, 1 << (Skv - 1).bit_length()))
    qt = _pad_to(_pad_to(q.transpose(0, 2, 1, 3), 2, bq), 3, 128)
    kt = _pad_to(_pad_to(k.transpose(0, 2, 1, 3), 2, bkv), 3, 128)
    vt = _pad_to(_pad_to(v.transpose(0, 2, 1, 3), 2, bkv), 3, 128)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               real_dh=dh, seq_q=Sq, seq_kv=Skv,
                               block_q=bq, block_kv=bkv, interpret=interpret)
    return out[:, :, :Sq, :dh].transpose(0, 2, 1, 3)


def flash_attention_auto(q, k, v, *, causal, window, cfg):
    """Model-layer dispatch: Pallas on TPU-ish configs, chunked-jnp
    otherwise (the dry-run path)."""
    if cfg.attn_impl == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=jax.default_backend() != "tpu")
    from ...models.layers import chunked_attention  # lazy: avoid cycle
    return chunked_attention(q, k, v, causal=causal, window=window,
                             chunk=min(cfg.attn_chunk, k.shape[1]),
                             unroll=cfg.unroll_scans,
                             shard_constrain=cfg.attn_shard_constraints,
                             accum_bf16=cfg.attn_accum_bf16)
