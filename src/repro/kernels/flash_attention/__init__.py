from .ops import flash_attention, flash_attention_auto

__all__ = ["flash_attention", "flash_attention_auto"]
