"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """q: (B, Sq, H, dh); k/v: (B, Skv, KV, dh).  fp32 softmax."""
    B, Sq, H, dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(dh)
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, dh).astype(q.dtype)
