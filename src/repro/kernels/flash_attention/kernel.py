"""Flash-attention forward Pallas kernel (TPU target).

Online-softmax attention streamed over KV blocks: never materializes
the (Sq, Skv) score matrix in HBM.  TPU-native blocking: the grid's two
outer dims are embarrassingly parallel (batch, head); the inner dims
walk query blocks and — sequentially, so VMEM scratch carries the
running (m, l, acc) statistics — KV blocks.  Block shapes are
MXU-aligned (multiples of 128 on the contracted dims).

Supports GQA (query-head -> kv-head mapping via the index map), causal
masking, and sliding windows (Mistral/Danube SWA).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int | None,
                 block_q: int, block_kv: int, seq_q: int, seq_kv: int,
                 n_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)              # (bkv, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32)  # (bq, bkv)

    q_pos = iq * block_q + lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_kv), 0)
    k_pos = ik * block_kv + lax.broadcasted_iota(jnp.int32,
                                                 (block_q, block_kv), 1)
    mask = (k_pos < seq_kv) & (q_pos < seq_q)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]                             # (bq,)
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
    l_scr[:, 0] = l_scr[:, 0] * corr + p.sum(axis=1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32))
    m_scr[:, 0] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[:, 0], 1e-20)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool, window: int | None,
                         real_dh: int, seq_q: int, seq_kv: int,
                         block_q: int = 128, block_kv: int = 128,
                         interpret: bool = True):
    """q: (B, H, Sq, dh); k/v: (B, KV, Skv, dh) — pre-padded so that
    Sq % block_q == Skv % block_kv == 0 and dh is lane-aligned.
    ``seq_q``/``seq_kv`` are the *unpadded* lengths used for masking."""
    B, H, Sq, dh = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    n_q = Sq // block_q
    n_kv = Skv // block_kv
    grid = (B, H, n_q, n_kv)

    kernel = functools.partial(
        _attn_kernel, scale=1.0 / math.sqrt(real_dh), causal=causal,
        window=window, block_q=block_q, block_kv=block_kv,
        seq_q=seq_q, seq_kv=seq_kv, n_kv_blocks=n_kv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, dh),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, dh),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
