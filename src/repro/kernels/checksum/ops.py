"""Device-array checksums for checkpoint integrity (paper §7)."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .kernel import BLOCK_WORDS, LANES, ROWS, checksum_lanes

MOD = 1 << 32


def _as_words(x) -> jnp.ndarray:
    """Bit-cast any array to a flat int32 word stream (zero-pad tail)."""
    flat = jnp.ravel(x)
    nbytes = flat.size * flat.dtype.itemsize
    b8 = jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(nbytes)
    pad = (-nbytes) % 4
    if pad:
        b8 = jnp.pad(b8, (0, pad))
    w = b8.reshape(-1, 4).astype(jnp.uint32)
    word = (w[:, 0] | (w[:, 1] << 8) | (w[:, 2] << 16) | (w[:, 3] << 24))
    return word.astype(jnp.int32)


def checksum_array(x, use_pallas: bool = True) -> tuple[int, int]:
    """Lanesum32 (a, b) of an on-device array's little-endian bytes."""
    words = _as_words(x)
    n = words.size
    pad = (-n) % BLOCK_WORDS
    if pad:
        words = jnp.pad(words, (0, pad))
    if use_pallas:
        blocks = words.reshape(-1, ROWS, LANES)
        a_l, b_l = checksum_lanes(blocks)
        a = int(np.asarray(a_l, dtype=np.int64).astype(np.uint32)
                .astype(np.uint64).sum() % MOD)
        b = int(np.asarray(b_l, dtype=np.int64).astype(np.uint32)
                .astype(np.uint64).sum() % MOD)
        return a, b
    from .ref import jnp_lanesum32
    a, b = jnp_lanesum32(words)
    return int(a), int(b)


def checksum_digest(x, use_pallas: bool = True) -> str:
    a, b = checksum_array(x, use_pallas=use_pallas)
    return f"{b:08x}{a:08x}"
