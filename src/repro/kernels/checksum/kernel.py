"""Blocked lane checksum Pallas kernel ("lanesum32").

The paper's §7 integrity check re-reads data and computes checksums on
the DTN's CPUs.  On a TPU pod the *source-side* checksum of a checkpoint
shard can be computed on-device before D2H, removing the host hash from
the critical path.  Fletcher-style sequential checksums don't map to the
VPU, so we adapt (DESIGN.md §5): the data is viewed as uint32 words laid
out across the 8x128 VPU lanes; each grid step accumulates

    a += w                  (plain sum,   mod 2^32 by int32 wraparound)
    b += (i+1) * w          (index-weighted sum, order-sensitive)

into per-lane int32 accumulators; a final host fold reduces the 8x128
lanes to the 64-bit digest.  Deterministic for a fixed array shape and
sensitive to both corruption and reordering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROWS, LANES = 8, 128
BLOCK_WORDS = ROWS * LANES


def _checksum_kernel(w_ref, a_out, b_out, a_scr, b_scr, *, n_blocks: int):
    ib = pl.program_id(0)

    @pl.when(ib == 0)
    def _init():
        a_scr[...] = jnp.zeros_like(a_scr)
        b_scr[...] = jnp.zeros_like(b_scr)

    w = w_ref[0]  # (ROWS, LANES) int32
    base = ib * BLOCK_WORDS
    idx = (base + 1
           + lax.broadcasted_iota(jnp.int32, (ROWS, LANES), 0) * LANES
           + lax.broadcasted_iota(jnp.int32, (ROWS, LANES), 1))
    a_scr[...] = a_scr[...] + w
    b_scr[...] = b_scr[...] + w * idx  # int32 wraparound == mod 2^32

    @pl.when(ib == n_blocks - 1)
    def _fin():
        a_out[0] = a_scr[...]
        b_out[0] = b_scr[...]


def checksum_lanes(words):
    """words: (n_blocks, ROWS, LANES) int32 -> (a_lanes, b_lanes) each
    (ROWS, LANES) int32."""
    n_blocks = words.shape[0]
    kernel = functools.partial(_checksum_kernel, n_blocks=n_blocks)
    a, b = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, ROWS, LANES), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, ROWS, LANES), lambda i: (0, 0, 0)),
                   pl.BlockSpec((1, ROWS, LANES), lambda i: (0, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, ROWS, LANES), jnp.int32),
                   jax.ShapeDtypeStruct((1, ROWS, LANES), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((ROWS, LANES), jnp.int32),
                        pltpu.VMEM((ROWS, LANES), jnp.int32)],
        interpret=True,
    )(words)
    return a[0], b[0]
