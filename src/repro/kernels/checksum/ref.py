"""Pure-jnp / numpy oracle for the lanesum32 checksum."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

MOD = 1 << 32


def lanesum32_ref(words) -> tuple[int, int]:
    """words: 1-D int32/uint32 array.  Returns (a, b) ints mod 2^32."""
    w = np.asarray(words).astype(np.uint64) & 0xFFFFFFFF
    idx = (np.arange(1, w.size + 1, dtype=np.uint64)) & 0xFFFFFFFF
    a = int(w.sum() % MOD)
    b = int((w * idx % MOD).sum() % MOD)
    return a, b


def digest_ref(data: bytes) -> str:
    """Byte-stream variant (little-endian words, zero-padded tail)."""
    pad = (-len(data)) % 4
    w = np.frombuffer(data + b"\0" * pad, dtype="<u4")
    a, b = lanesum32_ref(w)
    return f"{b:08x}{a:08x}"


def jnp_lanesum32(words):
    """jnp version used when the Pallas path is off.  Relies on int32
    two's-complement wraparound (== arithmetic mod 2^32), same as the
    kernel."""
    w = words.astype(jnp.int32)
    idx = jnp.arange(w.size, dtype=jnp.int32) + 1
    a = jnp.sum(w)                # wraps mod 2^32
    b = jnp.sum(w * idx)
    to_u32 = lambda v: int(np.asarray(v, dtype=np.int64) & 0xFFFFFFFF)
    return to_u32(a), to_u32(b)
