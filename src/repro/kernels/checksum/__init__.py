from .ops import checksum_array, checksum_digest

__all__ = ["checksum_array", "checksum_digest"]
