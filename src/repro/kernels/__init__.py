# Pallas TPU kernels for the framework's compute hot-spots, each with a
# jit'd wrapper (ops.py) and a pure-jnp oracle (ref.py); validated in
# interpret mode on CPU:
#   flash_attention/ — online-softmax GQA attention (causal / SWA)
#   ssm_scan/        — chunked gated linear recurrence (mamba2 / rwkv6)
#   checksum/        — lanesum32 integrity checksum (paper §7, on-device)
