"""jit wrapper for the chunked gated-linear-recurrence kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssm_scan_bthk


@functools.partial(jax.jit,
                   static_argnames=("chunk", "subchunk", "interpret",
                                    "has_u"))
def _ssm_scan_impl(q, k, v, g, u, s0, *, chunk, subchunk, interpret, has_u):
    return ssm_scan_bthk(q, k, v, g, u if has_u else None, s0,
                         chunk=chunk, subchunk=subchunk, interpret=interpret)


def ssm_scan(q, k, v, log_decay, u=None, initial_state=None, *,
             chunk: int = 128, subchunk: int = 16, interpret: bool = True):
    """Public op.  Shapes as in repro.models.ssm.ssm_scan_ref.
    Pads T up to a chunk multiple (decay 0 / k 0 padding is inert)."""
    B, T, H, K = q.shape
    V = v.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, K, V), jnp.float32)
    pad = (-T) % chunk
    if pad:
        pad_cfg = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(x, pad_cfg) for x in (q, k, v))
        log_decay = jnp.pad(log_decay, pad_cfg)
    u_arg = u if u is not None else jnp.zeros((H, K), jnp.float32)
    y, s_fin = _ssm_scan_impl(q, k, v, log_decay, u_arg, initial_state,
                              chunk=chunk, subchunk=min(subchunk, chunk),
                              interpret=interpret, has_u=u is not None)
    return y[:, :T], s_fin
