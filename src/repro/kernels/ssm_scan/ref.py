"""Pure-jnp oracle for the ssm_scan kernel: the exact step recurrence.

(Re-exported from repro.models.ssm so the kernel test oracle and the
model reference are literally the same code.)
"""

from ...models.ssm import ssm_scan_ref as ssm_scan_ref  # noqa: F401
from ...models.ssm import ssm_scan_chunked as ssm_scan_chunked  # noqa: F401
