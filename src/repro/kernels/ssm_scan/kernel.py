"""Chunked gated-linear-recurrence Pallas kernel (TPU target).

Implements the chunk-parallel form of

    S_t = diag(exp(g_t)) S_{t-1} + k_t^T v_t ;  y_t = q_t S_t  (+ rwkv6
    u-bonus variant reading S_{t-1})

for one (batch, head) per outer grid cell.  The chunk axis is the
innermost grid dim and runs sequentially: the (K, V) state lives in VMEM
scratch across chunks (this is how the TPU replaces the GPU's
inter-block shared-memory handoff).  Within a chunk, sub-chunks of R=16
turn the recurrence into MXU matmuls with all exponents <= 0
(numerically safe — see repro/models/ssm.py for the derivation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(q_ref, k_ref, v_ref, g_ref, u_ref, s0_ref, y_ref, sfin_ref,
                s_scr, *, chunk: int, subchunk: int, n_chunks: int,
                use_u: bool):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    L, R = chunk, subchunk
    NS = L // R
    q = q_ref[0, :, 0].astype(jnp.float32)   # (L, K)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)   # (L, V)
    g = g_ref[0, :, 0].astype(jnp.float32)   # (L, K)
    z = jnp.cumsum(g, axis=0)
    zq = z - g if use_u else z
    u = u_ref[0].astype(jnp.float32) if use_u else None  # (K,)

    mask = lax.broadcasted_iota(jnp.int32, (R, R), 0) >= \
        lax.broadcasted_iota(jnp.int32, (R, R), 1) + (1 if use_u else 0)
    S = s_scr[...]
    for s in range(NS):
        sl = slice(s * R, (s + 1) * R)
        qs, ks, vs = q[sl], k[sl], v[sl]
        zs, zqs = z[sl], zq[sl]
        z_start = z[s * R - 1] if s > 0 else jnp.zeros_like(z[0])
        z_end = z[(s + 1) * R - 1]
        # inter-chunk: state contribution
        q_dec = qs * jnp.exp(zqs - z_start[None, :])
        y = lax.dot_general(q_dec, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (R, V)
        # intra: pairwise within sub-chunk — (R, R, K) broadcast
        Ez = jnp.exp(zqs[:, None, :] - zs[None, :, :])
        A = jnp.sum(qs[:, None, :] * ks[None, :, :] * Ez, axis=-1)
        A = jnp.where(mask, A, 0.0)
        y = y + lax.dot_general(A, vs, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if use_u:
            bonus = jnp.sum(qs * u[None, :] * ks, axis=-1)   # (R,)
            y = y + bonus[:, None] * vs
        y_ref[0, sl, 0] = y.astype(y_ref.dtype)
        # carry state
        k_dec = ks * jnp.exp(z_end[None, :] - zs)
        S = (jnp.exp(z_end - z_start)[:, None] * S
             + lax.dot_general(k_dec, vs, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32))
    s_scr[...] = S

    @pl.when(ic == n_chunks - 1)
    def _fin():
        sfin_ref[0, 0] = s_scr[...]


def ssm_scan_bthk(q, k, v, g, u, s0, *, chunk: int = 128, subchunk: int = 16,
                  interpret: bool = True):
    """q,k,g: (B, T, H, K); v: (B, T, H, V); u: (H, K); s0: (B, H, K, V).
    T must divide by ``chunk``.  Returns (y: (B,T,H,V), s_fin (B,H,K,V))."""
    B, T, H, K = q.shape
    V = v.shape[-1]
    use_u = u is not None
    if u is None:
        u = jnp.zeros((H, K), jnp.float32)
    NC = T // chunk
    grid = (B, H, NC)

    kernel = functools.partial(_ssm_kernel, chunk=chunk, subchunk=subchunk,
                               n_chunks=NC, use_u=use_u)
    seq_spec = lambda b, h, ic: (b, ic, h, 0)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, K), seq_spec),
            pl.BlockSpec((1, chunk, 1, K), seq_spec),
            pl.BlockSpec((1, chunk, 1, V), seq_spec),
            pl.BlockSpec((1, chunk, 1, K), seq_spec),
            pl.BlockSpec((1, K), lambda b, h, ic: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, V), seq_spec),
            pl.BlockSpec((1, 1, K, V), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, V), q.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(q, k, v, g, u, s0)
