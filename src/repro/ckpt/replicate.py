"""Third-party checkpoint replication (paper §2.1 + §6.5).

After a checkpoint lands on the cluster connector, the managed transfer
service replicates it to a second storage system (e.g. an emulated
cloud object store) WITHOUT the training job in the data path — the
paper's third-party transfer, applied to checkpoint durability.

Concurrency/placement come from the fitted performance model (§5): the
Advisor predicts transfer time per route and picks the best, instead of
exhaustively benchmarking.
"""

from __future__ import annotations

from ..core.perfmodel import Advisor
from ..core.transfer import (Endpoint, TransferOptions, TransferService,
                             TransferTask)


def replicate_checkpoint(service: TransferService, src: Endpoint,
                         dst: Endpoint, step: int,
                         advisor: Advisor | None = None,
                         n_objects_hint: int = 64,
                         bytes_hint: int = 1 << 30,
                         integrity: bool = True,
                         sync: bool = False) -> TransferTask:
    options = TransferOptions(integrity=integrity,
                              checksum_algorithm="lanesum32")
    if advisor is not None and advisor.routes:
        _, cc, predicted = advisor.best(n_objects_hint, bytes_hint)
        options.concurrency = cc
    src_ep = Endpoint(src.connector, f"{src.path}/step_{step}",
                      src.endpoint_id)
    dst_ep = Endpoint(dst.connector, f"{dst.path}/step_{step}",
                      dst.endpoint_id)
    return service.submit(src_ep, dst_ep, options, sync=sync)
