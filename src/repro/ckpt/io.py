"""Byte-level helpers over the Connector interface.

The checkpoint layer and data pipeline talk to storage exclusively
through Connector Send/Recv (paper §3) — these helpers adapt in-memory
buffers to the AppChannel protocol.
"""

from __future__ import annotations

import threading

from ..core.connector import AppChannel, ByteRange, Connector, Session


class BytesSource(AppChannel):
    """Feeds Recv (upload) from an in-memory buffer."""

    def __init__(self, payload: bytes, blocksize: int = 1 << 22,
                 concurrency: int = 2):
        self.payload = payload
        self.bs = blocksize
        self.cc = concurrency
        self._claim = 0
        self._lock = threading.Lock()
        self.bytes_done = 0

    def write(self, offset, data):
        raise NotImplementedError

    def read(self, offset, length):
        return self.payload[offset:offset + length]

    def get_concurrency(self):
        return self.cc

    def get_blocksize(self):
        return self.bs

    def get_read_range(self):
        with self._lock:
            if self._claim >= len(self.payload):
                return None
            ln = min(self.bs, len(self.payload) - self._claim)
            rng = ByteRange(self._claim, ln)
            self._claim += ln
            return rng

    def bytes_written(self, offset, length):
        with self._lock:
            self.bytes_done += length

    def finished(self, error=None):
        pass


class BytesSink(AppChannel):
    """Collects Send (download) output, optionally a sub-range."""

    def __init__(self, blocksize: int = 1 << 22, concurrency: int = 2,
                 offset: int = 0, length: int | None = None):
        self.bs = blocksize
        self.cc = concurrency
        self._start = offset
        self._want = length
        self._claim = offset
        self._size = None
        self._blocks: dict[int, bytes] = {}
        self._lock = threading.Lock()

    def set_size(self, size):
        self._size = size

    def _end(self):
        if self._want is None:
            return self._size if self._size is not None else float("inf")
        return self._start + self._want

    def write(self, offset, data):
        with self._lock:
            self._blocks[offset] = data

    def read(self, offset, length):
        raise NotImplementedError

    def get_concurrency(self):
        return self.cc

    def get_blocksize(self):
        return self.bs

    def get_read_range(self):
        with self._lock:
            end = self._end()
            if self._claim >= end:
                return None
            ln = int(min(self.bs, end - self._claim))
            rng = ByteRange(self._claim, ln)
            self._claim += ln
            return rng

    def bytes_written(self, offset, length):
        pass

    def finished(self, error=None):
        self.error = error

    def data(self) -> bytes:
        out = b"".join(self._blocks[o] for o in sorted(self._blocks))
        if self._want is not None:
            out = out[:self._want]
        return out


def put_bytes(connector: Connector, session: Session, path: str,
              payload: bytes, concurrency: int = 2) -> None:
    connector.recv(session, path, BytesSource(payload,
                                              concurrency=concurrency))


def get_bytes(connector: Connector, session: Session, path: str,
              offset: int = 0, length: int | None = None,
              concurrency: int = 2) -> bytes:
    sink = BytesSink(offset=offset, length=length, concurrency=concurrency)
    connector.send(session, path, sink)
    return sink.data()


def delete_path(connector: Connector, session: Session, path: str) -> None:
    connector.command(session, "delete", path)
