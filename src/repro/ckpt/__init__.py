from .io import get_bytes, put_bytes, delete_path
from .checkpoint import CheckpointManager, save_checkpoint, restore_checkpoint
from .replicate import replicate_checkpoint

__all__ = ["get_bytes", "put_bytes", "delete_path", "CheckpointManager",
           "save_checkpoint", "restore_checkpoint", "replicate_checkpoint"]
