"""Distributed checkpointing through the Connector interface.

Design (DESIGN.md §2):

* every pytree leaf becomes one object — except small leaves, which are
  *coalesced* into bundle objects.  The bundle threshold comes straight
  from the paper's performance model: per-file overhead ``t0`` makes
  many-small-files transfers slow (paper §5), so we keep
  ``N * t0 << B / R`` by construction.
* a ``manifest.json`` records the tree structure, shapes, dtypes and a
  per-object **lanesum32 checksum** computed on-device by the Pallas
  checksum kernel (paper §7 strong integrity, source side).
* restore verifies each object's checksum before installing it
  (destination side of §7), and is *mesh-independent*: arrays are
  re-sharded to whatever mesh the restoring job uses (elastic restart).
* saves are atomic: objects land under ``<step>.tmp/`` and the manifest
  write is the commit point, then the directory is renamed.
"""

from __future__ import annotations

import json
import threading

import numpy as np

import jax

from ..core.connector import Connector, Credential, Session
from ..core.errors import IntegrityError, NotFound
from ..kernels.checksum.ref import digest_ref
from .io import get_bytes, put_bytes

MB = 1024 * 1024


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for keypath, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", "")))
                        for k in keypath)
        out[path] = leaf
    return out


def _leaf_bytes(leaf) -> bytes:
    arr = np.asarray(jax.device_get(leaf))
    return arr.tobytes()


def _digest(leaf) -> str:
    try:
        from ..kernels.checksum.ops import checksum_digest
        return checksum_digest(leaf, use_pallas=False)  # jnp path is fast
    except Exception:
        return digest_ref(_leaf_bytes(leaf))


def save_checkpoint(state, connector: Connector, base: str, step: int,
                    credential: Credential | None = None,
                    bundle_threshold: int = 4 * MB,
                    verify: bool = True) -> dict:
    """Writes ``state`` under ``base/step_<n>/``.  Returns the manifest."""
    leaves = _flatten(state)
    session = connector.start(credential)
    tmp = f"{base}/step_{step}.tmp"
    final = f"{base}/step_{step}"
    manifest = {"step": step, "objects": {}, "bundles": {},
                "checksum_algorithm": "lanesum32"}
    try:
        bundle: list[tuple[str, bytes, str, list, str]] = []
        bundle_size = 0
        bundle_idx = 0

        def flush_bundle():
            nonlocal bundle, bundle_size, bundle_idx
            if not bundle:
                return
            name = f"bundle_{bundle_idx}.bin"
            blob = b"".join(b for _, b, _, _, _ in bundle)
            put_bytes(connector, session, f"{tmp}/{name}", blob)
            off = 0
            for path, data, dig, shape, dtype in bundle:
                manifest["bundles"][path] = {
                    "object": name, "offset": off, "length": len(data),
                    "checksum": dig, "shape": shape, "dtype": dtype,
                }
                off += len(data)
            bundle_idx += 1
            bundle = []
            bundle_size = 0

        for path, leaf in sorted(leaves.items()):
            data = _leaf_bytes(leaf)
            dig = digest_ref(data)
            shape = list(np.asarray(jax.device_get(leaf)).shape)
            dtype = str(np.asarray(jax.device_get(leaf)).dtype)
            if len(data) < bundle_threshold:
                bundle.append((path, data, dig, shape, dtype))
                bundle_size += len(data)
                if bundle_size >= 8 * bundle_threshold:
                    flush_bundle()
                continue
            obj = f"{tmp}/{path.replace('/', '.')}.bin"
            put_bytes(connector, session, obj, data)
            manifest["objects"][path] = {
                "object": f"{path.replace('/', '.')}.bin",
                "checksum": dig, "shape": shape, "dtype": dtype,
            }
        flush_bundle()

        if verify:  # §7: re-read from storage and compare checksums
            for path, meta in manifest["objects"].items():
                got = get_bytes(connector, session, f"{tmp}/{meta['object']}")
                if digest_ref(got) != meta["checksum"]:
                    raise IntegrityError(f"post-write verify failed: {path}")

        put_bytes(connector, session, f"{tmp}/manifest.json",
                  json.dumps(manifest).encode())
        connector.command(session, "rename", tmp, to=final)
        # update the "latest" pointer last (atomic-ish commit marker)
        put_bytes(connector, session, f"{base}/LATEST",
                  str(step).encode())
        return manifest
    finally:
        connector.destroy(session)


def latest_step(connector: Connector, base: str,
                credential: Credential | None = None) -> int | None:
    session = connector.start(credential)
    try:
        try:
            return int(get_bytes(connector, session, f"{base}/LATEST"))
        except NotFound:
            return None
    finally:
        connector.destroy(session)


def restore_checkpoint(abstract_state, connector: Connector, base: str,
                       step: int | None = None,
                       credential: Credential | None = None,
                       shardings=None, verify: bool = True):
    """Restores into the structure of ``abstract_state``; if
    ``shardings`` (a matching pytree of NamedSharding) is given, arrays
    are placed sharded — on a *possibly different* mesh than the saver's
    (elastic restart)."""
    session = connector.start(credential)
    try:
        if step is None:
            step = int(get_bytes(connector, session, f"{base}/LATEST"))
        root = f"{base}/step_{step}"
        manifest = json.loads(get_bytes(connector, session,
                                        f"{root}/manifest.json"))
        bundles_cache: dict[str, bytes] = {}

        def load(path: str) -> np.ndarray:
            if path in manifest["objects"]:
                meta = manifest["objects"][path]
                data = get_bytes(connector, session,
                                 f"{root}/{meta['object']}")
            elif path in manifest["bundles"]:
                meta = manifest["bundles"][path]
                obj = meta["object"]
                if obj not in bundles_cache:
                    bundles_cache[obj] = get_bytes(connector, session,
                                                   f"{root}/{obj}")
                data = bundles_cache[obj][meta["offset"]:
                                          meta["offset"] + meta["length"]]
            else:
                raise NotFound(f"checkpoint object for {path}")
            if verify and digest_ref(data) != meta["checksum"]:
                raise IntegrityError(f"checksum mismatch restoring {path}")
            return np.frombuffer(data, dtype=meta["dtype"]) \
                .reshape(meta["shape"])

        leaves = _flatten(abstract_state)
        sh_leaves = _flatten(shardings) if shardings is not None else {}
        restored = {}
        for path, spec in leaves.items():
            arr = load(path)
            if sh_leaves.get(path) is not None:
                arr = jax.device_put(arr, sh_leaves[path])
            restored[path] = arr

        flat = jax.tree_util.tree_flatten_with_path(abstract_state)
        treedef = jax.tree_util.tree_structure(abstract_state)
        ordered = []
        for keypath, _ in flat[0]:
            path = "/".join(str(getattr(k, "key", getattr(k, "idx", "")))
                            for k in keypath)
            ordered.append(restored[path])
        return jax.tree_util.tree_unflatten(treedef, ordered), step
    finally:
        connector.destroy(session)


class CheckpointManager:
    """Async, double-buffered checkpointing for the train loop.

    ``save_async`` snapshots to host (blocking only for D2H), then a
    background thread streams objects through the Connector —
    fire-and-forget, same as the paper's managed transfers.  ``retain``
    old checkpoints are garbage-collected.
    """

    def __init__(self, connector: Connector, base: str,
                 credential: Credential | None = None, retain: int = 3):
        self.connector = connector
        self.base = base
        self.credential = credential
        self.retain = retain
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None
        self._saved_steps: list[int] = []

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, state, step: int):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def work():
            try:
                save_checkpoint(host_state, self.connector, self.base, step,
                                credential=self.credential)
                self._saved_steps.append(step)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        while len(self._saved_steps) > self.retain:
            victim = self._saved_steps.pop(0)
            session = self.connector.start(self.credential)
            try:
                self.connector.command(session, "delete",
                                       f"{self.base}/step_{victim}")
            except NotFound:
                pass
            finally:
                self.connector.destroy(session)

    def restore_latest(self, abstract_state, shardings=None):
        step = latest_step(self.connector, self.base, self.credential)
        if step is None:
            return None, None
        return restore_checkpoint(abstract_state, self.connector, self.base,
                                  step, credential=self.credential,
                                  shardings=shardings)
