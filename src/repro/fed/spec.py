"""Serializable transfer submission spec — the unit of federation.

The paper's third-party model (§2.1) works because a transfer is fully
described by *references*: endpoints, paths, options, identity — never
file bytes, never live connector state.  :class:`TransferSpec` makes
that description a first-class, JSON-round-trippable value, so a task
can move between control planes: a client submits one to a
:class:`~repro.fed.coordinator.FederatedCoordinator`, a site manager
adopts it via :meth:`~repro.core.manager.TransferManager.import_state`,
and an overloaded or failed site re-serializes it (hole map and
per-range digests riding along in ``markers``) for a peer to resume
re-sending only the missing bytes.

Connectors themselves cannot travel; endpoints are referenced by id and
each site resolves them against its own endpoint-ownership map.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field, fields

from ..core.transfer import TransferOptions

#: lifecycle states a spec can be serialized in.  "queued" has no
#: partial progress; "paused" travels with its hole map (markers);
#: "cancelled" is terminal and only registered on arrival.
SPEC_STATES = ("queued", "paused", "cancelled")


@dataclass
class TransferSpec:
    """One submission, fully described by value (JSON-clean).

    ``markers`` is the traveled restart state — exactly what
    :class:`~repro.core.transfer.MarkerStore` persists: per-file
    completed ranges, per-range digests, and recorded checksums — so a
    paused task's holes (and its §7 checksum fold) survive the hop.
    ``stats`` carries the charge-accounted model seconds and resume
    count accrued on previous sites, keeping attribution exact.
    """

    task_id: str
    src_endpoint: str
    src_path: str
    dst_endpoint: str
    dst_path: str
    tenant: str = ""
    priority: int = 0
    state: str = "queued"
    options: dict = field(default_factory=dict)
    #: advisor hints: route name + workload estimate, so placement can
    #: predict without walking the source tree
    route: str = ""
    n_files: int = 0
    nbytes: int = 0
    origin_site: str = ""
    #: observability: the task's trace id travels with the spec, so a
    #: handed-off task's spans on the adopting site stitch into the
    #: same timeline as the spans it accrued at the origin
    trace_id: str = ""
    stats: dict = field(default_factory=dict)
    markers: dict = field(default_factory=lambda: {"files": {}})
    #: replica hints: JSON-clean catalog entry dicts naming verified
    #: copies of the source that already exist (see
    #: :mod:`repro.catalog`) — the adopting site merges and
    #: re-validates them, so a handed-off fan-out member can still be
    #: served by a replica read instead of a source read
    replicas: list = field(default_factory=list)
    version: int = 1

    # ---- construction ----------------------------------------------------
    @classmethod
    def new(cls, task_id: str, src_endpoint: str, src_path: str,
            dst_endpoint: str, dst_path: str, *, tenant: str = "",
            priority: int = 0,
            options: TransferOptions | dict | None = None,
            route: str = "", n_files: int = 0, nbytes: int = 0,
            origin_site: str = "") -> "TransferSpec":
        """Build a fresh (queued, no-progress) submission spec."""
        if isinstance(options, TransferOptions):
            options = asdict(options)
        return cls(task_id=task_id, src_endpoint=src_endpoint,
                   src_path=src_path, dst_endpoint=dst_endpoint,
                   dst_path=dst_path, tenant=tenant, priority=priority,
                   options=dict(options or {}), route=route,
                   n_files=n_files, nbytes=nbytes, origin_site=origin_site)

    def validate(self) -> None:
        if not self.task_id:
            raise ValueError("spec needs a task_id")
        if not self.src_endpoint or not self.dst_endpoint:
            raise ValueError("spec needs src and dst endpoint ids")
        if self.state not in SPEC_STATES:
            raise ValueError(f"unknown spec state {self.state!r} "
                             f"(expected one of {SPEC_STATES})")
        if not isinstance(self.markers, dict) \
                or not isinstance(self.markers.get("files", None), dict):
            raise ValueError("markers must be a {'files': {...}} mapping")

    # ---- manager payload shape ------------------------------------------
    def to_payload(self) -> dict:
        """The dict shape
        :meth:`~repro.core.manager.TransferManager.import_state`
        consumes (and :meth:`export_state` produces)."""
        return {
            "version": self.version,
            "task_id": self.task_id,
            "state": self.state,
            "tenant": self.tenant,
            "priority": self.priority,
            "origin_site": self.origin_site,
            "trace_id": self.trace_id,
            "src": {"endpoint_id": self.src_endpoint,
                    "path": self.src_path},
            "dst": {"endpoint_id": self.dst_endpoint,
                    "path": self.dst_path},
            "options": dict(self.options),
            "route": self.route,
            "n_files": self.n_files,
            "nbytes": self.nbytes,
            "stats": dict(self.stats),
            "markers": self.markers,
            "replicas": list(self.replicas),
        }

    @classmethod
    def from_payload(cls, payload: dict) -> "TransferSpec":
        spec = cls(
            task_id=payload["task_id"],
            src_endpoint=payload["src"]["endpoint_id"],
            src_path=payload["src"]["path"],
            dst_endpoint=payload["dst"]["endpoint_id"],
            dst_path=payload["dst"]["path"],
            tenant=payload.get("tenant", ""),
            priority=payload.get("priority", 0),
            state=payload.get("state", "queued"),
            options=dict(payload.get("options", {})),
            route=payload.get("route", ""),
            n_files=payload.get("n_files", 0),
            nbytes=payload.get("nbytes", 0),
            origin_site=payload.get("origin_site", ""),
            trace_id=payload.get("trace_id", ""),
            stats=dict(payload.get("stats", {})),
            markers=payload.get("markers") or {"files": {}},
            replicas=list(payload.get("replicas", []) or []),
            version=payload.get("version", 1),
        )
        spec.validate()
        return spec

    # ---- JSON travel -----------------------------------------------------
    def to_json(self) -> str:
        """Canonical wire form (sorted keys: byte-stable for a given
        spec, so digests/logs of specs are comparable)."""
        self.validate()
        return json.dumps(asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, data: str) -> "TransferSpec":
        raw = json.loads(data)
        known = {f.name for f in fields(cls)}
        spec = cls(**{k: v for k, v in raw.items() if k in known})
        spec.validate()
        return spec

    # ---- introspection ---------------------------------------------------
    def pending_bytes(self) -> int | None:
        """Bytes a resume would still have to move — the workload hint
        minus what the traveled hole maps say already landed.  ``None``
        when the spec carries no ``nbytes`` hint."""
        if not self.nbytes:
            return None
        return max(0, self.nbytes - self.done_bytes())

    def done_bytes(self) -> int:
        """Bytes the traveled markers say already landed (complete files
        count only when the spec knows per-file sizes via ``done``)."""
        total = 0
        for st in self.markers.get("files", {}).values():
            total += sum(ln for _, ln in st.get("done", []))
        return total
