"""Federated multi-site control plane (paper §2.1, scaled to a fleet of
control planes).

The paper's orchestrator coordinates transfers between sites *without
sitting in the data path*.  :class:`FederatedCoordinator` reproduces
that one level up: it registers N :class:`~repro.core.manager.
TransferManager` sites with endpoint-ownership maps, routes each
serialized :class:`~repro.fed.spec.TransferSpec` to a site by a
pluggable placement policy, exchanges periodic queue-state digests, and
supports **handoff** — re-serializing a queued or paused task from an
overloaded or failed site and resuming it on a peer, the traveled hole
map guaranteeing only the missing bytes are re-sent.

Third-party semantics are enforced by the charge-attribution clock
(:mod:`repro.core.clock`): every coordinator entry point runs with the
coordinator as the thread's charge owner, so any model time it accrued
would be tallied against it — :meth:`model_seconds` must therefore read
0.0, and :meth:`assert_third_party` turns that into a hard invariant.
Data-plane time lands on worker threads that re-bind the charge owner
to the task, so cross-site stats stay attributed to the originating
tenant and task, never to the coordinator.  The coordinator's own
drain/settle polls advance the *model* clock (never ``time.monotonic``)
under a sibling ``#wait`` identity, so deadlines are wall-clock-free
and the invariant still reads 0.0 (see :meth:`wait_seconds`).  The one
deliberate exception is the caller-facing ``wait_all(timeout=)`` bound:
model time never advances while every site idles, so a model deadline
could never fire there — that timeout runs on the sanctioned
:func:`~repro.core.clock.wall_now` helper (which charges nothing, so
the third-party invariant is untouched).

Health plane (heartbeats + hysteresis rebalancing)
--------------------------------------------------
The existing digest exchange doubles as a **heartbeat** carrier: a site
whose ``digest()`` call raises has missed a beat, and
:meth:`FederatedCoordinator.beat` auto-triggers the :meth:`fail_site`
re-homing path once ``miss_threshold`` consecutive beats are missed —
no caller intervention.  A :class:`RebalancePolicy` adds a sustained-
saturation signal with hysteresis (enter/exit thresholds + a minimum
dwell time over the model clock, plus a per-task move cooldown, so
specs don't ping-pong) that proactively migrates *queued* specs off
degrading sites through the same ``export_state``/``import_state``
handoff the failure path uses.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field

from ..catalog import hint_bytes
from ..core.clock import charge_to, wall_now
from ..core.connector import Connector
from ..core.perfmodel import Advisor
from ..core.transfer import Endpoint, TransferTask
from ..svc import StatusBus
from .spec import TransferSpec

#: built-in placement policy names (see :meth:`FederatedCoordinator._place`)
PLACEMENT_POLICIES = ("owner", "least-loaded", "advisor")

#: real seconds per idle-wait poll while draining/settling a task
POLL_REAL = 0.05
#: model seconds charged (to the coordinator's ``#wait`` identity) per
#: poll, so drain/settle deadlines advance even at time scale 0
POLL_MODEL = 0.05


class StrandedTasksError(LookupError):
    """A site failure could not re-home every task.  ``moved`` lists
    the ``(task_id, new_site_id)`` pairs that WERE re-homed before the
    error (the work is not lost, only unreported by the return value);
    ``stranded`` names the tasks left on the dead site's durable
    store."""

    def __init__(self, site_id: str, moved, stranded):
        self.site_id = site_id
        self.moved = list(moved)
        self.stranded = list(stranded)
        super().__init__(
            f"no live site could adopt {self.stranded!r} from "
            f"{site_id!r}; their marker state remains on the dead "
            f"site's store ({len(self.moved)} others re-homed first)")


@dataclass
class QueueDigest:
    """One site's periodic queue-state report, as exchanged between
    control planes: enough for placement, nothing data-plane."""

    site_id: str
    seq: int
    queued: int
    running: int
    paused: int
    in_flight_bytes: int
    #: endpoint id -> active tasks / per-endpoint cap (busy-based
    #: ``active / worker budget`` when the site is uncapped)
    saturation: dict = field(default_factory=dict)
    #: endpoint ids whose circuit breaker the site reports as open
    #: (health plane, :mod:`repro.core.health`)
    unavailable: list = field(default_factory=list)
    #: replica-plane summary from the site's catalog —
    #: ``{"stats": {...}, "sources": {source_key: bytes}}`` — so
    #: placement can score replica hits without touching the site
    #: (see :mod:`repro.catalog`); empty when the site has no catalog
    catalog: dict = field(default_factory=dict)
    #: the site manager's queue-state generation this digest reflects;
    #: an unchanged etag means the site's queue has not mutated, so the
    #: coordinator reuses the previous digest instead of rebuilding
    etag: int = -1

    @property
    def depth(self) -> int:
        return self.queued + self.running


@dataclass
class FedMetrics:
    submissions: int = 0
    handoffs: int = 0
    failovers: int = 0
    #: failovers triggered by the heartbeat monitor (a strict subset of
    #: ``failovers``; the rest were caller-invoked ``fail_site``)
    auto_failovers: int = 0
    #: queued specs migrated by the hysteresis rebalancer
    rebalances: int = 0
    digest_exchanges: int = 0
    #: per-site digests answered by the etag cache during exchanges —
    #: the "beat() consumes the etag instead of recomputing" evidence
    digest_reuses: int = 0
    #: site_id -> cumulative missed heartbeats (digest() calls that
    #: raised); reset never — per-site consecutive-miss state lives on
    #: the SiteHandle
    heartbeat_misses: dict = field(default_factory=dict)
    #: task_ids left stranded by heartbeat-driven failovers (the
    #: auto path swallows StrandedTasksError so one sick site can't
    #: abort the whole beat — the strandings are recorded here)
    stranded: list = field(default_factory=list)
    #: site_id -> tasks placed there (initial placements + handoffs in)
    placements: dict = field(default_factory=dict)
    #: (task_id, site_id, reason) in placement order — "submit",
    #: "handoff", "failover", or "rebalance"
    placement_log: list = field(default_factory=list)


class SiteHandle:
    """One registered site: its manager, the endpoints it can reach,
    and the subset it *owns* (is closest to)."""

    def __init__(self, site_id: str, manager, endpoints: dict, owns):
        self.site_id = site_id
        self.manager = manager
        self.endpoints: dict[str, Connector] = dict(endpoints)
        self.owns = set(endpoints if owns is None else owns)
        self.alive = True
        self.digest: QueueDigest | None = None
        #: consecutive digest exchanges this site failed to answer
        self.missed_beats = 0
        #: hysteresis rebalancer state: is the site currently marked hot,
        #: and since when (model clock) its signal has been >= enter
        self.hot = False
        self.hot_since: float | None = None

    def resolves(self, spec: TransferSpec) -> bool:
        return (spec.src_endpoint in self.endpoints
                and spec.dst_endpoint in self.endpoints)

    def endpoint_pair(self, spec: TransferSpec) -> tuple[Endpoint, Endpoint]:
        src = Endpoint(self.endpoints[spec.src_endpoint], spec.src_path,
                       spec.src_endpoint)
        dst = Endpoint(self.endpoints[spec.dst_endpoint], spec.dst_path,
                       spec.dst_endpoint)
        return src, dst

    def load(self) -> int:
        """Queue depth from the last digest exchange (live snapshot when
        none has happened yet)."""
        if self.digest is not None:
            return self.digest.depth
        c = self.manager.counts()
        return c["queued"] + c["running"]


@dataclass
class RebalancePolicy:
    """Hysteresis knobs for proactive queued-spec migration.

    A site's *signal* is ``max(max endpoint saturation, min(1, queued /
    queue_norm))`` from its last digest.  The site turns **hot** only
    after the signal has stayed >= ``enter`` for ``dwell`` model
    seconds, and stops being hot only once the signal drops <= ``exit``
    — the enter/exit gap plus the dwell are the hysteresis that keeps
    borderline sites from flapping.  Each :meth:`FederatedCoordinator.
    maybe_rebalance` tick moves at most ``max_moves`` queued specs off
    hot sites (to the least-loaded non-hot candidate below ``enter``),
    and a spec that just moved is pinned for ``move_cooldown`` model
    seconds so it cannot ping-pong."""

    enter: float = 0.75
    exit: float = 0.35
    dwell: float = 1.0
    queue_norm: int = 8
    max_moves: int = 2
    move_cooldown: float = 5.0


class FederatedCoordinator:
    """Routes serialized submissions across registered sites and moves
    live tasks between them.  Never opens a connector session, never
    touches file bytes: it handles *references* (specs, endpoint ids,
    digests), exactly the paper's third-party posture.

    ``placement`` picks the site for a spec: ``"owner"`` (the site
    whose ownership map claims the spec's *source* endpoint — the
    paper's place-close-to-the-source rule; least-loaded among multiple
    owners), ``"least-loaded"`` (smallest queue depth from the digest
    exchange), ``"advisor"`` (fastest predicted completion: each
    candidate site's Advisor route prediction scaled by its queue
    depth), or any callable ``(spec, candidates) -> SiteHandle``.
    """

    def __init__(self, placement: str = "owner", name: str = "fed",
                 digest_every: int = 4, miss_threshold: int = 3,
                 rebalance: RebalancePolicy | None = None,
                 bus: StatusBus | None = None, catalog=None):
        self.placement = placement
        #: optional federation-wide :class:`~repro.catalog.ReplicaCatalog`
        #: installed on every registered site that has none of its own —
        #: the dedupe-aware-routing convenience for in-process fleets.
        #: The coordinator itself only ever reads digests (metadata):
        #: replica reads happen on site data planes, so third-party
        #: semantics are untouched.
        self.catalog = catalog
        #: service plane: placement/failover/beat event stream; events
        #: are stamped with the involved site's model clock when one is
        #: known (the coordinator itself has no clock — third party)
        self.bus = bus or StatusBus(site_id=f"fed:{name}")
        #: charge-clock identity all coordinator work is attributed to;
        #: third-party semantics == this owner's tally stays 0.0
        self.charge_owner = f"fed:{name}"
        #: sibling identity for drain/settle deadline polls: model time
        #: lands here, visibly, WITHOUT breaking assert_third_party()
        self.wait_owner = f"fed:{name}#wait"
        #: exchange queue-state digests every this many submissions
        #: (and on demand via :meth:`exchange_digests`)
        self.digest_every = max(1, digest_every)
        #: consecutive missed heartbeats before :meth:`beat` auto-fails
        #: a site
        self.miss_threshold = max(1, miss_threshold)
        #: hysteresis rebalancing policy (None = reactive failover only)
        self.rebalance = rebalance
        self.metrics = FedMetrics()
        self._sites: dict[str, SiteHandle] = {}
        self._placements: dict[str, str] = {}      # task_id -> site_id
        self._tasks: dict[str, TransferTask] = {}  # task_id -> live handle
        self._specs: dict[str, TransferSpec] = {}  # last serialized form
        #: task_id -> model time of its last rebalance move (cooldown)
        self._moved_at: dict[str, float] = {}
        self._digest_seq = itertools.count(1)
        self._since_exchange = 0
        self._lock = threading.RLock()

    # ---- membership ------------------------------------------------------
    def register_site(self, site_id: str, manager,
                      endpoints: dict[str, Connector],
                      owns=None) -> SiteHandle:
        """Register one site control plane.  ``endpoints`` maps endpoint
        id -> connector for every endpoint the site can reach; ``owns``
        names the subset it is authoritative (closest) for — defaults
        to all of them."""
        with self._lock:
            if site_id in self._sites:
                raise ValueError(f"site {site_id!r} already registered")
            if not manager.site_id:
                manager.site_id = site_id
            if self.catalog is not None \
                    and manager.service.catalog is None:
                manager.service.catalog = self.catalog
            site = SiteHandle(site_id, manager, endpoints, owns)
            self._sites[site_id] = site
            return site

    def sites(self) -> dict[str, SiteHandle]:
        with self._lock:
            return dict(self._sites)

    def site_of(self, task_id: str) -> str | None:
        with self._lock:
            return self._placements.get(task_id)

    def task(self, task_id: str) -> TransferTask:
        """The task's *current* live handle (follows handoffs)."""
        with self._lock:
            return self._tasks[task_id]

    def last_spec(self, task_id: str) -> TransferSpec | None:
        """The most recent serialized form the coordinator placed —
        after a handoff this is the traveled spec, hole map included."""
        with self._lock:
            return self._specs.get(task_id)

    # ---- queue-state digests ---------------------------------------------
    def exchange_digests(self) -> dict[str, QueueDigest]:
        with self._lock, charge_to(self.charge_owner):
            return self._exchange_locked()

    def _exchange_locked(self) -> dict[str, QueueDigest]:
        out = {}
        for site in self._sites.values():
            if not site.alive:
                continue
            try:
                d = site.manager.digest()
            except Exception:
                # the digest stream IS the heartbeat: a site that can't
                # answer has missed a beat.  Keep its stale digest for
                # placement until beat() decides it is dead.
                site.missed_beats += 1
                misses = self.metrics.heartbeat_misses
                misses[site.site_id] = misses.get(site.site_id, 0) + 1
                continue
            site.missed_beats = 0
            etag = d.get("etag", -1)
            prev = site.digest
            if prev is not None and etag >= 0 and etag == prev.etag:
                # etag hit: the site's queue has not mutated since the
                # last beat — keep the previous digest, skip the rebuild
                self.metrics.digest_reuses += 1
                out[site.site_id] = prev
                continue
            site.digest = QueueDigest(
                site_id=site.site_id, seq=next(self._digest_seq),
                queued=d["queued"], running=d["running"],
                paused=d["paused"],
                in_flight_bytes=d["in_flight_bytes"],
                saturation=d["saturation"],
                unavailable=list(d.get("unavailable_endpoints", [])),
                catalog=dict(d.get("catalog", {}) or {}),
                etag=etag)
            out[site.site_id] = site.digest
        self.metrics.digest_exchanges += 1
        self._since_exchange = 0
        return out

    def _maybe_exchange_locked(self) -> None:
        self._since_exchange += 1
        if self._since_exchange >= self.digest_every \
                or self.metrics.digest_exchanges == 0:
            self._exchange_locked()

    # ---- heartbeat monitor ----------------------------------------------
    def beat(self, timeout: float = 30.0) -> list[str]:
        """One heartbeat tick: exchange digests (a ``digest()`` call
        that raises is a missed beat), auto-fail any live site at
        ``miss_threshold`` consecutive misses via the :meth:`fail_site`
        re-homing path, then run the hysteresis rebalancer if a policy
        is set.  Returns the site ids failed over on this tick.

        A stranded task on a dead site must not abort the rest of the
        beat — :class:`StrandedTasksError` is swallowed here and the
        task ids recorded in ``metrics.stranded`` instead."""
        with self._lock, charge_to(self.charge_owner):
            self._exchange_locked()
            due = [s.site_id for s in self._sites.values()
                   if s.alive and s.missed_beats >= self.miss_threshold]
        failed = []
        for site_id in due:
            try:
                self.fail_site(site_id, timeout=timeout)
            except StrandedTasksError as e:
                self.metrics.stranded.extend(e.stranded)
            self.metrics.auto_failovers += 1
            failed.append(site_id)
        if self.rebalance is not None:
            self.maybe_rebalance()
        self.bus.publish("beat", data={"failed": list(failed)})
        return failed

    # ---- hysteresis rebalancing -----------------------------------------
    @staticmethod
    def _signal(site: SiteHandle, policy: RebalancePolicy) -> float:
        """Degradation signal in [0, 1]: the worse of endpoint
        saturation and normalized queue depth, from the last digest."""
        d = site.digest
        if d is None:
            return 0.0
        sat = max(d.saturation.values(), default=0.0)
        return max(sat, min(1.0, d.queued / max(1, policy.queue_norm)))

    def maybe_rebalance(self) -> list[tuple[str, str, str]]:
        """One rebalancer tick over the last exchanged digests: update
        each site's hot/cold hysteresis state, then migrate up to
        ``max_moves`` *queued* specs (never running — their bytes are
        in flight; never paused — a pause is an operator/failover
        decision) from hot sites to the least-loaded cold candidate.
        Returns ``[(task_id, from_site, to_site), ...]``."""
        policy = self.rebalance
        if policy is None:
            return []
        moved: list[tuple[str, str, str]] = []
        with self._lock, charge_to(self.charge_owner):
            live = [s for s in self._sites.values() if s.alive]
            for s in live:
                sig = self._signal(s, policy)
                now = s.manager.service.clock.virtual_elapsed
                if s.hot:
                    if sig <= policy.exit:   # hysteresis: exit < enter
                        s.hot = False
                        s.hot_since = None
                elif sig >= policy.enter:
                    if s.hot_since is None:
                        s.hot_since = now
                    if now - s.hot_since >= policy.dwell:
                        s.hot = True  # sustained, not a blip
                else:
                    s.hot_since = None
            budget = policy.max_moves
            for site in live:
                if not site.hot or budget <= 0:
                    continue
                now = site.manager.service.clock.virtual_elapsed
                for tid, sid in list(self._placements.items()):
                    if budget <= 0:
                        break
                    if sid != site.site_id:
                        continue
                    task = self._tasks[tid]
                    if task.status != TransferTask.PENDING:
                        continue  # queued specs only
                    last = self._moved_at.get(tid)
                    if last is not None \
                            and now - last < policy.move_cooldown:
                        continue  # anti-ping-pong pin
                    ref = self._specs.get(tid)
                    if ref is None:
                        continue
                    dests = [c for c in live
                             if c.site_id != site.site_id and not c.hot
                             and c.resolves(ref)
                             and self._signal(c, policy) < policy.enter]
                    if not dests:
                        continue
                    payload = site.manager.export_state(tid)
                    if payload is None:
                        continue  # started running since the check
                    spec = TransferSpec.from_payload(payload)
                    dest = min(dests, key=lambda s: s.load())
                    self._import_at_locked(dest, spec, reason="rebalance")
                    self.metrics.rebalances += 1
                    self._moved_at[tid] = now
                    moved.append((tid, site.site_id, dest.site_id))
                    budget -= 1
        return moved

    # ---- placement -------------------------------------------------------
    def _candidates(self, spec: TransferSpec,
                    exclude: str | None = None) -> list[SiteHandle]:
        sites = [s for s in self._sites.values()
                 if s.alive and s.site_id != exclude and s.resolves(spec)]
        if not sites:
            raise LookupError(
                f"no live site resolves both endpoints of {spec.task_id!r} "
                f"({spec.src_endpoint!r} -> {spec.dst_endpoint!r})")
        return sites

    def _place(self, spec: TransferSpec,
               candidates: list[SiteHandle]) -> SiteHandle:
        if callable(self.placement):
            return self.placement(spec, candidates)
        if self.placement == "owner":
            owners = [s for s in candidates if spec.src_endpoint in s.owns]
            pool = owners or candidates
            # replica-aware tiebreak: equal load, prefer the site whose
            # catalog already holds more of this source (dedupe-aware
            # routing — bytes it will not have to move)
            return min(pool, key=lambda s: (s.load(),
                                            -self._replica_bytes(s, spec)))
        if self.placement == "least-loaded":
            return min(candidates,
                       key=lambda s: (s.load(),
                                      -self._replica_bytes(s, spec)))
        if self.placement == "advisor":
            return min(candidates, key=lambda s: self._predicted(s, spec))
        raise ValueError(f"unknown placement policy {self.placement!r}")

    @staticmethod
    def _replica_bytes(site: SiteHandle, spec: TransferSpec) -> int:
        """Bytes the site's replica catalog reports already holding for
        the spec's source — scored from the last exchanged digest (the
        metadata plane), with a live-catalog fallback before the first
        exchange.  Clamped to the workload hint so a stale summary can
        never make a transfer look free-er than its own size."""
        d = site.digest
        sources = d.catalog.get("sources", {}) if d is not None else {}
        if not sources:
            cat = getattr(site.manager, "catalog", None)
            if cat is None:
                return 0
            held = cat.held_bytes_at((spec.dst_endpoint,),
                                     spec.src_endpoint, spec.src_path)
        else:
            held = hint_bytes(sources, spec.src_endpoint, spec.src_path)
        return min(held, spec.nbytes) if spec.nbytes else held

    def _predicted(self, site: SiteHandle, spec: TransferSpec) -> float:
        """Predicted completion on ``site``: the Advisor's route model
        for this workload — minus the bytes the site's replica catalog
        says need not cross the wire — serialized behind the site's
        current queue depth (depth+1 workloads of this shape, a
        deliberately simple backlog model).  Sites without a fitted
        advisor sort last."""
        adv = site.manager.advisor
        if adv is None or not adv.routes:
            return float("inf")
        route = next((r for r in adv.routes if r.name == spec.route),
                     adv.routes[0])
        _, _, eta = Advisor([route]).best(
            max(1, spec.n_files), spec.nbytes,
            replica_bytes=self._replica_bytes(site, spec))
        return eta * (1 + site.load())

    # ---- submission ------------------------------------------------------
    def submit(self, spec: TransferSpec | str,
               sync: bool = False) -> TransferTask:
        """Place one serialized submission on a site and return that
        site's live task handle.  Accepts a :class:`TransferSpec` or
        its JSON wire form."""
        if isinstance(spec, str):
            spec = TransferSpec.from_json(spec)
        spec.validate()
        with self._lock, charge_to(self.charge_owner):
            self.metrics.submissions += 1
            self._maybe_exchange_locked()
            site = self._place(spec, self._candidates(spec))
            task = self._import_at_locked(site, spec, reason="submit")
        if sync:
            task.wait()
        return task

    def _import_at_locked(self, site: SiteHandle, spec: TransferSpec,
                          reason: str) -> TransferTask:
        if not spec.origin_site:
            spec.origin_site = site.site_id  # first placement is origin
        src, dst = site.endpoint_pair(spec)
        task = site.manager.import_state(spec.to_payload(), src, dst)
        self._placements[spec.task_id] = site.site_id
        self._tasks[spec.task_id] = task
        self._specs[spec.task_id] = spec
        self.metrics.placements[site.site_id] = \
            self.metrics.placements.get(site.site_id, 0) + 1
        self.metrics.placement_log.append(
            (spec.task_id, site.site_id, reason))
        now = site.manager.service.clock.virtual_elapsed
        # charge-free adoption marker on the adopting site's tracer: the
        # traveled trace id stitches this into the task's origin timeline
        site.manager.tracer.record(
            "adopt", "queue", now, now,
            trace_id=spec.trace_id or task.trace_id,
            task_id=spec.task_id, site=site.site_id, reason=reason)
        self.bus.publish("placed", task_id=spec.task_id,
                         data={"site": site.site_id, "reason": reason},
                         t=now)
        return task

    # ---- handoff ---------------------------------------------------------
    def _poll_tick(self, clock, task) -> None:
        """One drain/settle poll: a short *real* wait for the worker to
        go idle, then a model-clock step charged to the ``#wait``
        identity so the model deadline advances even at time scale 0 —
        and :meth:`assert_third_party` (which audits ``charge_owner``,
        not the wait sibling) still reads 0.0."""
        task.wait_idle(POLL_REAL)
        with charge_to(self.wait_owner):
            clock.sleep(POLL_MODEL)

    def _drain_export(self, site: SiteHandle, task_id: str,
                      timeout: float) -> dict | None:
        """Export a task from ``site``, pausing it first if it is
        running.  ``None`` when the task finished before it could be
        exported (the handoff lost the race — nothing to move).
        ``timeout`` is MODEL seconds on the site's clock: wall-clock
        free, like every other deadline in the stack."""
        mgr = site.manager
        payload = mgr.export_state(task_id)
        if payload is not None:
            return payload
        mgr.pause(task_id)
        try:
            task = mgr.get(task_id)
        except KeyError:
            return None
        clock = mgr.service.clock
        deadline = clock.virtual_elapsed + timeout
        while clock.virtual_elapsed < deadline:
            payload = mgr.export_state(task_id)
            if payload is not None:
                return payload
            if task._done.is_set():
                return None  # completed/failed before the pause landed
            self._poll_tick(clock, task)
        raise TimeoutError(
            f"task {task_id!r} did not drain off {site.site_id!r} "
            f"within {timeout} model seconds")

    def _precheck_adoption(self, task_id: str, origin_id: str,
                           to_site: str | None) -> None:
        """Raise BEFORE the destructive export if no site could adopt
        the task — endpoints never change across handoffs, so the last
        placed spec answers this without touching the origin."""
        ref = self._specs.get(task_id)
        if ref is None:
            raise LookupError(f"unknown task {task_id!r}")
        if to_site is not None:
            site = self._sites.get(to_site)
            if site is None or not (site.alive and site.resolves(ref)):
                raise LookupError(
                    f"site {to_site!r} cannot adopt {task_id!r}")
        else:
            self._candidates(ref, exclude=origin_id)

    def _await_settled(self, site: SiteHandle, task_id: str,
                       timeout: float) -> None:
        """Wait until ``task_id`` has no run loop (paused checkpoint
        durable, charge bookkeeping complete) or finished.  ``timeout``
        is MODEL seconds on the site's clock."""
        mgr = site.manager
        try:
            task = mgr.get(task_id)
        except KeyError:
            return
        clock = mgr.service.clock
        deadline = clock.virtual_elapsed + timeout
        while clock.virtual_elapsed < deadline:
            if task._done.is_set() or (task.status == TransferTask.PAUSED
                                       and mgr.settled(task_id)):
                return
            self._poll_tick(clock, task)
        raise TimeoutError(
            f"task {task_id!r} did not settle on {site.site_id!r} "
            f"within {timeout} model seconds")

    def handoff(self, task_id: str, to_site: str | None = None,
                timeout: float = 30.0) -> TransferTask | None:
        """Move one queued/paused/running task to a peer site.  A
        running task is paused and drained first, so its hole map (and
        checksum fold) travel and the peer re-sends only the holes.
        Returns the adopting site's task handle, or ``None`` when the
        task finished before it could move."""
        with self._lock:
            origin_id = self._placements.get(task_id)
            if origin_id is None:
                raise LookupError(f"unknown task {task_id!r}")
            origin = self._sites[origin_id]
            self._precheck_adoption(task_id, origin_id, to_site)
        with charge_to(self.charge_owner):
            h0 = origin.manager.service.clock.virtual_elapsed
            payload = self._drain_export(origin, task_id, timeout)
            if payload is None:
                return None
            spec = TransferSpec.from_payload(payload)
            with self._lock:
                try:
                    if to_site is not None:
                        site = self._sites[to_site]
                        if not (site.alive and site.resolves(spec)):
                            raise LookupError(
                                f"site {to_site!r} cannot adopt "
                                f"{task_id!r}")
                    else:
                        site = self._place(
                            spec, self._candidates(spec,
                                                   exclude=origin_id))
                except Exception:
                    # never strand an exported task: the origin adopts
                    # its own spec back (a queued re-import) rather
                    # than losing the traveled marker state
                    if origin.alive:
                        self._import_at_locked(origin, spec,
                                               reason="handoff-abort")
                    raise
                task = self._import_at_locked(site, spec, reason="handoff")
                self.metrics.handoffs += 1
                # the drain→adoption window, on the origin's clock;
                # record() charges nothing, so the coordinator's
                # third-party invariant (0.0 model seconds) holds
                origin.manager.tracer.record(
                    "handoff", "queue", h0,
                    origin.manager.service.clock.virtual_elapsed,
                    trace_id=spec.trace_id, task_id=task_id,
                    origin=origin_id, to=site.site_id)
        return task

    # ---- site failure ----------------------------------------------------
    def fail_site(self, site_id: str,
                  timeout: float = 30.0) -> list[tuple[str, str]]:
        """Take a site out of rotation and re-home every task it still
        holds.  Running tasks are paused (their partial progress is
        checkpointed through the site's MarkerStore — the emulation of
        a crash with durable restart markers), serialized, and resumed
        on peers re-sending only the holes.  Returns
        ``[(task_id, new_site_id), ...]`` for every task moved."""
        with self._lock:
            site = self._sites[site_id]
            site.alive = False
            doomed = [tid for tid, sid in self._placements.items()
                      if sid == site_id
                      and not self._tasks[tid]._done.is_set()]
            # a task no peer can adopt must NOT be exported (the export
            # would clear the only copy of its marker state); it is
            # still paused and drained below, so its checkpoint lands
            # on the dead site's durable store before teardown
            stranded = []
            for tid in doomed:
                try:
                    self._precheck_adoption(tid, site_id, None)
                except LookupError:
                    stranded.append(tid)
            adoptable = [tid for tid in doomed if tid not in stranded]
        moved: list[tuple[str, str]] = []
        try:
            with charge_to(self.charge_owner):
                # request every pause first — stranded tasks included,
                # or they would keep streaming on the "failed" site and
                # shutdown would forget their live charge tallies —
                # then drain: tasks checkpoint concurrently, not
                # serially
                for tid in doomed:
                    site.manager.pause(tid)
                for tid in stranded:
                    try:
                        self._await_settled(site, tid, timeout)
                    except TimeoutError:
                        pass  # reported via StrandedTasksError below
                for tid in adoptable:
                    try:
                        payload = self._drain_export(site, tid, timeout)
                    except TimeoutError:
                        # one wedged drain must not abort the rest of
                        # the failover (or lose the `moved` record)
                        stranded.append(tid)
                        continue
                    if payload is None:
                        continue  # finished during the drain
                    spec = TransferSpec.from_payload(payload)
                    with self._lock:
                        peer = self._place(
                            spec, self._candidates(spec,
                                                   exclude=site_id))
                        self._import_at_locked(peer, spec,
                                               reason="failover")
                    moved.append((tid, peer.site_id))
        finally:
            self.metrics.failovers += 1
            site.manager.shutdown(wait=False)
            self.bus.publish("failover",
                             data={"site": site_id, "moved": len(moved),
                                   "stranded": len(stranded)})
        if stranded:
            raise StrandedTasksError(site_id, moved, stranded)
        return moved

    # ---- lifecycle fan-out ----------------------------------------------
    def wait_all(self, timeout: float | None = None) -> bool:
        """Wait until every placed task has finished on its current
        site (paused tasks excluded, as in ``TransferManager``).

        Delegates to each live site's condition-variable ``wait_all``
        (one notify per completion — no wall-clock re-poll slicing);
        the outer loop only re-checks for tasks that migrated to
        another site (handoff / failover) while a site was draining.
        A task stranded on no live site falls back to a bounded wait
        on its own done event.

        ``timeout`` is a *wall* bound by design: it exists to hand
        control back to a caller even when the fleet is wedged, and
        model time never advances while every site idles — a model
        deadline could never fire.  Routed through the sanctioned
        ``wall_now`` helper (see the module docstring)."""
        deadline = None if timeout is None else wall_now() + timeout

        def _pending_locked():
            return [t for t in self._tasks.values()
                    if not t._done.is_set()
                    and t.status != TransferTask.PAUSED]

        while True:
            with self._lock:
                pending = _pending_locked()
                sites = [s for s in self._sites.values() if s.alive]
            if not pending:
                return True
            drained = True
            for site in sites:
                remaining = None if deadline is None \
                    else deadline - wall_now()
                if remaining is not None and remaining <= 0:
                    return False
                drained = site.manager.wait_all(remaining) and drained
            with self._lock:
                still = _pending_locked()
            if not still:
                return True
            if drained:
                # every live site is drained yet tasks remain: they are
                # stranded off-site (dead site / mid-migration) — wait
                # on the task itself, bounded so migrations re-check
                remaining = None if deadline is None \
                    else deadline - wall_now()
                if remaining is not None and remaining <= 0:
                    return False
                step = 0.1 if remaining is None else min(0.1, remaining)
                still[0].wait(step)

    def shutdown(self, wait: bool = True,
                 timeout: float | None = None) -> None:
        if wait:
            self.wait_all(timeout)
        with self._lock:
            sites = list(self._sites.values())
        for site in sites:
            if site.alive:
                site.manager.shutdown(wait=False)

    # ---- third-party invariant ------------------------------------------
    def model_seconds(self) -> float:
        """Model time charged to the coordinator across every site's
        clock.  The third-party contract says this is exactly 0.0: the
        coordinator moves references, the sites' worker threads move
        bytes (and charge their own tasks)."""
        clocks = {}
        with self._lock:
            for site in self._sites.values():
                clock = site.manager.service.clock
                clocks[id(clock)] = clock
        return sum(c.charged(self.charge_owner) for c in clocks.values())

    def wait_seconds(self) -> float:
        """Model time spent polling drain/settle deadlines, across every
        site's clock.  Charged to the ``#wait`` sibling identity — it is
        coordination overhead, observable here, and deliberately NOT a
        third-party violation: no data-plane byte ever moves under it."""
        clocks = {}
        with self._lock:
            for site in self._sites.values():
                clock = site.manager.service.clock
                clocks[id(clock)] = clock
        return sum(c.charged(self.wait_owner) for c in clocks.values())

    def assert_third_party(self) -> None:
        charged = self.model_seconds()
        if charged > 0.0:
            raise AssertionError(
                f"third-party violation: coordinator {self.charge_owner!r} "
                f"accrued {charged:.6f} model seconds of data-plane time")
