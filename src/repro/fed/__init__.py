"""Federation plane: multiple site control planes, one third-party
coordinator.

* :mod:`repro.fed.spec` — :class:`TransferSpec`, the JSON-round-trip
  submission value that lets a task (including a paused one, hole map
  and checksum fold riding along) move between control planes.
* :mod:`repro.fed.coordinator` — :class:`FederatedCoordinator`:
  endpoint-ownership placement (owner / least-loaded /
  advisor-predicted-fastest), periodic queue-state digest exchange,
  task handoff, site-failure re-homing, a heartbeat monitor that
  auto-triggers failover from missed digests, and hysteresis-gated
  proactive rebalancing (:class:`RebalancePolicy`) — all without ever
  touching file bytes (enforced by the charge-attribution clock).
"""

from .coordinator import (PLACEMENT_POLICIES, FederatedCoordinator,
                          FedMetrics, QueueDigest, RebalancePolicy,
                          SiteHandle, StrandedTasksError)
from .spec import SPEC_STATES, TransferSpec

__all__ = [
    "FederatedCoordinator", "FedMetrics", "PLACEMENT_POLICIES",
    "QueueDigest", "RebalancePolicy", "SiteHandle", "SPEC_STATES",
    "StrandedTasksError", "TransferSpec",
]
