"""Content-addressed replica catalog (replica management plane).

Allcock et al. (PAPERS.md) showed the natural step past efficient
transport is *replica management*: don't move bytes a site already
holds.  The data plane's per-range digest journal (§7 checksum fold)
content-addresses every traveled segment anyway, so publishing the
finished (digest, location) pairs into a catalog is nearly free — and a
fan-out of N identical submissions then collapses to 1 real transfer
plus N-1 near-destination replica reads, each still verified end-to-end
by the same fold.

* :mod:`repro.catalog.replica` — :class:`ReplicaCatalog`: site-scoped
  replica entries keyed by content digest + source ``(size, mtime)``
  signature, LRU/byte-budget eviction, staleness invalidation, and the
  compact summaries that ride the federation digest/etag exchange so
  placement can score replica hits.
"""

from .replica import (ReplicaCatalog, ReplicaEntry, hint_bytes,
                      source_key)

__all__ = ["ReplicaCatalog", "ReplicaEntry", "hint_bytes", "source_key"]
