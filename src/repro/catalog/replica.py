"""Content-addressed replica catalog with LRU/byte-budget eviction.

An entry says: the bytes whose §7 checksum is ``content`` — produced
from source file ``(src_endpoint, src_path)`` while its stat signature
was ``src_sig`` — are durably held at ``(endpoint_id, path)``.  The
data plane publishes entries at durable-commit time (the
:class:`~repro.core.transfer.RangeDigester` fold already computed the
key) and consults the catalog before opening a source stream: a fresh
entry at the destination endpoint is satisfied by a local replica read
instead of a source read, with the checksum fold still verifying the
replica against ``content`` end-to-end.

Trust model — the catalog is a *hint* cache, never an authority:

* **staleness**: a lookup carries the source's current ``(size,
  mtime)`` signature; a signature mismatch invalidates every entry
  derived from that source and reports a miss (the §7 source re-read
  this shortcut replaces would have seen the new bytes, so the
  shortcut must refuse to serve the old ones);
* **corruption**: the replica read re-folds the streamed bytes and the
  caller invalidates on mismatch — a corrupt replica costs one wasted
  local read, never a wrong byte at the destination;
* **eviction**: LRU under an optional byte budget / entry cap, exact
  and deterministic (ordered by use, tie-broken by a monotonic
  counter, never wall time).

Everything is JSON-clean so entries can travel as *hints* with a
federated handoff (:class:`~repro.fed.spec.TransferSpec`) and be
re-validated by the adopting site.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import asdict, dataclass, field


def source_key(src_endpoint: str, src_path: str) -> str:
    return f"{src_endpoint}|{src_path}"


def hint_bytes(sources: dict, src_endpoint: str, src_path: str) -> int:
    """Bytes a catalog source-summary holds for a source prefix — the
    placement-scoring primitive.  ``sources`` maps ``source_key`` ->
    bytes (the shape :meth:`ReplicaCatalog.source_summary` exports and
    the federation digest exchange carries).  Matches the exact path
    and anything under it (directory submissions expand to per-file
    entries)."""
    exact = source_key(src_endpoint, src_path)
    prefix = source_key(src_endpoint, src_path.rstrip("/")) + "/"
    return sum(n for k, n in sources.items()
               if k == exact or k.startswith(prefix))


@dataclass
class ReplicaEntry:
    """One cataloged replica: content identity, provenance, location."""

    #: §7 checksum of the bytes — plain hex or an ``r:`` composite
    #: folded from per-range digests
    content: str
    size: int
    #: source stat signature ``[size, mtime]`` the entry is valid
    #: against (same shape the marker journal stamps as ``src_sig``)
    src_sig: list
    src_endpoint: str
    src_path: str
    #: where the replica lives
    endpoint_id: str
    path: str
    site: str = ""
    #: per-range digests backing an ``r:`` composite ``content`` — the
    #: boundaries a replica read must re-fold over to verify
    digests: dict = field(default_factory=dict)

    def key(self) -> tuple[str, str, str]:
        return (self.content, self.endpoint_id, self.path)

    def src_key(self) -> str:
        return source_key(self.src_endpoint, self.src_path)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ReplicaEntry":
        known = {f for f in cls.__dataclass_fields__}
        return cls(**{k: v for k, v in d.items() if k in known})


class ReplicaCatalog:
    """Thread-safe content-addressed replica index.

    ``byte_budget``/``max_entries`` bound the catalog; eviction is
    exact LRU (publishes and serving lookups refresh recency, peeks and
    placement scoring do not).  All counters are monotonic and the
    ``generation`` bumps on every mutation, so a federation digest can
    etag the catalog the same way the manager etags its queue state.
    """

    def __init__(self, byte_budget: int | None = None,
                 max_entries: int | None = None, site: str = ""):
        self.byte_budget = byte_budget
        self.max_entries = max_entries
        self.site = site
        self._lock = threading.Lock()
        #: entry.key() -> ReplicaEntry, least-recently-used first
        self._entries: OrderedDict[tuple, ReplicaEntry] = OrderedDict()
        #: source_key -> set of entry keys derived from that source
        self._by_source: dict[str, set] = {}
        self.bytes = 0
        self.generation = 0
        # observability
        self.published = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.stale_invalidations = 0
        self.corrupt_invalidations = 0

    # ---- write path ------------------------------------------------------
    def publish(self, *, content: str, size: int, src_sig,
                src_endpoint: str, src_path: str, endpoint_id: str,
                path: str, site: str = "",
                digests: dict | None = None) -> ReplicaEntry | None:
        """Index one durably-committed replica.  Oversized payloads
        (bigger than the whole byte budget) are refused rather than
        evicting the entire catalog for an entry that still won't fit."""
        if not content or size <= 0 or src_sig is None:
            return None
        if self.byte_budget is not None and size > self.byte_budget:
            return None
        entry = ReplicaEntry(content=content, size=size,
                             src_sig=list(src_sig),
                             src_endpoint=src_endpoint, src_path=src_path,
                             endpoint_id=endpoint_id, path=path,
                             site=site or self.site,
                             digests=dict(digests or {}))
        with self._lock:
            key = entry.key()
            old = self._entries.pop(key, None)
            if old is not None:
                self.bytes -= old.size
                self._by_source.get(old.src_key(), set()).discard(key)
            self._entries[key] = entry
            self.bytes += size
            self._by_source.setdefault(entry.src_key(), set()).add(key)
            self.published += 1
            self.generation += 1
            self._evict_locked()
        return entry

    def merge_hint(self, hint: dict) -> ReplicaEntry | None:
        """Adopt a traveled replica hint (a :meth:`ReplicaEntry.to_dict`
        dict riding a :class:`~repro.fed.spec.TransferSpec`).  Hints go
        through :meth:`publish`, so budgets and invalidation apply to
        them exactly as to locally-produced entries."""
        try:
            e = ReplicaEntry.from_dict(hint)
        except TypeError:
            return None  # malformed hint: ignore, never raise
        if not e.content or e.size <= 0:
            return None
        return self.publish(content=e.content, size=e.size,
                            src_sig=e.src_sig, src_endpoint=e.src_endpoint,
                            src_path=e.src_path, endpoint_id=e.endpoint_id,
                            path=e.path, site=e.site, digests=e.digests)

    def _evict_locked(self) -> None:
        while ((self.byte_budget is not None
                and self.bytes > self.byte_budget)
               or (self.max_entries is not None
                   and len(self._entries) > self.max_entries)):
            key, victim = self._entries.popitem(last=False)
            self.bytes -= victim.size
            self._by_source.get(victim.src_key(), set()).discard(key)
            self.evictions += 1
            self.generation += 1

    # ---- read path -------------------------------------------------------
    def _fresh_locked(self, src_endpoint: str, src_path: str, src_sig,
                      endpoint_id: str | None) -> ReplicaEntry | None:
        """Most-recently-used fresh entry for a source, invalidating
        stale ones as they are discovered (caller holds the lock)."""
        skey = source_key(src_endpoint, src_path)
        keys = self._by_source.get(skey)
        if not keys:
            return None
        sig = list(src_sig) if src_sig is not None else None
        best = None
        for key in list(keys):
            entry = self._entries.get(key)
            if entry is None:
                keys.discard(key)
                continue
            if sig is None or entry.src_sig != sig:
                # the source changed under the entry: every byte it
                # indexes is stale — drop it now so no later lookup
                # (possibly without a fresh stat) can be served old data
                self._drop_locked(key)
                self.stale_invalidations += 1
                continue
            if endpoint_id is not None and entry.endpoint_id != endpoint_id:
                continue
            best = entry  # OrderedDict iterates LRU->MRU; keep the last
        return best

    def lookup(self, src_endpoint: str, src_path: str, src_sig,
               endpoint_id: str | None = None) -> ReplicaEntry | None:
        """A fresh replica of ``(src_endpoint, src_path)`` at
        ``endpoint_id`` (any endpoint when ``None``), validated against
        the source's *current* stat signature.  Counts a hit/miss and
        refreshes LRU recency — this is the serving path."""
        with self._lock:
            entry = self._fresh_locked(src_endpoint, src_path, src_sig,
                                       endpoint_id)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(entry.key())
            self.hits += 1
            return entry

    def peek(self, src_endpoint: str, src_path: str, src_sig,
             endpoint_id: str | None = None) -> ReplicaEntry | None:
        """Like :meth:`lookup` but counter- and LRU-neutral — for
        routing decisions that may not be followed by a read."""
        with self._lock:
            return self._fresh_locked(src_endpoint, src_path, src_sig,
                                      endpoint_id)

    def invalidate(self, entry: ReplicaEntry,
                   reason: str = "corrupt") -> bool:
        """Drop one entry (a replica read that failed its fold calls
        this before falling back to a real transfer)."""
        with self._lock:
            if entry.key() not in self._entries:
                return False
            self._drop_locked(entry.key())
            if reason == "corrupt":
                self.corrupt_invalidations += 1
            else:
                self.stale_invalidations += 1
            return True

    def _drop_locked(self, key: tuple) -> None:
        victim = self._entries.pop(key, None)
        if victim is None:
            return
        self.bytes -= victim.size
        self._by_source.get(victim.src_key(), set()).discard(key)
        self.generation += 1

    # ---- placement / federation views ------------------------------------
    def held_bytes_at(self, endpoint_ids, src_endpoint: str,
                      src_path: str) -> int:
        """Bytes already held at any of ``endpoint_ids`` for a source
        prefix — replica-aware route/placement scoring.  Read-only: no
        counters, no LRU touch (a score is not a serve)."""
        eps = set(endpoint_ids)
        exact = source_key(src_endpoint, src_path)
        prefix = source_key(src_endpoint, src_path.rstrip("/")) + "/"
        with self._lock:
            return sum(e.size for e in self._entries.values()
                       if e.endpoint_id in eps
                       and (e.src_key() == exact
                            or e.src_key().startswith(prefix)))

    def source_summary(self) -> dict:
        """Compact ``source_key -> bytes`` map — what rides the
        federation digest exchange (see :func:`hint_bytes`)."""
        with self._lock:
            out: dict[str, int] = {}
            for e in self._entries.values():
                out[e.src_key()] = out.get(e.src_key(), 0) + e.size
            return out

    def export_hints(self, src_endpoint: str, src_path: str,
                     limit: int = 32) -> list[dict]:
        """JSON-clean entry dicts for a source prefix, MRU-first — the
        replica hints a handoff carries to the adopting site."""
        exact = source_key(src_endpoint, src_path)
        prefix = source_key(src_endpoint, src_path.rstrip("/")) + "/"
        with self._lock:
            out = [e.to_dict() for e in reversed(self._entries.values())
                   if e.src_key() == exact or e.src_key().startswith(prefix)]
        return out[:limit]

    def entries(self) -> list[ReplicaEntry]:
        """LRU->MRU snapshot (tests assert eviction order with this)."""
        with self._lock:
            return list(self._entries.values())

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._entries), "bytes": self.bytes,
                    "hits": self.hits, "misses": self.misses,
                    "published": self.published,
                    "evictions": self.evictions,
                    "stale_invalidations": self.stale_invalidations,
                    "corrupt_invalidations": self.corrupt_invalidations,
                    "generation": self.generation}

    def hit_rate(self) -> float:
        with self._lock:
            total = self.hits + self.misses
            return self.hits / total if total else 0.0
