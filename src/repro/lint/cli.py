"""``python -m repro.lint`` — run the contract linter.

    python -m repro.lint                  # human table, exit 0/1
    python -m repro.lint --check          # CI gate: also enforce the
                                          # suppression budget
    python -m repro.lint --json           # machine-readable report
    python -m repro.lint --write-budget   # bless current suppressions
    python -m repro.lint src/repro/core   # subset of the tree

Exit codes: 0 clean, 1 findings / budget growth, 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .engine import (BUDGET_FILE, budget_violations, load_budget, run_lint,
                     write_budget)
from .rules import RULES

#: src/repro/lint/cli.py -> repo root is four parents up
_DEFAULT_ROOT = Path(__file__).resolve().parents[3]


def _table(rows: list[tuple[str, str, str]]) -> str:
    if not rows:
        return ""
    w0 = max(len(r[0]) for r in rows)
    w1 = max(len(r[1]) for r in rows)
    return "\n".join(f"{r[0]:<{w0}}  {r[1]:<{w1}}  {r[2]}" for r in rows)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="AST-based invariant checker for the clock/charge/"
                    "lock/health contracts (rules R001-R005)")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--root", type=Path, default=_DEFAULT_ROOT,
                    help="repo root for path scoping + the budget file")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable JSON report on stdout")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: nonzero exit on any unsuppressed "
                         "finding OR suppression growth past the budget")
    ap.add_argument("--write-budget", action="store_true",
                    help="record current suppression counts as the "
                         "blessed budget")
    ap.add_argument("--budget", type=Path, default=None,
                    help=f"budget file (default: <root>/{BUDGET_FILE})")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rule ids to report")
    args = ap.parse_args(argv)

    root = args.root.resolve()
    budget_path = args.budget or (root / BUDGET_FILE)
    report = run_lint(root, args.paths or None)
    if args.rules:
        keep = set(args.rules.split(","))
        unknown = keep - set(RULES) - {"R000"}
        if unknown:
            print(f"unknown rule(s): {','.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
        report.findings = [f for f in report.findings if f.rule in keep]
        report.suppressed = [f for f in report.suppressed
                             if f.rule in keep]

    if args.write_budget:
        write_budget(budget_path, report)
        print(f"budget written: {budget_path}")

    over = budget_violations(report, load_budget(budget_path)) \
        if args.check else []
    ok = not report.failing and not over

    if args.as_json:
        print(json.dumps({
            "ok": ok,
            "files_checked": report.files_checked,
            "findings": [f.to_dict() for f in report.failing],
            "suppressed": [f.to_dict() for f in report.suppressed],
            "unused_suppressions": [
                {"rule": s.rule, "line": s.line, "reason": s.reason}
                for s in report.unused_suppressions],
            "budget_violations": over,
        }, indent=2))
        return 0 if ok else 1

    rows = [(f.rule, f"{f.file}:{f.line}", f.message)
            for f in report.failing]
    if rows:
        print(_table(rows))
    if report.unused_suppressions:
        print(f"note: {len(report.unused_suppressions)} unused "
              "suppression(s) — remove stale disables")
    for msg in over:
        print(f"BUDGET: {msg}")
    n_sup = len(report.suppressed)
    print(f"{report.files_checked} files checked: "
          f"{len(report.failing)} finding(s), "
          f"{n_sup} suppressed (see {BUDGET_FILE})"
          + ("" if ok else " — FAIL"))
    return 0 if ok else 1
