"""The contract rules, as AST checkers.

Each rule enforces one of the codebase's concurrency / determinism
contracts (see ROADMAP "Enforced contracts").  A rule is a pure
function ``check(mod: ModuleInfo) -> list[Finding]`` over one parsed
module; scoping (which files a rule applies to) lives in
:mod:`repro.lint.engine`, so the checkers themselves stay testable on
fixture snippets.

All analysis is **intra-procedural** except R005's intra-module call
graph: a sleep hidden behind a helper called from inside a lock is out
of reach.  That is a deliberate trade — the contracts these rules guard
are *local idioms* (charge the thread you spawn, stamp from the model
clock, hold the lock you suffix for), and local analysis keeps every
finding explainable as "this line, this token".
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

# --------------------------------------------------------------------------
# findings + module context
# --------------------------------------------------------------------------


@dataclass
class Finding:
    """One rule violation at an exact source location."""

    rule: str
    file: str  # repo-relative posix path
    line: int
    message: str
    suppressed: bool = False
    reason: str = ""

    def to_dict(self) -> dict:
        return {"rule": self.rule, "file": self.file, "line": self.line,
                "message": self.message, "suppressed": self.suppressed,
                "reason": self.reason}


@dataclass
class ModuleInfo:
    """One parsed module plus the import-alias maps the rules need."""

    rel: str  # repo-relative posix path
    tree: ast.Module
    source: str
    #: local names bound to the stdlib ``time`` module (incl. aliases
    #: and function-local ``import time as _time``)
    time_names: set = field(default_factory=set)
    #: local name -> ``time`` attr, from ``from time import monotonic``
    time_funcs: dict = field(default_factory=dict)
    #: local names bound to the stdlib ``random`` module
    random_names: set = field(default_factory=set)
    #: local name -> ``random`` attr, from ``from random import random``
    random_funcs: dict = field(default_factory=dict)
    #: local names bound to the ``datetime`` *module*
    datetime_mod_names: set = field(default_factory=set)
    #: local names bound to the ``datetime.datetime`` *class*
    datetime_cls_names: set = field(default_factory=set)

    @classmethod
    def parse(cls, rel: str, source: str) -> "ModuleInfo":
        mod = cls(rel=rel, tree=ast.parse(source), source=source)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    name = alias.asname or alias.name
                    if alias.name == "time":
                        mod.time_names.add(name)
                    elif alias.name == "random":
                        mod.random_names.add(name)
                    elif alias.name == "datetime":
                        mod.datetime_mod_names.add(name)
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                for alias in node.names:
                    name = alias.asname or alias.name
                    if node.module == "time":
                        mod.time_funcs[name] = alias.name
                    elif node.module == "random":
                        mod.random_funcs[name] = alias.name
                    elif node.module == "datetime" \
                            and alias.name == "datetime":
                        mod.datetime_cls_names.add(name)
        return mod


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


# --------------------------------------------------------------------------
# R001 — wall-clock ban
# --------------------------------------------------------------------------

#: ``time`` attrs that read or burn wall time
_TIME_BANNED = {"time", "monotonic", "sleep", "perf_counter",
                "time_ns", "monotonic_ns", "perf_counter_ns",
                "process_time", "process_time_ns"}
#: ``datetime`` / ``datetime.datetime`` attrs that read the wall clock
_DATETIME_BANNED = {"now", "utcnow", "today"}
#: ``random``-module attrs that are NOT the global-stream gamble:
#: explicit (seedable) generator constructors
_RANDOM_OK = {"Random", "SystemRandom"}


def check_r001(mod: ModuleInfo) -> list[Finding]:
    """Wall-clock ban: model time comes from the injected ``Clock``
    (``src/repro/core/clock.py``), determinism from seeded RNGs.  Flags
    ``time.time/monotonic/sleep/...``, ``datetime.now`` (and friends),
    any stdlib ``random`` module-level draw (global RNG stream), and an
    unseeded ``random.Random()``.  ``jax.random`` (keyed) and seeded
    ``random.Random(seed)`` / ``numpy.default_rng(seed)`` instances are
    untouched.  Harness code that genuinely needs a *real* bound goes
    through the sanctioned ``clock.wall_now()`` / ``clock.wall_sleep()``
    helpers instead."""
    out = []

    def hit(node, what, why):
        out.append(Finding("R001", mod.rel, node.lineno,
                           f"wall clock: {what} — {why}"))

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name):
            base, attr = fn.value.id, fn.attr
            if base in mod.time_names and attr in _TIME_BANNED:
                hit(node, f"{base}.{attr}()",
                    "use the injected model Clock (or clock.wall_now/"
                    "wall_sleep for sanctioned harness bounds)")
            elif base in mod.random_names and attr not in _RANDOM_OK:
                hit(node, f"{base}.{attr}()",
                    "global random stream is unseeded; draw from a "
                    "random.Random(seed) instance")
            elif base in mod.random_names and attr == "Random" \
                    and not node.args and not node.keywords:
                hit(node, f"{base}.Random()",
                    "unseeded Random() falls back to OS entropy; "
                    "pass a seed")
            elif (base in mod.datetime_mod_names
                  or base in mod.datetime_cls_names) \
                    and attr in _DATETIME_BANNED:
                hit(node, f"{base}.{attr}()",
                    "wall-clock date; stamp from the model clock")
        elif isinstance(fn, ast.Attribute):
            # datetime.datetime.now()
            chain = _dotted(fn)
            if chain is not None and fn.attr in _DATETIME_BANNED:
                head = chain.rsplit(".", 1)[0]
                parts = head.split(".")
                if parts[0] in mod.datetime_mod_names and \
                        parts[-1] == "datetime":
                    hit(node, f"{chain}()",
                        "wall-clock date; stamp from the model clock")
        elif isinstance(fn, ast.Name):
            if mod.time_funcs.get(fn.id) in _TIME_BANNED:
                hit(node, f"{fn.id}() [time.{mod.time_funcs[fn.id]}]",
                    "use the injected model Clock")
            elif fn.id in mod.random_funcs \
                    and mod.random_funcs[fn.id] not in _RANDOM_OK:
                hit(node, f"{fn.id}() [random.{mod.random_funcs[fn.id]}]",
                    "global random stream is unseeded")
    return out


# --------------------------------------------------------------------------
# R002 — charge-owner propagation across thread/pool boundaries
# --------------------------------------------------------------------------


def _func_scopes(tree: ast.Module):
    """Yield (scope_node, body_nodes) for the module and each function,
    where body_nodes excludes nested function bodies (each nested def is
    its own scope — charge binding is per spawning frame)."""
    scopes = [tree] + [n for n in ast.walk(tree)
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]
    for scope in scopes:
        own: list[ast.AST] = []
        stack = list(ast.iter_child_nodes(scope))
        while stack:
            n = stack.pop()
            own.append(n)
            if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                stack.extend(ast.iter_child_nodes(n))
        yield scope, own


def _is_bind_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    return (isinstance(fn, ast.Name) and fn.id == "bind_charge_owner") or \
        (isinstance(fn, ast.Attribute) and fn.attr == "bind_charge_owner")


def check_r002(mod: ModuleInfo) -> list[Finding]:
    """Charge-owner propagation: in the transfer stack, every
    ``threading.Thread(target=...)`` and every ``<pool/executor>.submit
    (fn, ...)`` must hand the callee a ``bind_charge_owner``-wrapped
    callable, or ``Clock.charged(owner)`` silently loses the model time
    the spawned thread accrues (the fleet's per-task attribution — and
    the Advisor's refit observations — go quiet-wrong, not loud-wrong).
    Accepted: a direct ``bind_charge_owner(...)`` argument, or a name
    assigned from one in the same function scope."""
    out = []
    for scope, own in _func_scopes(mod.tree):
        bound = {t.id for n in own if isinstance(n, ast.Assign)
                 and _is_bind_call(n.value)
                 for t in n.targets if isinstance(t, ast.Name)}

        def ok(expr) -> bool:
            if expr is None:
                return False
            if _is_bind_call(expr):
                return True
            return isinstance(expr, ast.Name) and expr.id in bound

        for n in own:
            if not isinstance(n, ast.Call):
                continue
            fn = n.func
            name = _dotted(fn) or ""
            if name == "threading.Thread" or name == "Thread":
                target = next((kw.value for kw in n.keywords
                               if kw.arg == "target"), None)
                if target is None and n.args:
                    target = n.args[0]
                if not ok(target):
                    out.append(Finding(
                        "R002", mod.rel, n.lineno,
                        "Thread target not wrapped in bind_charge_owner "
                        "— spawned thread's model time is unattributed"))
            elif isinstance(fn, ast.Attribute) and fn.attr == "submit":
                recv = _dotted(fn.value) or ""
                leaf = recv.rsplit(".", 1)[-1].lower()
                if "pool" not in leaf and "executor" not in leaf:
                    continue  # task submission, not a worker pool
                work = n.args[0] if n.args else None
                if not ok(work):
                    out.append(Finding(
                        "R002", mod.rel, n.lineno,
                        f"{recv}.submit() callable not wrapped in "
                        "bind_charge_owner — pool thread's model time "
                        "is unattributed"))
    return out


# --------------------------------------------------------------------------
# R003 — *_locked discipline
# --------------------------------------------------------------------------

#: calls that burn model/wall time or touch storage — forbidden while
#: holding ``self._lock`` (a sleep under the queue lock stalls every
#: waiter; connector I/O under it inverts the control/data split)
_LOCKED_BODY_BANNED_ATTRS = {"sleep"}
_LOCKED_BODY_BANNED_IO = {"send", "recv", "send_batch", "recv_batch",
                          "listdir"}


def _with_acquires_self_lock(node: ast.With) -> bool:
    for item in node.items:
        name = _dotted(item.context_expr)
        if name in ("self._lock", "self._cv"):
            return True
    return False


def check_r003(mod: ModuleInfo) -> list[Finding]:
    """Lock discipline: a ``*_locked``-suffixed method encodes "caller
    holds ``self._lock``" in its name — so every call to one must sit
    inside a ``with self._lock:`` (or ``self._cv``) block, or inside a
    function itself suffixed ``_locked``.  Conversely, nothing slow may
    run *under* the lock: no ``*.sleep`` and no connector I/O
    (send/recv/batch/listdir) inside a ``with self._lock:`` body."""
    out = []

    funcs = [n for n in ast.walk(mod.tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in funcs:
        caller_locked = fn.name.endswith("_locked")
        # map every node in THIS function (not nested defs) to whether
        # a with-self._lock encloses it
        def visit(nodes, locked):
            for n in nodes:
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                    continue  # nested scope: its calls judged on its own
                inner = locked
                if isinstance(n, ast.With):
                    inner = locked or _with_acquires_self_lock(n)
                if isinstance(n, ast.Call):
                    name = _dotted(n.func) or ""
                    leaf = name.rsplit(".", 1)[-1]
                    if leaf.endswith("_locked") \
                            and not locked and not caller_locked:
                        out.append(Finding(
                            "R003", mod.rel, n.lineno,
                            f"{name}() called without holding "
                            "self._lock (callers of *_locked must hold "
                            "the lock or be *_locked themselves)"))
                    if locked and isinstance(n.func, ast.Attribute):
                        attr = n.func.attr
                        if attr in _LOCKED_BODY_BANNED_ATTRS:
                            out.append(Finding(
                                "R003", mod.rel, n.lineno,
                                f"{name}() inside `with self._lock:` — "
                                "sleeping under the lock stalls every "
                                "waiter"))
                        elif attr in _LOCKED_BODY_BANNED_IO:
                            out.append(Finding(
                                "R003", mod.rel, n.lineno,
                                f"{name}() inside `with self._lock:` — "
                                "connector I/O under the control-plane "
                                "lock"))
                visit(ast.iter_child_nodes(n), inner)

        visit(ast.iter_child_nodes(fn), False)
    return out


# --------------------------------------------------------------------------
# R004 — error taxonomy
# --------------------------------------------------------------------------


def check_r004(mod: ModuleInfo) -> list[Finding]:
    """Error taxonomy (``core/`` only): the health plane charges blame
    by error *type* and ``endpoint_id`` (see ``core/errors.py``), so a
    bare ``raise Exception`` is unroutable and a blind ``except
    Exception: pass`` (or bare ``except:``) eats the signal breakers
    and retry budgets feed on.  Raise/catch the taxonomy types."""
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Raise):
            exc = node.exc
            if isinstance(exc, ast.Call):
                exc = exc.func
            if isinstance(exc, ast.Name) and exc.id == "Exception":
                out.append(Finding(
                    "R004", mod.rel, node.lineno,
                    "bare `raise Exception` — raise a type from the "
                    "core/errors.py taxonomy so blame charging works"))
        elif isinstance(node, ast.ExceptHandler):
            blind = node.type is None or (
                isinstance(node.type, ast.Name)
                and node.type.id in ("Exception", "BaseException"))
            swallows = len(node.body) == 1 \
                and isinstance(node.body[0], ast.Pass)
            if blind and swallows:
                out.append(Finding(
                    "R004", mod.rel, node.lineno,
                    "blind `except Exception: pass` — swallows the "
                    "failure signal the health plane charges blame "
                    "from; catch the taxonomy type (or log + re-raise)"))
    return out


# --------------------------------------------------------------------------
# R005 — publish never blocks
# --------------------------------------------------------------------------

#: blocking primitives forbidden anywhere reachable from publish
_R005_BANNED = {"sleep", "wait", "wait_for", "join", "acquire", "result"}


def check_r005(mod: ModuleInfo) -> list[Finding]:
    """Publish-never-blocks: ``StatusBus.publish`` runs inside the
    manager lock at every queue mutation, so anything reachable from it
    must be O(1) ring work — no sleeps, no ``wait``/``wait_for``/
    ``join``/``acquire``/future-``result``.  (Context-managed bus and
    subscription locks guard constant-time sections and are allowed;
    a *blocking* primitive under them is exactly what this rule
    catches.)  Checked over the intra-module call graph rooted at any
    ``StatusBus.publish`` definition."""
    # collect class methods (reachable via `obj.X(...)`) and module
    # functions (reachable via `X(...)`) separately, so a builtin like
    # `next(iter)` never resolves to a method named ``next``
    methods: dict[str, list[ast.FunctionDef]] = {}
    functions: dict[str, list[ast.FunctionDef]] = {}
    roots: list[ast.FunctionDef] = []
    method_ids: set[int] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, ast.FunctionDef):
                    methods.setdefault(item.name, []).append(item)
                    method_ids.add(id(item))
                    if node.name == "StatusBus" and item.name == "publish":
                        roots.append(item)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.FunctionDef) and id(node) not in method_ids:
            functions.setdefault(node.name, []).append(node)
    if not roots:
        return []
    # BFS over simple-name call edges
    seen: set[int] = set()
    frontier = list(roots)
    reachable: list[ast.FunctionDef] = []
    while frontier:
        fn = frontier.pop()
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        reachable.append(fn)
        for n in ast.walk(fn):
            if isinstance(n, ast.Call):
                if isinstance(n.func, ast.Attribute):
                    frontier.extend(methods.get(n.func.attr, []))
                    frontier.extend(functions.get(n.func.attr, []))
                elif isinstance(n.func, ast.Name):
                    frontier.extend(functions.get(n.func.id, []))
    out = []
    for fn in reachable:
        for n in ast.walk(fn):
            if isinstance(n, ast.Call) \
                    and isinstance(n.func, ast.Attribute) \
                    and n.func.attr in _R005_BANNED:
                out.append(Finding(
                    "R005", mod.rel, n.lineno,
                    f"`{_dotted(n.func) or n.func.attr}()` reachable "
                    f"from StatusBus.publish (via {fn.name}) — publish "
                    "must never block"))
    return out


# --------------------------------------------------------------------------
# R006 — span discipline
# --------------------------------------------------------------------------


def check_r006(mod: ModuleInfo) -> list[Finding]:
    """Span discipline: a ``*.span(...)`` call (``Tracer.span`` and any
    API shaped like it) may only appear as a ``with`` context
    expression.  A span opened and never exited stays the innermost
    span on its thread forever: every later ``Clock.sleep`` charge on
    that thread lands in the wrong category, silently corrupting the
    ``TaskStats.time_budget()`` decomposition — so the guard must be
    scope-shaped, never a bare call or a stored context manager."""
    with_exprs: set[int] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                with_exprs.add(id(item.context_expr))
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "span" \
                and id(node) not in with_exprs:
            out.append(Finding(
                "R006", mod.rel, node.lineno,
                f"`{_dotted(node.func) or '<expr>.span'}(...)` outside "
                "a `with` — Tracer.span is a context manager ONLY; a "
                "leaked open span miscategorizes every later charge on "
                "its thread"))
    return out


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

#: rule id -> (one-line title, checker)
RULES = {
    "R001": ("wall-clock ban (model Clock only)", check_r001),
    "R002": ("charge-owner propagation across threads/pools", check_r002),
    "R003": ("*_locked lock discipline", check_r003),
    "R004": ("core/ error taxonomy", check_r004),
    "R005": ("StatusBus.publish never blocks", check_r005),
    "R006": ("Tracer.span used as a `with` context manager", check_r006),
}
