"""Contract linter: AST-based invariant checker for the repo's
clock/charge/lock/health contracts.

The transfer stack's correctness rests on conventions no runtime test
can see from the outside: model-time-only sleeps charged to a bound
owner, third-party coordinators that never touch bytes, ``*_locked``
lock discipline, the breaker error taxonomy, and publish-never-blocks
in the service plane.  This package machine-checks them as named rules
(R001-R005, see :mod:`repro.lint.rules`), with per-line reasoned
suppressions and a committed budget (:mod:`repro.lint.engine`) so new
violations fail CI while grandfathered ones stay visible.

Run ``python -m repro.lint --check`` (the CI lint lane).
"""

from .engine import (LintReport, budget_violations, lint_file, load_budget,
                     run_lint, write_budget)
from .rules import RULES, Finding, ModuleInfo

__all__ = ["Finding", "LintReport", "ModuleInfo", "RULES",
           "budget_violations", "lint_file", "load_budget", "run_lint",
           "write_budget"]
