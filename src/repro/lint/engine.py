"""Walk + scope + suppress + budget: the linter's driver.

Scoping
-------
Rules apply by repo-relative path (so fixtures in a temp tree that
mirrors ``src/repro/...`` exercise the exact production scoping):

* **R001** everywhere under ``src/repro/`` except ``core/clock.py`` —
  the clock implementation is the one sanctioned owner of real time
  (including the ``wall_now``/``wall_sleep`` harness helpers).
* **R002** the transfer stack only (``core/``, ``connectors/``,
  ``fed/``, ``svc/``, ``catalog/``) — the layers whose model time is
  charge-accounted.
* **R003** everywhere (it only fires on the ``*_locked`` /
  ``self._lock`` idiom).
* **R004** ``core/`` only, where the breaker taxonomy is load-bearing.
* **R005** ``svc/`` (the ``StatusBus.publish`` entry point).
* **R006** everywhere (it only fires on the ``*.span(...)`` idiom —
  the observability plane's context-manager-only span discipline).

Suppressions
------------
One line, same line as the finding::

    t0 = time.monotonic()  # lint: disable=R001(wall_seconds is real elapsed time by design)

The parenthesized reason is REQUIRED — a reason-less suppression is
itself reported as ``R000`` and cannot be suppressed.  Multiple rules:
``# lint: disable=R001(why),R002(why)``.  Reasons may not contain
``)``.

Budget
------
``lint-budget.json`` (repo root) records the blessed suppression count
per ``(file, rule)``.  ``--check`` fails on any unsuppressed finding
AND on suppression growth past the budget — so a new violation cannot
ride in under a fresh ``disable`` comment without a reviewed budget
bump — while grandfathered suppressions stay visible in every report.
"""

from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass, field
from pathlib import Path

from .rules import RULES, Finding, ModuleInfo

#: default budget filename, at the repo root
BUDGET_FILE = "lint-budget.json"

#: files R001 does not apply to — the clock owns real time
R001_ALLOWLIST = {"src/repro/core/clock.py"}
#: transfer-stack prefixes R002 applies to
R002_SCOPE = ("src/repro/core/", "src/repro/connectors/",
              "src/repro/fed/", "src/repro/svc/", "src/repro/catalog/")
R004_SCOPE = ("src/repro/core/",)
R005_SCOPE = ("src/repro/svc/",)

_SUPPRESS_RE = re.compile(r"#\s*lint:\s*disable=(.*)$")
_ITEM_RE = re.compile(r"(R\d{3})\s*(?:\(([^)]*)\))?")


def rule_applies(rule: str, rel: str) -> bool:
    if rule == "R001":
        return rel not in R001_ALLOWLIST
    if rule == "R002":
        return rel.startswith(R002_SCOPE)
    if rule == "R004":
        return rel.startswith(R004_SCOPE)
    if rule == "R005":
        return rel.startswith(R005_SCOPE)
    return True


@dataclass
class Suppression:
    rule: str
    line: int
    reason: str
    used: bool = False


def parse_suppressions(rel: str, source: str
                       ) -> tuple[dict[tuple[str, int], Suppression],
                                  list[Finding]]:
    """Per-line ``# lint: disable=`` markers -> {(rule, line): Suppression},
    plus R000 findings for reason-less markers."""
    sups: dict[tuple[str, int], Suppression] = {}
    meta: list[Finding] = []
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m is None:
            continue
        items = list(_ITEM_RE.finditer(m.group(1)))
        if not items:
            meta.append(Finding(
                "R000", rel, lineno,
                "malformed suppression: expected R00x(reason)"))
            continue
        for item in items:
            rule, reason = item.group(1), (item.group(2) or "").strip()
            if not reason:
                meta.append(Finding(
                    "R000", rel, lineno,
                    f"suppression of {rule} carries no reason — every "
                    "disable must say why"))
                continue
            sups[(rule, lineno)] = Suppression(rule, lineno, reason)
    return sups, meta


@dataclass
class LintReport:
    """Everything one run produced, pre-budget-verdict."""

    findings: list[Finding] = field(default_factory=list)  # unsuppressed
    suppressed: list[Finding] = field(default_factory=list)
    #: R000 meta-findings (reason-less suppressions) + parse failures
    meta: list[Finding] = field(default_factory=list)
    unused_suppressions: list[Suppression] = field(default_factory=list)
    files_checked: int = 0

    def suppression_counts(self) -> dict[str, dict[str, int]]:
        out: dict[str, dict[str, int]] = {}
        for f in self.suppressed:
            out.setdefault(f.file, {}).setdefault(f.rule, 0)
            out[f.file][f.rule] += 1
        return out

    @property
    def failing(self) -> list[Finding]:
        return self.meta + self.findings


def lint_file(path: Path, rel: str) -> tuple[list[Finding], list[Finding],
                                             list[Suppression]]:
    """-> (unsuppressed, suppressed, unused suppressions) for one file.
    A file that does not parse is one R000 finding (the compile lane
    owns syntax errors; the linter just refuses to vouch for the file).
    """
    source = path.read_text(encoding="utf-8")
    sups, meta = parse_suppressions(rel, source)
    try:
        mod = ModuleInfo.parse(rel, source)
    except SyntaxError as e:
        return (meta + [Finding("R000", rel, e.lineno or 1,
                                f"does not parse: {e.msg}")], [], [])
    raw: list[Finding] = []
    for rule, (_title, check) in RULES.items():
        if rule_applies(rule, rel):
            raw.extend(check(mod))
    open_, closed = list(meta), []
    for f in sorted(raw, key=lambda f: (f.line, f.rule)):
        sup = sups.get((f.rule, f.line))
        if sup is not None:
            sup.used = True
            f.suppressed, f.reason = True, sup.reason
            closed.append(f)
        else:
            open_.append(f)
    unused = [s for s in sups.values() if not s.used]
    return open_, closed, unused


def iter_targets(root: Path, paths: list[str] | None) -> list[Path]:
    """Python files to lint: explicit paths (files or dirs), or the
    default ``src/repro`` tree under ``root``.  The linter's own
    package is excluded — its rule docs and regexes quote the very
    tokens the rules ban."""
    bases = [Path(p) if os.path.isabs(p) else root / p
             for p in (paths or ["src/repro"])]
    out: list[Path] = []
    for base in bases:
        if base.is_file():
            out.append(base)
        else:
            out.extend(p for p in sorted(base.rglob("*.py"))
                       if "lint" not in p.parts)
    return out


def run_lint(root: Path, paths: list[str] | None = None) -> LintReport:
    report = LintReport()
    for path in iter_targets(root, paths):
        rel = path.resolve().relative_to(root.resolve()).as_posix()
        open_, closed, unused = lint_file(path, rel)
        report.findings.extend(open_)
        report.suppressed.extend(closed)
        report.unused_suppressions.extend(unused)
        report.files_checked += 1
    # split R000 back out of findings (kept in order above for locality)
    report.meta = [f for f in report.findings if f.rule == "R000"]
    report.findings = [f for f in report.findings if f.rule != "R000"]
    return report


# --------------------------------------------------------------------------
# budget
# --------------------------------------------------------------------------


def load_budget(path: Path) -> dict[str, dict[str, int]]:
    if not path.exists():
        return {}
    data = json.loads(path.read_text(encoding="utf-8"))
    return data.get("suppressions", {})


def write_budget(path: Path, report: LintReport) -> None:
    payload = {
        "_comment": "Blessed # lint: disable= counts per (file, rule). "
                    "Grown only by review: regenerate with "
                    "`python -m repro.lint --write-budget`.",
        "suppressions": {f: dict(sorted(rules.items())) for f, rules in
                         sorted(report.suppression_counts().items())},
    }
    path.write_text(json.dumps(payload, indent=2) + "\n", encoding="utf-8")


def budget_violations(report: LintReport,
                      budget: dict[str, dict[str, int]]) -> list[str]:
    """Messages for every (file, rule) whose live suppression count
    exceeds its budgeted count (absent = 0): new violations must be
    fixed or get a reviewed budget bump, not a drive-by disable."""
    out = []
    for file, rules in sorted(report.suppression_counts().items()):
        for rule, n in sorted(rules.items()):
            allowed = budget.get(file, {}).get(rule, 0)
            if n > allowed:
                out.append(
                    f"{file}: {n} {rule} suppressions exceed the "
                    f"budgeted {allowed} — fix the new violation or "
                    f"regenerate lint-budget.json under review")
    return out
