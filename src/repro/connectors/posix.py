"""POSIX Connector — the paper's first and reference implementation
(Fig. 2).  Translates the Connector interface onto open/read/write/stat
against a real filesystem subtree."""

from __future__ import annotations

import os
import shutil
import threading

from ..core.connector import AppChannel, ByteRange, Connector, Session, StatInfo
from ..core.errors import NotFound, PermanentError


class PosixConnector(Connector):
    name = "posix"
    credential_scheme = "local-user"

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)

    # -- path safety -----------------------------------------------------
    def _abs(self, path: str) -> str:
        p = os.path.abspath(os.path.join(self.root, path.lstrip("/")))
        if not (p == self.root or p.startswith(self.root + os.sep)):
            raise PermanentError(f"path escapes connector root: {path}")
        return p

    def _rel(self, abspath: str) -> str:
        return os.path.relpath(abspath, self.root)

    # -- metadata --------------------------------------------------------
    def stat(self, session: Session, path: str) -> StatInfo:
        session.check()
        p = self._abs(path)
        try:
            st = os.stat(p)
        except FileNotFoundError:
            raise NotFound(path) from None
        return StatInfo(
            name=path,
            size=st.st_size,
            mtime=st.st_mtime,
            is_dir=os.path.isdir(p),
            mode=st.st_mode & 0o777,
            nlink=st.st_nlink,
            uid=st.st_uid,
            gid=st.st_gid,
        )

    def listdir(self, session: Session, path: str):
        session.check()
        p = self._abs(path)
        if not os.path.isdir(p):
            raise NotFound(path)
        out = []
        for entry in sorted(os.listdir(p)):
            child = os.path.join(p, entry)
            st = os.stat(child)
            out.append(
                StatInfo(
                    name=os.path.join(path, entry) if path not in (".", "") else entry,
                    size=st.st_size,
                    mtime=st.st_mtime,
                    is_dir=os.path.isdir(child),
                    mode=st.st_mode & 0o777,
                )
            )
        return out

    def command(self, session: Session, op: str, path: str, **kw) -> None:
        session.check()
        p = self._abs(path)
        if op == "mkdir":
            os.makedirs(p, exist_ok=True)
        elif op == "delete":
            if os.path.isdir(p):
                shutil.rmtree(p)
            elif os.path.exists(p):
                os.remove(p)
            else:
                raise NotFound(path)
        elif op == "rename":
            dst = self._abs(kw["to"])
            os.makedirs(os.path.dirname(dst), exist_ok=True)
            os.replace(p, dst)
        elif op == "chmod":
            os.chmod(p, kw["mode"])
        else:
            raise PermanentError(f"unknown command {op!r}")

    # -- data ------------------------------------------------------------
    def send(self, session: Session, path: str, channel: AppChannel) -> None:
        session.check()
        p = self._abs(path)
        try:
            size = os.path.getsize(p)
        except OSError:
            raise NotFound(path) from None
        if hasattr(channel, "set_size"):
            channel.set_size(size)
        cc = max(1, channel.get_concurrency())
        err: list[Exception] = []

        def worker() -> None:
            try:
                with open(p, "rb") as f:
                    while True:
                        rng = channel.get_read_range()
                        if rng is None or rng.offset >= size:
                            return
                        length = min(rng.length, size - rng.offset)
                        f.seek(rng.offset)
                        data = f.read(length)
                        channel.write(rng.offset, data)
            except Exception as e:  # pragma: no cover - surfaced below
                err.append(e)

        threads = [threading.Thread(target=worker, daemon=True) for _ in range(cc)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        channel.finished(err[0] if err else None)
        if err:
            raise err[0]

    def recv(self, session: Session, path: str, channel: AppChannel) -> None:
        session.check()
        p = self._abs(path)
        os.makedirs(os.path.dirname(p) or self.root, exist_ok=True)
        bs = channel.get_blocksize()
        lock = threading.Lock()
        err: list[Exception] = []
        # Pre-create / truncate once, then positional writes (supports
        # out-of-order + holey restart writes).
        with open(p, "ab"):
            pass
        f = open(p, "r+b")

        def worker() -> None:
            try:
                while True:
                    rng = channel.get_read_range()
                    if rng is None:
                        return
                    done = 0
                    while done < rng.length:
                        step = min(bs, rng.length - done)
                        data = channel.read(rng.offset + done, step)
                        if not data:
                            return
                        with lock:
                            f.seek(rng.offset + done)
                            f.write(data)
                        channel.bytes_written(rng.offset + done, len(data))
                        done += len(data)
            except Exception as e:
                err.append(e)
                try:  # wake sibling streams blocked on the channel
                    channel.finished(e)
                except Exception:
                    pass

        cc = max(1, channel.get_concurrency())
        threads = [threading.Thread(target=worker, daemon=True) for _ in range(cc)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        f.flush()
        os.fsync(f.fileno())
        f.close()
        channel.finished(err[0] if err else None)
        if err:
            raise err[0]
