"""POSIX Connector — the paper's first and reference implementation
(Fig. 2).  Translates the Connector interface onto open/read/write/stat
against a real filesystem subtree.

Bulk path: ``send_batch``/``recv_batch`` stream each file on the
session's shared worker pool (one pool per session, threads reused
across files and attempts) instead of spawning ``concurrency`` fresh
threads per file the way the per-file path must; directory listings use
``os.scandir`` so each entry's stat comes from the directory read
itself rather than a second syscall per child."""

from __future__ import annotations

import os
import shutil
import threading

from ..core.clock import bind_charge_owner
from ..core.connector import AppChannel, ByteRange, Connector, Session, StatInfo
from ..core.errors import NotFound, PermanentError


class PosixConnector(Connector):
    name = "posix"
    credential_scheme = "local-user"

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)

    # -- path safety -----------------------------------------------------
    def _abs(self, path: str) -> str:
        p = os.path.abspath(os.path.join(self.root, path.lstrip("/")))
        if not (p == self.root or p.startswith(self.root + os.sep)):
            raise PermanentError(f"path escapes connector root: {path}")
        return p

    def _rel(self, abspath: str) -> str:
        return os.path.relpath(abspath, self.root)

    # -- metadata --------------------------------------------------------
    def stat(self, session: Session, path: str) -> StatInfo:
        session.check()
        p = self._abs(path)
        try:
            st = os.stat(p)
        except FileNotFoundError:
            raise NotFound(path) from None
        return StatInfo(
            name=path,
            size=st.st_size,
            mtime=st.st_mtime,
            is_dir=os.path.isdir(p),
            mode=st.st_mode & 0o777,
            nlink=st.st_nlink,
            uid=st.st_uid,
            gid=st.st_gid,
        )

    def listdir(self, session: Session, path: str):
        session.check()
        p = self._abs(path)
        if not os.path.isdir(p):
            raise NotFound(path)
        out = []
        with os.scandir(p) as it:
            for entry in sorted(it, key=lambda e: e.name):
                st = entry.stat()
                out.append(
                    StatInfo(
                        name=os.path.join(path, entry.name)
                        if path not in (".", "") else entry.name,
                        size=st.st_size,
                        mtime=st.st_mtime,
                        is_dir=entry.is_dir(),
                        mode=st.st_mode & 0o777,
                    )
                )
        return out

    def command(self, session: Session, op: str, path: str, **kw) -> None:
        session.check()
        p = self._abs(path)
        if op == "mkdir":
            os.makedirs(p, exist_ok=True)
        elif op == "delete":
            if os.path.isdir(p):
                shutil.rmtree(p)
            elif os.path.exists(p):
                os.remove(p)
            else:
                raise NotFound(path)
        elif op == "rename":
            dst = self._abs(kw["to"])
            os.makedirs(os.path.dirname(dst), exist_ok=True)
            os.replace(p, dst)
        elif op == "chmod":
            os.chmod(p, kw["mode"])
        else:
            raise PermanentError(f"unknown command {op!r}")

    # -- data ------------------------------------------------------------
    def _send_stream(self, p: str, size: int, channel: AppChannel) -> None:
        """One claim-read-write stream (one open handle per stream)."""
        with open(p, "rb") as f:
            while True:
                rng = channel.get_read_range()
                if rng is None or rng.offset >= size:
                    return
                length = min(rng.length, size - rng.offset)
                f.seek(rng.offset)
                data = f.read(length)
                channel.write(rng.offset, data)

    def _recv_stream(self, f, lock, bs: int, channel: AppChannel) -> None:
        """One claim-read-write stream into an open positional handle."""
        while True:
            rng = channel.get_read_range()
            if rng is None:
                return
            done = 0
            while done < rng.length:
                step = min(bs, rng.length - done)
                data = channel.read(rng.offset + done, step)
                if not data:
                    return
                if lock is not None:
                    with lock:
                        f.seek(rng.offset + done)
                        f.write(data)
                else:
                    f.seek(rng.offset + done)
                    f.write(data)
                channel.bytes_written(rng.offset + done, len(data))
                done += len(data)

    def send(self, session: Session, path: str, channel: AppChannel) -> None:
        session.check()
        p = self._abs(path)
        try:
            size = os.path.getsize(p)
        except OSError:
            raise NotFound(path) from None
        if hasattr(channel, "set_size"):
            channel.set_size(size)
        cc = max(1, channel.get_concurrency())
        err: list[Exception] = []

        def worker() -> None:
            try:
                self._send_stream(p, size, channel)
            except Exception as e:  # pragma: no cover - surfaced below
                err.append(e)

        threads = [threading.Thread(target=bind_charge_owner(worker),
                                    daemon=True) for _ in range(cc)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        channel.finished(err[0] if err else None)
        if err:
            raise err[0]

    def _open_recv(self, path: str):
        p = self._abs(path)
        os.makedirs(os.path.dirname(p) or self.root, exist_ok=True)
        # Pre-create / truncate once, then positional writes (supports
        # out-of-order + holey restart writes).
        with open(p, "ab"):
            pass
        return open(p, "r+b")

    def recv(self, session: Session, path: str, channel: AppChannel) -> None:
        session.check()
        bs = channel.get_blocksize()
        lock = threading.Lock()
        err: list[Exception] = []
        f = self._open_recv(path)

        def worker() -> None:
            try:
                self._recv_stream(f, lock, bs, channel)
            except Exception as e:
                err.append(e)
                try:  # wake sibling streams blocked on the channel
                    channel.finished(e)
                except Exception:
                    pass

        cc = max(1, channel.get_concurrency())
        threads = [threading.Thread(target=bind_charge_owner(worker),
                                    daemon=True) for _ in range(cc)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        f.flush()
        os.fsync(f.fileno())
        f.close()
        channel.finished(err[0] if err else None)
        if err:
            raise err[0]

    # -- bulk data plane --------------------------------------------------
    def send_batch(self, session: Session, paths, channel_factory) -> None:
        """Native batch Send: one single-stream task per file on the
        session's shared pool (threads reused across files/attempts);
        errors contained per file via ``channel.finished``."""
        session.check()

        def one(path: str, channel: AppChannel) -> None:
            try:
                p = self._abs(path)
                try:
                    size = os.path.getsize(p)
                except OSError:
                    raise NotFound(path) from None
                if hasattr(channel, "set_size"):
                    channel.set_size(size)
                self._send_stream(p, size, channel)
                channel.finished(None)
            except Exception as e:
                channel.finished(e)

        self._dispatch_batch(session, paths, channel_factory, one)

    def recv_batch(self, session: Session, paths, channel_factory) -> None:
        """Native batch Recv — single stream + private handle per file,
        no cross-stream handle lock needed."""
        session.check()

        def one(path: str, channel: AppChannel) -> None:
            try:
                f = self._open_recv(path)
                try:
                    self._recv_stream(f, None, channel.get_blocksize(), channel)
                    f.flush()
                    os.fsync(f.fileno())
                finally:
                    f.close()
                channel.finished(None)
            except Exception as e:
                channel.finished(e)

        self._dispatch_batch(session, paths, channel_factory, one)
