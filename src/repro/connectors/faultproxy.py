"""Fault-proxy Connector: chaos injection for *any* storage backend.

:class:`FaultProxyConnector` wraps an inner :class:`Connector` and
delegates every interface call — ``stat`` / ``listdir`` / ``command`` /
``send`` / ``recv`` / ``send_batch`` / ``recv_batch`` / ``checksum`` /
session lifecycle — after admitting it through a
:class:`~repro.core.faults.FaultSchedule`.  Unlike the old ad-hoc
``CloudStorage.fault_plan`` hook (which could only fail emulated cloud
API calls), the proxy makes the same composable failure plan work
against posix, memory, cloud, or any future connector, because it
attacks the *interface*, not one implementation.

Where each fault kind lands
---------------------------
* control-plane kinds (transient / rate-limit / session-drop / latency)
  fire at op admission, plus per-block on the pseudo-ops ``read`` (data
  flowing into storage on the recv side) and ``write`` (data flowing out
  of storage on the send side), so mid-stream failures hit after real
  progress has been made and restart markers matter;
* data-plane kinds (``bit_flip``, ``truncate``) are applied to blocks a
  destination connector reads from the application — i.e. bytes about to
  be *written to storage*.  Corrupting the send side instead would also
  corrupt the service's streaming source checksum and turn the fault
  into silent, undetectable corruption; flipping the storage-bound copy
  is exactly the §7 scenario that end-to-end integrity catches.

``destroy`` is deliberately never faulted, so session teardown (worker
pools, file handles) always runs and a chaos run can't leak resources.

The proxy is transparent: unknown attributes (``location``,
``placement``, ``storage``, ``store``, ``root``, ...) forward to the
inner connector, so link selection and test helpers keep working.
"""

from __future__ import annotations

from ..core.clock import DEFAULT_CLOCK
from ..core.connector import (AppChannel, ByteRange, Connector, Credential,
                              Session, StatInfo)
from ..core.faults import FaultSchedule, StreamFaults


class _ChaosRecvChannel(AppChannel):
    """Wraps the recv-side AppChannel: per-block ``read`` admission plus
    this attempt's data directives (bit-flip / truncate)."""

    def __init__(self, inner: AppChannel, schedule: FaultSchedule,
                 path: str, stream: StreamFaults):
        self._inner = inner
        self._schedule = schedule
        self._path = path
        self._stream = stream
        self._cut = False

    def write(self, offset: int, data: bytes) -> None:
        self._inner.write(offset, data)

    def read(self, offset: int, length: int) -> bytes:
        self._schedule.check("read", self._path)
        if self._cut:
            return b""
        data = self._inner.read(offset, length)
        out = self._stream.filter(offset, data)
        if data and not out:
            # the stream was cut: stop consuming, or positional readers
            # (length-driven loops) would mis-sequence later blocks
            self._cut = True
        elif out is not data and len(out) < len(data):
            self._cut = True  # truncated mid-block: deliver tail of nothing
        return out

    def get_concurrency(self) -> int:
        return self._inner.get_concurrency()

    def get_blocksize(self) -> int:
        return self._inner.get_blocksize()

    def get_read_range(self) -> ByteRange | None:
        if self._cut:
            return None
        return self._inner.get_read_range()

    def bytes_written(self, offset: int, length: int) -> None:
        self._inner.bytes_written(offset, length)

    def finished(self, error: Exception | None = None) -> None:
        self._inner.finished(error)


class _ChaosSendChannel(AppChannel):
    """Wraps the send-side AppChannel: per-block ``write`` admission.
    No data mutation here — see the module docstring."""

    def __init__(self, inner: AppChannel, schedule: FaultSchedule, path: str):
        self._inner = inner
        self._schedule = schedule
        self._path = path

    def set_size(self, size: int) -> None:
        fn = getattr(self._inner, "set_size", None)
        if fn is not None:
            fn(size)

    def write(self, offset: int, data: bytes) -> None:
        self._schedule.check("write", self._path)
        self._inner.write(offset, data)

    def read(self, offset: int, length: int) -> bytes:
        return self._inner.read(offset, length)

    def get_concurrency(self) -> int:
        return self._inner.get_concurrency()

    def get_blocksize(self) -> int:
        return self._inner.get_blocksize()

    def get_read_range(self) -> ByteRange | None:
        return self._inner.get_read_range()

    def bytes_written(self, offset: int, length: int) -> None:
        self._inner.bytes_written(offset, length)

    def finished(self, error: Exception | None = None) -> None:
        self._inner.finished(error)


class FaultProxyConnector(Connector):
    """Wrap ``inner`` so every op replays ``schedule`` faults first.

    Sessions are the inner connector's own sessions, so wrapped and bare
    access can share state and ``Session.check`` semantics carry over.
    """

    def __init__(self, inner: Connector, schedule: FaultSchedule,
                 clock=None):
        self.inner = inner
        self.schedule = schedule
        self.name = f"chaos[{inner.name}]"
        self.credential_scheme = inner.credential_scheme
        if schedule.clock is None:
            schedule.clock = clock or getattr(inner, "clock", None) \
                or DEFAULT_CLOCK

    # -- transparency -----------------------------------------------------
    def __getattr__(self, item):
        # only consulted for attributes not found on the proxy itself:
        # location/placement/storage/store/root/... forward to the inner
        # connector so link inference and test helpers see through us
        return getattr(self.inner, item)

    # -- lifecycle --------------------------------------------------------
    def start(self, credential: Credential | None = None) -> Session:
        self.schedule.check("start", self.inner.name)
        return self.inner.start(credential)

    def destroy(self, session: Session) -> None:
        self.inner.destroy(session)  # never faulted: cleanup must run

    def set_credential(self, session: Session,
                       credential: Credential | None) -> None:
        self.inner.set_credential(session, credential)

    # -- metadata ---------------------------------------------------------
    def stat(self, session: Session, path: str) -> StatInfo:
        self.schedule.check("stat", path)
        return self.inner.stat(session, path)

    def listdir(self, session: Session, path: str):
        self.schedule.check("listdir", path)
        return self.inner.listdir(session, path)

    def command(self, session: Session, op: str, path: str, **kw) -> None:
        self.schedule.check("command", path)
        self.inner.command(session, op, path, **kw)

    # -- data -------------------------------------------------------------
    def send(self, session: Session, path: str, channel: AppChannel) -> None:
        self.schedule.check("send", path)
        self.inner.send(session, path,
                        _ChaosSendChannel(channel, self.schedule, path))

    def recv(self, session: Session, path: str, channel: AppChannel) -> None:
        self.schedule.check("recv", path)
        self.inner.recv(session, path, self._wrap_recv(path, channel))

    def _wrap_recv(self, path: str, channel: AppChannel) -> AppChannel:
        stream = self.schedule.data_plan("recv", path)
        return _ChaosRecvChannel(channel, self.schedule, path, stream)

    # -- bulk data plane --------------------------------------------------
    def send_batch(self, session: Session, paths, channel_factory) -> None:
        paths = list(paths)
        self.schedule.check("send_batch", paths[0] if paths else "")

        def factory(path: str):
            ch = channel_factory(path)
            if ch is None:
                return None
            return _ChaosSendChannel(ch, self.schedule, path)

        self.inner.send_batch(session, paths, factory)

    def recv_batch(self, session: Session, paths, channel_factory) -> None:
        paths = list(paths)
        self.schedule.check("recv_batch", paths[0] if paths else "")

        def factory(path: str):
            ch = channel_factory(path)
            if ch is None:
                return None
            return self._wrap_recv(path, ch)

        self.inner.recv_batch(session, paths, factory)

    # -- optional capabilities --------------------------------------------
    def checksum(self, session: Session, path: str, algorithm: str) -> str:
        self.schedule.check("checksum", path)
        return self.inner.checksum(session, path, algorithm)

    def preferred_blocksize(self) -> int:
        return self.inner.preferred_blocksize()

    def supports_ranged_read(self) -> bool:
        return self.inner.supports_ranged_read()

    def __repr__(self) -> str:  # pragma: no cover
        return f"<FaultProxyConnector over {self.inner!r}>"
