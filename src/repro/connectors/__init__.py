"""Connector implementations (paper §4): POSIX + six emulated cloud
storage services (AWS-S3, Wasabi, Google-Cloud, Google-Drive, Box, Ceph)
plus an in-memory store for tests."""

from .posix import PosixConnector
from .memory import MemoryConnector
from .cloud import (
    CloudStorage,
    ObjectStoreConnector,
    NativeClient,
    make_cloud,
    PROFILES,
)

__all__ = [
    "PosixConnector",
    "MemoryConnector",
    "CloudStorage",
    "ObjectStoreConnector",
    "NativeClient",
    "make_cloud",
    "PROFILES",
]
