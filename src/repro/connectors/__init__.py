"""Connector implementations (paper §4): POSIX + six emulated cloud
storage services (AWS-S3, Wasabi, Google-Cloud, Google-Drive, Box, Ceph),
an in-memory store for tests, and a fault-proxy wrapper that replays a
:class:`~repro.core.faults.FaultSchedule` against any of them."""

from .posix import PosixConnector
from .memory import MemoryConnector
from .cloud import (
    CloudStorage,
    ObjectStoreConnector,
    NativeClient,
    make_cloud,
    PROFILES,
)
from .faultproxy import FaultProxyConnector

__all__ = [
    "PosixConnector",
    "MemoryConnector",
    "CloudStorage",
    "ObjectStoreConnector",
    "NativeClient",
    "make_cloud",
    "PROFILES",
    "FaultProxyConnector",
]
