"""Emulated cloud object stores + their Connectors (paper §4-§6).

The container is offline, so each provider (AWS-S3, Wasabi, Google-Cloud,
Google-Drive, Box, Ceph) is emulated as a :class:`CloudStorage` service:
a blob namespace fronted by a native API whose calls cost request
round-trips, payload transmission on a network link, API-processing
latency, and call-quota tokens (Drive/Box throttle, paper §4).  All
constants are *model seconds* scaled by ``REPRO_TIME_SCALE``
(see ``repro.core.clock``).

Two access paths exist, matching the paper's experiment design:

* :class:`NativeClient` — the two-party baseline ("boto3"), running at
  the science institution, calling the native API over the WAN.
* :class:`ObjectStoreConnector` — the Connector, deployed either
  ``placement="local"`` (institution DTN, native API over WAN — Fig. 4)
  or ``placement="cloud"`` (VM next to the storage, native API over LAN,
  GridFTP handles the WAN hop — Fig. 5).
"""

from __future__ import annotations

import threading
import warnings
from dataclasses import dataclass, field, replace

from ..core.clock import (Clock, DEFAULT_CLOCK, Link, TokenBucket,
                          bind_charge_owner)
from ..core.connector import AppChannel, Connector, Credential, Session, StatInfo
from ..core.errors import AuthError, FaultInjected, NotFound, RateLimitError
from ..core.faults import FaultSchedule
from .memory import BlobDict

MB = 1024 * 1024


@dataclass(frozen=True)
class StorageProfile:
    """Per-provider native-API characteristics (model units)."""

    provider: str
    api_latency: float          # service-side processing per call (s)
    put_calls: int              # control round-trips per object PUT
    get_calls: int              # control round-trips per object GET
    quota_rate: float           # API calls per second (token bucket)
    quota_burst: float
    intra_bw: float             # service-internal per-object-stream cap (B/s)
    native_put_streams: int = 1  # native SDK internal parallelism (multipart)
    native_get_streams: int = 1
    credential_scheme: str = "s3-keypair"
    consistency_delay: float = 0.0  # eventual visibility of fresh objects


#: §4's six providers.  Constants chosen so the *relative* behaviour
#: matches the paper's measurements (Figs. 6-17): S3-family APIs are
#: fast w/ generous quotas; Drive/Box have high per-call latency and
#: tight call quotas; Ceph is institution-grade (low latency).
PROFILES: dict[str, StorageProfile] = {
    "s3": StorageProfile("s3", api_latency=0.020, put_calls=2, get_calls=1,
                         quota_rate=1000, quota_burst=2000, intra_bw=300 * MB,
                         native_put_streams=4, native_get_streams=2),
    "wasabi": StorageProfile("wasabi", api_latency=0.035, put_calls=2, get_calls=1,
                             quota_rate=500, quota_burst=1000, intra_bw=220 * MB,
                             native_put_streams=2, native_get_streams=2),
    "gcs": StorageProfile("gcs", api_latency=0.025, put_calls=2, get_calls=1,
                          quota_rate=1000, quota_burst=2000, intra_bw=280 * MB,
                          native_put_streams=2, native_get_streams=2,
                          credential_scheme="oauth2-token"),
    "drive": StorageProfile("drive", api_latency=0.180, put_calls=3, get_calls=2,
                            quota_rate=10, quota_burst=25, intra_bw=25 * MB,
                            credential_scheme="oauth2-token",
                            consistency_delay=0.5),
    "box": StorageProfile("box", api_latency=0.140, put_calls=3, get_calls=2,
                          quota_rate=16, quota_burst=32, intra_bw=30 * MB,
                          credential_scheme="oauth2-token",
                          consistency_delay=0.5),
    "ceph": StorageProfile("ceph", api_latency=0.004, put_calls=2, get_calls=1,
                           quota_rate=5000, quota_burst=10000, intra_bw=400 * MB),
}


def wan_link(clock: Clock | None = None) -> Link:
    """Institution <-> cloud WAN (iperf-calibrated vs paper §6: ~4-7
    Gbps aggregate, single TCP stream ~40 MB/s)."""
    return Link("wan", rtt=0.030, per_stream_bw=40 * MB, aggregate_bw=600 * MB,
                clock=clock or DEFAULT_CLOCK)


def lan_link(clock: Clock | None = None) -> Link:
    """In-cloud VM <-> storage frontend."""
    return Link("lan", rtt=0.001, per_stream_bw=300 * MB, aggregate_bw=2500 * MB,
                clock=clock or DEFAULT_CLOCK)


class CloudStorage:
    """The provider-side service: blobs + native API semantics."""

    def __init__(self, profile: StorageProfile, clock: Clock | None = None,
                 fault_plan=None, faults: FaultSchedule | None = None):
        self.profile = profile
        self.clock = clock or DEFAULT_CLOCK
        # model-clock mtimes: the (size, mtime) stat signature stays
        # deterministic across same-seed runs (see BlobDict._stamp)
        self.blobs = BlobDict(clock=self.clock)
        self.quota = TokenBucket(profile.quota_rate, profile.quota_burst, self.clock)
        #: shared fault-injection plan, replayed at API admission with
        #: op names "put"/"put_part"/"get"/"stat"/"list"/"delete"/
        #: "complete"/"checksum"/"copy" and the object key as the path
        self.faults = faults or FaultSchedule()
        if self.faults.clock is None:
            self.faults.clock = self.clock
        self._fault_plan = None
        if fault_plan is not None:
            self.fault_plan = fault_plan  # deprecation warning via setter
        self._op_index = 0
        self._fresh: dict[str, float] = {}  # key -> visible-at (virtual s)
        self._lock = threading.Lock()

    @property
    def fault_plan(self):
        """Deprecated ad-hoc hook ``callable(op, index) -> bool(fail?)``;
        use ``faults=FaultSchedule(...)`` instead."""
        return self._fault_plan

    @fault_plan.setter
    def fault_plan(self, fn) -> None:
        if fn is not None:
            warnings.warn(
                "CloudStorage.fault_plan is deprecated; compose a "
                "repro.core.faults.FaultSchedule and pass it as "
                "CloudStorage(faults=...) (or wrap any connector in "
                "FaultProxyConnector)", DeprecationWarning, stacklevel=2)
        self._fault_plan = fn

    # -- plumbing ---------------------------------------------------------
    def _admit(self, op: str, calls: int, link: Link,
               pipeline: "ApiPipeline | None" = None, key: str = "") -> None:
        with self._lock:
            self._op_index += 1
            idx = self._op_index
        if self._fault_plan is not None and self._fault_plan(op, idx):
            raise FaultInjected(f"{self.profile.provider}:{op}#{idx}")
        self.faults.check(op, key)
        wait = self.quota.try_acquire(calls)
        if wait > 0:
            raise RateLimitError(
                f"{self.profile.provider} API quota exceeded", retry_after=wait)
        if pipeline is not None:
            pipeline.charge(calls)
        else:
            link.round_trip(calls)
            self.clock.sleep(self.profile.api_latency * calls)

    def _mark_fresh(self, key: str) -> None:
        if self.profile.consistency_delay > 0:
            with self._lock:
                self._fresh[key] = (self.clock.virtual_elapsed
                                    + self.profile.consistency_delay)

    def _visible(self, key: str) -> bool:
        if self.profile.consistency_delay <= 0:
            return True
        with self._lock:
            t = self._fresh.get(key)
            if t is None or self.clock.virtual_elapsed >= t:
                self._fresh.pop(key, None)
                return True
            return False

    def _payload(self, link: Link, nbytes: int, streams: int) -> None:
        # Payload pays the slower of the network hop and the service's
        # internal media bandwidth.
        if nbytes <= 0:
            return
        link.transmit(nbytes, streams=streams)
        self.clock.sleep(nbytes / self.profile.intra_bw)

    # -- native API (boto3-ish) --------------------------------------------
    def api_put(self, key: str, data: bytes, link: Link, streams: int = 1,
                pipeline: "ApiPipeline | None" = None) -> None:
        self._admit("put", self.profile.put_calls, link, pipeline, key)
        self._payload(link, len(data), streams)
        self.blobs.put(key, data)
        self._mark_fresh(key)

    def api_put_range(self, key: str, offset: int, data: bytes, link: Link,
                      streams: int = 1,
                      pipeline: "ApiPipeline | None" = None) -> None:
        """One part of a multipart upload (1 call per part)."""
        self._admit("put_part", 1, link, pipeline, key)
        self._payload(link, len(data), streams)
        self.blobs.put_range(key, offset, data)
        self._mark_fresh(key)

    def api_complete_multipart(self, key: str, link: Link,
                               pipeline: "ApiPipeline | None" = None) -> None:
        self._admit("complete", 1, link, pipeline, key)

    def api_get(self, key: str, link: Link, offset: int = 0,
                length: int | None = None, streams: int = 1,
                pipeline: "ApiPipeline | None" = None) -> bytes:
        self._admit("get", self.profile.get_calls, link, pipeline, key)
        if not self.blobs.exists(key):
            raise NotFound(key)
        size = self.blobs.size(key)
        if length is None:
            length = size - offset
        data = self.blobs.get_range(key, offset, min(length, max(0, size - offset)))
        self._payload(link, len(data), streams)
        return data

    def api_stat(self, key: str, link: Link,
                 pipeline: "ApiPipeline | None" = None) -> StatInfo:
        self._admit("stat", 1, link, pipeline, key)
        if self.blobs.exists(key) and self._visible(key):
            return StatInfo(name=key, size=self.blobs.size(key),
                            mtime=self.blobs.mtime(key))
        objs, dirs = self.blobs.list_prefix(key)
        if objs or dirs or key == "":
            return StatInfo(name=key, size=0, mtime=0.0, is_dir=True)
        raise NotFound(key)

    def api_list(self, prefix: str, link: Link) -> tuple[list[str], list[str]]:
        self._admit("list", 1, link, key=prefix)
        objs, dirs = self.blobs.list_prefix(prefix)
        return [k for k in objs if self._visible(k)], dirs

    def api_delete(self, key: str, link: Link) -> None:
        self._admit("delete", 1, link, key=key)
        self.blobs.delete(key)

    def api_checksum(self, key: str, link: Link, algorithm: str) -> str:
        """Server-side checksum (beyond-paper optimization; real stores
        expose ETag/x-goog-hash/GetObjectAttributes).  Costs one control
        round-trip + a service-internal read — NO egress re-read, which
        is the §7/§8.2 integrity tax this eliminates."""
        self._admit("checksum", 1, link, key=key)
        data = self.blobs.get(key)
        self.clock.sleep(len(data) / self.profile.intra_bw)
        from ..core.integrity import hasher
        h = hasher(algorithm)
        h.update(data)
        return h.hexdigest()


class ApiPipeline:
    """A persistent connection keeping up to ``depth`` requests in
    flight against the provider frontend (HTTP pipelining — the same
    amortization GridFTP command pipelining gives the control channel,
    paper §5.3.2 / §8).  Round-trip latency and service-side processing
    overlap across the in-flight window, so each admitted call costs
    ~1/depth of the serial price.  Quota accounting is **not**
    amortized: providers meter API calls, not connections, so
    RateLimitError still fires exactly as it would per-call."""

    def __init__(self, storage: CloudStorage, link: Link, depth: int = 8):
        self.storage = storage
        self.link = link
        self.depth = max(1, depth)

    def charge(self, calls: int) -> None:
        self.storage.clock.sleep(
            (self.link.rtt + self.storage.profile.api_latency * calls)
            / self.depth)


def make_cloud(provider: str, clock: Clock | None = None,
               faults: FaultSchedule | None = None, **overrides) -> CloudStorage:
    prof = PROFILES[provider]
    if overrides:
        prof = replace(prof, **overrides)
    return CloudStorage(prof, clock=clock, faults=faults)


class ObjectStoreConnector(Connector):
    """Connector over a :class:`CloudStorage` native API (paper §4).

    ``placement="local"``: runs on an institution DTN; every API call
    crosses the WAN (Fig. 4).  ``placement="cloud"``: runs on a VM next
    to the storage; API calls are LAN-local and the WAN hop is handled
    by the GridFTP data channel (Fig. 5).
    """

    def __init__(self, storage: CloudStorage, placement: str = "local",
                 clock: Clock | None = None, part_size: int = 8 * MB,
                 server_checksum: bool = False, pipeline_depth: int = 8):
        self.storage = storage
        self.placement = placement
        self.clock = clock or storage.clock
        self.part_size = part_size
        self.server_checksum = server_checksum
        self.pipeline_depth = max(1, pipeline_depth)
        self.name = f"{storage.profile.provider}-conn-{placement}"
        self.credential_scheme = storage.profile.credential_scheme
        self.access_link = (lan_link(self.clock) if placement == "cloud"
                            else wan_link(self.clock))

    def checksum(self, session: Session, path: str, algorithm: str) -> str:
        if self.server_checksum:
            session.check()
            return self.storage.api_checksum(self._key(path),
                                             self.access_link, algorithm)
        return super().checksum(session, path, algorithm)

    # -- auth (paper Fig. 3) ----------------------------------------------
    def set_credential(self, session: Session, credential: Credential | None) -> None:
        if credential is None or credential.scheme != self.credential_scheme:
            raise AuthError(
                f"{self.name} requires credential scheme "
                f"{self.credential_scheme!r}, got "
                f"{credential.scheme if credential else None!r}")
        session.credential = credential

    @staticmethod
    def _key(path: str) -> str:
        return path.strip("/")

    # -- metadata ----------------------------------------------------------
    def stat(self, session: Session, path: str) -> StatInfo:
        session.check()
        return self.storage.api_stat(self._key(path), self.access_link)

    def listdir(self, session: Session, path: str):
        session.check()
        objs, dirs = self.storage.api_list(self._key(path), self.access_link)
        out = [StatInfo(name=k, size=self.storage.blobs.size(k),
                        mtime=self.storage.blobs.mtime(k)) for k in objs]
        out += [StatInfo(name=d, size=0, mtime=0.0, is_dir=True) for d in dirs]
        return out

    def command(self, session: Session, op: str, path: str, **kw) -> None:
        session.check()
        key = self._key(path)
        if op == "mkdir":
            return
        if op == "delete":
            self.storage.api_delete(key, self.access_link)
        elif op == "rename":
            to = self._key(kw["to"])
            if self.storage.blobs.exists(key):
                data = self.storage.api_get(key, self.access_link)
                self.storage.api_put(to, data, self.access_link)
                self.storage.api_delete(key, self.access_link)
                return
            # prefix rename = server-side copy per object (no data move
            # through the connector; one API call each)
            objs = [k for k in self.storage.blobs.keys()
                    if k.startswith(key + "/")]
            if not objs:
                raise NotFound(path)
            for k in objs:
                self._admit_copy(k)
                self.storage.blobs.put(to + k[len(key):],
                                       self.storage.blobs.get(k))
                self.storage.blobs.delete(k)
        else:
            raise NotFound(op)

    def _admit_copy(self, key: str) -> None:
        """Server-side COPY: control-plane cost only."""
        self.storage._admit("copy", 1, self.access_link, key=key)

    # -- data ----------------------------------------------------------------
    def send(self, session: Session, path: str, channel: AppChannel) -> None:
        session.check()
        key = self._key(path)
        size = self.storage.api_stat(key, self.access_link).size
        if hasattr(channel, "set_size"):
            channel.set_size(size)
        err: list[Exception] = []

        def worker() -> None:
            try:
                while not err:
                    rng = channel.get_read_range()
                    if rng is None or rng.offset >= size:
                        return
                    length = min(rng.length, size - rng.offset)
                    data = self.storage.api_get(key, self.access_link,
                                                offset=rng.offset, length=length)
                    channel.write(rng.offset, data)
            except Exception as e:
                err.append(e)

        self._pool(channel, worker)
        channel.finished(err[0] if err else None)
        if err:
            raise err[0]

    def recv(self, session: Session, path: str, channel: AppChannel) -> None:
        session.check()
        key = self._key(path)
        err: list[Exception] = []
        wrote = [False]

        def worker() -> None:
            try:
                while not err:
                    rng = channel.get_read_range()
                    if rng is None:
                        return
                    done = 0
                    while done < rng.length:
                        step = min(self.part_size, rng.length - done)
                        data = channel.read(rng.offset + done, step)
                        if not data:
                            return
                        # parts may land out of order -> multipart semantics
                        self.storage.api_put_range(key, rng.offset + done,
                                                   data, self.access_link)
                        wrote[0] = True
                        channel.bytes_written(rng.offset + done, len(data))
                        done += len(data)
            except Exception as e:
                err.append(e)
                try:  # wake sibling streams blocked on the channel
                    channel.finished(e)
                except Exception:
                    pass

        self._pool(channel, worker)
        if wrote[0] and not err:
            self.storage.api_complete_multipart(key, self.access_link)
        elif not err and not self.storage.blobs.exists(key):
            # nothing claimed = zero-byte target: a real store would
            # still create the (empty) object
            self.storage.api_put(key, b"", self.access_link)
        channel.finished(err[0] if err else None)
        if err:
            raise err[0]

    def _pool(self, channel: AppChannel, worker) -> None:
        cc = max(1, channel.get_concurrency())
        worker = bind_charge_owner(worker)
        threads = [threading.Thread(target=worker, daemon=True) for _ in range(cc)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    # -- bulk data plane ----------------------------------------------------
    def _batch_pipeline(self, n_files: int) -> ApiPipeline:
        # a pipeline can't be deeper than the requests actually in it
        return ApiPipeline(self.storage, self.access_link,
                           depth=min(self.pipeline_depth, max(1, n_files)))

    def send_batch(self, session: Session, paths, channel_factory) -> None:
        """Native batch Send: per-object stat + GET issued through one
        request pipeline (amortized admission), files spread over the
        session's shared worker pool."""
        session.check()
        paths = list(paths)
        pipeline = self._batch_pipeline(len(paths))

        def one(path: str, channel: AppChannel) -> None:
            try:
                key = self._key(path)
                size = self.storage.api_stat(key, self.access_link,
                                             pipeline=pipeline).size
                if hasattr(channel, "set_size"):
                    channel.set_size(size)
                while True:
                    rng = channel.get_read_range()
                    if rng is None or rng.offset >= size:
                        break
                    length = min(rng.length, size - rng.offset)
                    data = self.storage.api_get(key, self.access_link,
                                                offset=rng.offset, length=length,
                                                pipeline=pipeline)
                    channel.write(rng.offset, data)
                channel.finished(None)
            except Exception as e:
                channel.finished(e)

        self._dispatch_batch(session, paths, channel_factory, one,
                             pool_size=self.pipeline_depth)

    def recv_batch(self, session: Session, paths, channel_factory) -> None:
        """Native batch Recv: grouped small objects go up as pipelined
        single-shot PUTs (no per-object multipart complete); holey
        restarts fall back to pipelined part uploads."""
        session.check()
        paths = list(paths)
        pipeline = self._batch_pipeline(len(paths))

        def one(path: str, channel: AppChannel) -> None:
            try:
                key = self._key(path)
                parts: list[tuple[int, bytes]] = []
                while True:
                    rng = channel.get_read_range()
                    if rng is None:
                        break
                    done = 0
                    while done < rng.length:
                        step = min(self.part_size, rng.length - done)
                        data = channel.read(rng.offset + done, step)
                        if not data:
                            break
                        parts.append((rng.offset + done, data))
                        done += len(data)
                if not parts:  # nothing claimed: zero-byte target — still
                    # create the (empty) object, matching per-file recv
                    if not self.storage.blobs.exists(key):
                        self.storage.api_put(key, b"", self.access_link,
                                             pipeline=pipeline)
                    channel.finished(None)
                    return
                parts.sort()
                # single-shot PUT only for a complete fresh object: a
                # resumed upload may be filling a *prefix* hole, and a
                # whole-object PUT would truncate the tail already in
                # storage — those must go through ranged part uploads
                contiguous = parts[0][0] == 0 and all(
                    a + len(d) == b for (a, d), (b, _) in zip(parts, parts[1:]))
                if contiguous and not self.storage.blobs.exists(key):
                    self.storage.api_put(key, b"".join(d for _, d in parts),
                                         self.access_link, pipeline=pipeline)
                else:
                    for off, data in parts:
                        self.storage.api_put_range(key, off, data,
                                                   self.access_link,
                                                   pipeline=pipeline)
                    self.storage.api_complete_multipart(key, self.access_link,
                                                        pipeline=pipeline)
                for off, data in parts:
                    channel.bytes_written(off, len(data))
                channel.finished(None)
            except Exception as e:
                channel.finished(e)

        self._dispatch_batch(session, paths, channel_factory, one,
                             pool_size=self.pipeline_depth)


class NativeClient:
    """Two-party baseline: the user's own machine driving the provider
    SDK over the WAN (boto3/gsutil/Box SDK in the paper §5-§6)."""

    def __init__(self, storage: CloudStorage, clock: Clock | None = None,
                 startup_cost: float = 0.15):
        self.storage = storage
        self.clock = clock or storage.clock
        self.link = wan_link(self.clock)
        self.startup_cost = startup_cost  # login/session setup (paper §5.4)

    def login(self) -> None:
        self.clock.sleep(self.startup_cost)

    def upload_file(self, local_path: str, key: str) -> None:
        with open(local_path, "rb") as f:
            data = f.read()
        self.storage.api_put(key, data, self.link,
                             streams=self.storage.profile.native_put_streams)

    def upload_bytes(self, data: bytes, key: str) -> None:
        self.storage.api_put(key, data, self.link,
                             streams=self.storage.profile.native_put_streams)

    def download_bytes(self, key: str) -> bytes:
        return self.storage.api_get(
            key, self.link, streams=self.storage.profile.native_get_streams)

    def download_file(self, key: str, local_path: str) -> None:
        data = self.download_bytes(key)
        with open(local_path, "wb") as f:
            f.write(data)
