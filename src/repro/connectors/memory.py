"""In-memory Connector — zero-latency storage for unit tests and as the
blob backend for emulated cloud stores."""

from __future__ import annotations

import threading

from ..core.clock import Clock
from ..core.connector import AppChannel, Connector, Session, StatInfo
from ..core.errors import NotFound, PermanentError


class BlobDict:
    """Flat object namespace with '/'-separated pseudo-directories.

    Mtimes are **model-deterministic** (contract R001): stamped from
    the injected model :class:`Clock` when one is given, blended with a
    strictly-increasing per-store tick so two writes in the same model
    instant (zero-latency stores at time scale 0) still get distinct,
    ordered stamps.  Same-seed runs therefore produce identical
    ``(size, mtime)`` stat signatures — which is what keeps the replica
    catalog's staleness check (and the marker journal's ``src_sig``
    guard) reproducible instead of poisoned by wall time.
    """

    #: mtime granularity of the per-write tick (~1 microsecond of model
    #: time; fine enough to never mask a clock advance, coarse enough
    #: to survive float addition exactly over millions of writes)
    TICK = 2.0 ** -20

    def __init__(self, clock: Clock | None = None):
        self._objs: dict[str, bytearray] = {}
        self._mtime: dict[str, float] = {}
        self._clock = clock
        self._last_stamp = 0.0
        self.lock = threading.RLock()

    def _stamp(self) -> float:
        """Next mtime (caller holds the lock): model clock if injected
        (monotonic per-store counter fallback), strictly increasing."""
        base = 0.0 if self._clock is None else self._clock.virtual_elapsed
        self._last_stamp = max(base, self._last_stamp + self.TICK)
        return self._last_stamp

    def put_range(self, key: str, offset: int, data: bytes) -> None:
        with self.lock:
            buf = self._objs.setdefault(key, bytearray())
            if len(buf) < offset + len(data):
                buf.extend(b"\0" * (offset + len(data) - len(buf)))
            buf[offset : offset + len(data)] = data
            self._mtime[key] = self._stamp()

    def get_range(self, key: str, offset: int, length: int) -> bytes:
        with self.lock:
            if key not in self._objs:
                raise NotFound(key)
            return bytes(self._objs[key][offset : offset + length])

    def put(self, key: str, data: bytes) -> None:
        with self.lock:
            self._objs[key] = bytearray(data)
            self._mtime[key] = self._stamp()

    def get(self, key: str) -> bytes:
        with self.lock:
            if key not in self._objs:
                raise NotFound(key)
            return bytes(self._objs[key])

    def delete(self, key: str) -> None:
        with self.lock:
            if key in self._objs:
                del self._objs[key]
                del self._mtime[key]
                return
            # prefix (directory) delete
            doomed = [k for k in self._objs if k.startswith(key.rstrip("/") + "/")]
            if not doomed:
                raise NotFound(key)
            for k in doomed:
                del self._objs[k]
                del self._mtime[k]

    def size(self, key: str) -> int:
        with self.lock:
            if key not in self._objs:
                raise NotFound(key)
            return len(self._objs[key])

    def mtime(self, key: str) -> float:
        with self.lock:
            return self._mtime.get(key, 0.0)

    def exists(self, key: str) -> bool:
        with self.lock:
            return key in self._objs

    def keys(self) -> list[str]:
        with self.lock:
            return sorted(self._objs)

    def list_prefix(self, prefix: str) -> tuple[list[str], list[str]]:
        """Returns (objects, common-prefixes) one level below prefix —
        S3 ListObjectsV2 delimiter semantics."""
        prefix = prefix.strip("/")
        pfx = prefix + "/" if prefix else ""
        with self.lock:
            objs, dirs = [], set()
            for k in sorted(self._objs):
                if not k.startswith(pfx):
                    continue
                rest = k[len(pfx):]
                if "/" in rest:
                    dirs.add(pfx + rest.split("/", 1)[0])
                else:
                    objs.append(k)
            return objs, sorted(dirs)


class MemoryConnector(Connector):
    name = "memory"

    def __init__(self, store: BlobDict | None = None,
                 clock: Clock | None = None):
        self.store = store or BlobDict(clock=clock)

    @staticmethod
    def _key(path: str) -> str:
        return path.strip("/")

    def stat(self, session: Session, path: str) -> StatInfo:
        session.check()
        key = self._key(path)
        if self.store.exists(key):
            return StatInfo(name=path, size=self.store.size(key),
                            mtime=self.store.mtime(key))
        objs, dirs = self.store.list_prefix(key)
        if objs or dirs or key == "":
            return StatInfo(name=path, size=0, mtime=0.0, is_dir=True)
        raise NotFound(path)

    def listdir(self, session: Session, path: str):
        session.check()
        key = self._key(path)
        objs, dirs = self.store.list_prefix(key)
        if not objs and not dirs and key and not self.store.exists(key):
            raise NotFound(path)
        out = [StatInfo(name=k, size=self.store.size(k), mtime=self.store.mtime(k))
               for k in objs]
        out += [StatInfo(name=d, size=0, mtime=0.0, is_dir=True) for d in dirs]
        return out

    def command(self, session: Session, op: str, path: str, **kw) -> None:
        session.check()
        key = self._key(path)
        if op == "mkdir":
            return  # flat namespace: directories are implicit
        if op == "delete":
            self.store.delete(key)
        elif op == "rename":
            to = self._key(kw["to"])
            if self.store.exists(key):
                self.store.put(to, self.store.get(key))
                self.store.delete(key)
                return
            # prefix (directory) rename
            moved = False
            for k in self.store.keys():
                if k.startswith(key + "/"):
                    self.store.put(to + k[len(key):], self.store.get(k))
                    self.store.delete(k)
                    moved = True
            if not moved:
                raise NotFound(path)
        else:
            raise PermanentError(f"unknown command {op!r}")

    def send(self, session: Session, path: str, channel: AppChannel) -> None:
        session.check()
        key = self._key(path)
        size = self.store.size(key)
        if hasattr(channel, "set_size"):
            channel.set_size(size)
        while True:
            rng = channel.get_read_range()
            if rng is None or rng.offset >= size:
                break
            length = min(rng.length, size - rng.offset)
            channel.write(rng.offset, self.store.get_range(key, rng.offset, length))
        channel.finished(None)

    def recv(self, session: Session, path: str, channel: AppChannel) -> None:
        session.check()
        key = self._key(path)
        # materialize the object up front (posix pre-creates the file the
        # same way) so a zero-byte transfer still produces an object
        self.store.put_range(key, 0, b"")
        bs = channel.get_blocksize()
        while True:
            rng = channel.get_read_range()
            if rng is None:
                break
            done = 0
            while done < rng.length:
                step = min(bs, rng.length - done)
                data = channel.read(rng.offset + done, step)
                if not data:
                    break
                self.store.put_range(key, rng.offset + done, data)
                channel.bytes_written(rng.offset + done, len(data))
                done += len(data)
        channel.finished(None)

    # -- bulk data plane --------------------------------------------------
    # Zero-latency storage: batching buys file-level overlap on the
    # session pool, nothing more.  Dispatch stays on self.send/self.recv
    # so subclasses that wrap the per-file path keep working.
    def _batch(self, session: Session, paths, channel_factory, op) -> None:
        session.check()

        def one(path: str, channel: AppChannel) -> None:
            try:
                op(session, path, channel)
            except Exception as e:
                channel.finished(e)

        self._dispatch_batch(session, paths, channel_factory, one)

    def send_batch(self, session: Session, paths, channel_factory) -> None:
        self._batch(session, paths, channel_factory, self.send)

    def recv_batch(self, session: Session, paths, channel_factory) -> None:
        self._batch(session, paths, channel_factory, self.recv)
