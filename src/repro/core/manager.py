"""Multi-task control plane for the managed transfer service.

The paper's contribution is not the Connector alone but the *managed*
third-party service built on it — an orchestrator that initiates
source->destination transfers without sitting in the data path and runs
many tasks at once for performance, error handling, and integrity
(paper §2.1-§2.2).  :class:`TransferManager` is that control plane:

* a priority/FIFO submission queue with a global worker budget and
  per-endpoint concurrency caps, so a fleet of tasks cannot overrun a
  single storage endpoint;
* full task lifecycle — ``submit`` / ``pause`` / ``resume`` / ``cancel``
  / ``wait`` — where a paused task is checkpointed through the
  service's :class:`~repro.core.transfer.MarkerStore` and a resume
  re-opens only the unfinished holes;
* fair scheduling across *tenants* (credential identities from
  :class:`~repro.core.transfer.CredentialStore`): tenants take turns in
  round-robin order, so one user's 10k-file task cannot starve others;
* session sharing: one live connector :class:`Session` per endpoint,
  refcounted across every task that touches it (a
  :class:`SessionPool`), instead of a start/destroy pair per task;
* model-driven routing, closed-loop: a submission naming multiple
  candidate routes is placed by :meth:`~repro.core.perfmodel.Advisor.best`,
  the batch policy sized by
  :meth:`~repro.core.perfmodel.Advisor.coalesce_threshold`, and the
  prediction vs. the *charge-accounted* model-time actual (exact per
  task even under concurrency — every clock charge names its owning
  task, see :mod:`repro.core.clock`) recorded in
  :class:`~repro.core.transfer.TaskStats`.  Every ``refit_every``
  completions per route the manager refits that route's perf model from
  a bounded ring of recent observations and pushes the refreshed
  ``coalesce_threshold``/concurrency into still-queued submissions, so
  a live fleet converges without resubmission (the paper's §5 "easily
  characterized in different contexts without exhaustive benchmarking",
  automated).

:class:`~repro.core.transfer.TransferService` keeps the per-task engine
(expansion, pipes, batches, retries, markers); a bare ``service.submit``
is just the degenerate case of this manager with default knobs.
"""

from __future__ import annotations

import heapq
import itertools
import statistics
import threading
from collections import deque
from contextlib import contextmanager
from dataclasses import asdict, dataclass, field, replace

from .clock import charge_to
from .connector import Session, iter_files
from .perfmodel import Advisor, Route, fit_perf_model
from .transfer import (Endpoint, TransferOptions, TransferService,
                       TransferTask)
from ..obs import MetricsRegistry, Tracer
from ..obs.trace import NULL_TRACER
from ..svc import StatusBus


# --------------------------------------------------------------------------
# session sharing across tasks
# --------------------------------------------------------------------------
class _PoolEntry:
    """One pooled session generation: its own refcount and drain flag.
    A fresh generation replacing a dead session starts at refcount 0 and
    stale holders of the old generation can never touch it — releases
    are matched by session identity, not by endpoint key."""

    __slots__ = ("session", "refs", "draining")

    def __init__(self, session: Session):
        self.session = session
        self.refs = 0
        self.draining = False


class SessionPool:
    """One live connector session per endpoint, shared by every task the
    manager runs against it.

    The per-task engine historically paid ``start``/``destroy`` per
    task; at fleet scale that is a fresh activation (and a fresh batch
    worker pool) per task per endpoint.  The pool refcounts instead:
    ``acquire`` starts a session on first use, every later task reuses
    it, and sessions stay warm between tasks until :meth:`close_all`
    (manager shutdown) destroys them.

    Each pooled session is a :class:`_PoolEntry` *generation*: when a
    session dies mid-task (provider drop, chaos) the next ``acquire``
    starts a replacement generation, and the dead generation's holders
    release against *their* entry — never the replacement's refcount —
    so a stale release can neither go negative nor destroy a live
    session early.  Draining is likewise per generation: ``close_all``
    retires the entries that exist at that moment, and the pool stays
    usable for later work instead of destroying every future session at
    refcount zero.
    """

    def __init__(self, creds):
        self._creds = creds
        self._lock = threading.Lock()
        #: key -> current generation for that endpoint
        self._current: dict[tuple, _PoolEntry] = {}
        #: id(session) -> its entry, for every generation still holding
        #: references (current or retired)
        self._by_session: dict[int, _PoolEntry] = {}

    @staticmethod
    def _key(ep: Endpoint) -> tuple:
        return (id(ep.connector), ep.resolved_id())

    def acquire(self, ep: Endpoint) -> Session:
        with self._lock:
            key = self._key(ep)
            entry = self._current.get(key)
            if entry is None or entry.session.closed or entry.draining:
                if entry is not None and entry.refs <= 0:
                    # a generation that died while idle has no holders
                    # left to drain it — drop its tracking entry here
                    self._by_session.pop(id(entry.session), None)
                session = ep.connector.start(
                    self._creds.lookup(ep.resolved_id()))
                entry = _PoolEntry(session)
                self._current[key] = entry
                self._by_session[id(session)] = entry
            entry.refs += 1
            return entry.session

    def release(self, ep: Endpoint, session: Session) -> None:
        """Return one reference on ``session``.  A release against a
        generation that has since been replaced only drains that old
        generation; if the session is unknown (already fully drained)
        it is a no-op."""
        victim = None
        with self._lock:
            entry = self._by_session.get(id(session))
            if entry is None or entry.refs <= 0:
                return
            entry.refs -= 1
            key = self._key(ep)
            retired = self._current.get(key) is not entry
            if entry.refs == 0 and (entry.draining or retired
                                    or entry.session.closed):
                # last holder off a dead/draining/replaced generation
                # completes its teardown — never under a live transfer
                self._by_session.pop(id(session), None)
                if not retired:
                    del self._current[key]
                victim = entry.session
        if victim is not None and not victim.closed:
            victim.connector.destroy(victim)

    @property
    def live_sessions(self) -> int:
        with self._lock:
            return sum(1 for e in self._current.values()
                       if not e.session.closed)

    def close_all(self) -> None:
        """Destroy the idle sessions now and mark the in-use ones
        draining (their final ``release`` destroys them).  Only the
        generations alive at this moment are affected: sessions started
        afterwards pool normally again."""
        victims = []
        with self._lock:
            for key, entry in list(self._current.items()):
                entry.draining = True
                if entry.refs <= 0:
                    del self._current[key]
                    self._by_session.pop(id(entry.session), None)
                    victims.append(entry.session)
        for session in victims:
            if not session.closed:
                session.connector.destroy(session)


# --------------------------------------------------------------------------
# submissions
# --------------------------------------------------------------------------
@dataclass
class RouteCandidate:
    """One route a submission may take; ``name`` matches an Advisor
    :class:`~repro.core.perfmodel.Route` so the manager can predict."""

    name: str
    src: Endpoint
    dst: Endpoint


@dataclass
class _Submission:
    task: TransferTask
    src: Endpoint
    dst: Endpoint
    options: TransferOptions
    tenant: str
    priority: int
    seq: int
    route_name: str = ""
    n_files_hint: int = 0
    nbytes_hint: int = 0
    #: a resume raced an in-flight pause: when the run loop drains with
    #: status PAUSED, re-queue instead of filing into the paused set
    resume_pending: bool = False
    #: model time this submission (last) entered the ready queue — the
    #: start of the retroactive "queue-wait" span recorded at dispatch
    enqueued_at: float = 0.0
    #: seq of this submission's live heap entry, or None when it holds
    #: none (running / paused / cancelled).  A heap item is a tombstone
    #: unless its seq matches — that is what lets pause/cancel dequeue
    #: in O(1) and the scheduler pop lazily instead of re-sorting
    queued_seq: int | None = None
    #: which refit generation of the route model produced
    #: ``predicted_seconds`` (0 = the seed fit, k = after the k-th refit)
    predict_gen: int = 0

    @property
    def ep_ids(self) -> set[str]:
        return {self.src.resolved_id(), self.dst.resolved_id()}


@dataclass
class ManagerMetrics:
    """Control-plane accounting, for caps/fairness assertions."""

    submitted: int = 0
    completed: int = 0
    cancelled: int = 0
    pauses: int = 0
    resumes: int = 0
    #: federation traffic: tasks serialized out to / imported from a
    #: peer control plane
    exports: int = 0
    imports: int = 0
    peak_active: int = 0
    #: high-water mark of concurrently-active tasks touching an endpoint
    peak_by_endpoint: dict = field(default_factory=dict)
    #: how many dispatches each tenant has received (fairness evidence)
    dispatches_by_tenant: dict = field(default_factory=dict)
    #: (tenant, task_id) in dispatch order — round-robin observability
    dispatch_log: list = field(default_factory=list)
    #: dispatches deferred because an endpoint's circuit breaker was
    #: open at pick time (health plane, :mod:`repro.core.health`)
    health_deferrals: int = 0
    #: route -> automatic refits performed by the online loop
    refits: dict = field(default_factory=dict)
    #: digest() calls answered from the etag cache (queue generation
    #: unchanged) vs. recomputed — the service plane's "an unchanged
    #: snapshot costs ~0" evidence
    digest_hits: int = 0
    digest_misses: int = 0
    #: (route, predict_gen, predicted_s, actual_s) per successful routed
    #: task, in completion order — the prediction-vs-actual error record
    #: the refit loop is judged by.  A bounded ring, like the
    #: observation history: a long-lived fleet must not grow it forever.
    prediction_log: deque = field(
        default_factory=lambda: deque(maxlen=ManagerMetrics.PREDICTION_LOG))

    PREDICTION_LOG = 512


# --------------------------------------------------------------------------
# the manager
# --------------------------------------------------------------------------
class TransferManager:
    """Owns a fleet of :class:`TransferTask`s over one
    :class:`TransferService`.

    Scheduling model: each tenant has a priority heap of submissions;
    tenants take strict round-robin turns, and within a turn the
    tenant's best eligible entry (lowest ``priority``, then FIFO) runs.
    An entry is eligible when the global worker budget has a free slot
    and neither of its endpoints is at ``per_endpoint_cap`` active
    tasks.  Dispatch is event-driven — submissions, completions, and
    resumes pump the scheduler; there is no polling thread.
    """

    def __init__(self, service: TransferService | None = None,
                 advisor: Advisor | None = None, max_workers: int = 4,
                 per_endpoint_cap: int | None = 2,
                 share_sessions: bool = True, refit_every: int = 8,
                 history_limit: int = 64, site_id: str = "",
                 health=None, catalog=None, tracer=None, registry=None,
                 metrics_every: int = 16, **service_kw):
        self.service = service or TransferService(**service_kw)
        if health is not None:
            # shared health plane: the data plane's retry loop and this
            # scheduler consult the SAME registry, so a breaker opened
            # by one task's failures steers every later dispatch
            self.service.health = health
        if catalog is not None:
            # shared replica plane: the data plane publishes/serves
            # replicas from the SAME catalog this scheduler (and the
            # federation digest exchange) scores placement against
            self.service.catalog = catalog
        self.advisor = advisor
        #: federation identity: which site control plane this manager is
        #: (stamped into TaskStats.site so attribution survives handoff)
        self.site_id = site_id
        self.max_workers = max(1, max_workers)
        self.per_endpoint_cap = per_endpoint_cap
        #: auto-refit a route's perf model after this many successful
        #: routed completions on it (0/None disables the online loop)
        self.refit_every = refit_every
        #: observations kept per route — a bounded ring, so refits track
        #: recent traffic instead of averaging over the fleet's lifetime
        self.history_limit = max(2, history_limit)
        self.sessions = SessionPool(self.service.creds) if share_sessions \
            else None
        self.metrics = ManagerMetrics()
        #: observability plane (repro.obs): a model-time tracer shared
        #: with the data plane — spans opened inside TransferService
        #: attach to the task each run loop binds — plus a labeled
        #: metrics registry absorbing the per-plane counters
        if tracer is None and self.service.tracer.enabled:
            # the caller pre-wired a live tracer on the service: share it
            self.tracer = self.service.tracer
        else:
            self.tracer = tracer or Tracer(clock=self.service.clock)
            self.service.tracer = self.tracer
        if self.service.health is not None \
                and self.service.health.tracer is NULL_TRACER:
            self.service.health.tracer = self.tracer
        self.registry = registry or MetricsRegistry()
        #: publish a "metrics" bus event every N terminal completions
        #: (0 disables the periodic stream)
        self.metrics_every = max(0, metrics_every)
        self._tasks_total = self.registry.counter(
            "tasks_total", "terminal task outcomes by site/tenant/status")
        self._task_seconds = self.registry.histogram(
            "task_model_seconds",
            "charged model seconds per terminal task")
        self._queue_wait = self.registry.histogram(
            "queue_wait_model_seconds",
            "model seconds from enqueue to dispatch")
        self.registry.register_collector(self._collect_metrics)
        self._lock = threading.RLock()
        #: service plane: lifecycle/progress event stream (see repro.svc)
        self.bus = StatusBus(site_id=site_id, clock=self.service.clock)
        #: one condition variable on the manager lock carries every
        #: completion/queue-mutation signal: wait_all blocks on it and
        #: every _touch_locked notifies it — no poll-and-sleep anywhere
        self._cv = threading.Condition(self._lock)
        #: queue-state generation — the digest etag.  Bumped by every
        #: queue mutation (submit/dispatch/pause/resume/cancel/finish/
        #: export/import), never by reads, so an unchanged fleet answers
        #: digest() from cache
        self._generation = 0
        self._digest_cache: dict | None = None
        self._queues: dict[str, list] = {}   # tenant -> [(prio, seq, sub)]
        self._rr: list[str] = []             # tenant round-robin order
        self._queued: dict[str, _Submission] = {}
        self._running: dict[str, _Submission] = {}
        self._paused: dict[str, _Submission] = {}
        self._all: dict[str, _Submission] = {}
        self._active_eps: dict[str, int] = {}
        self._seq = itertools.count()
        #: per-route bounded ring of (n_files, nbytes, model_seconds)
        #: from completed tasks — the online-refit observation log
        self._history: dict[str, deque] = {}
        #: per-route successful completions since the last refit
        self._since_refit: dict[str, int] = {}
        #: per-route refit generation (0 = seed model)
        self._refit_gen: dict[str, int] = {}
        self._shutdown = False

    @property
    def health(self):
        """The shared :class:`~repro.core.health.EndpointHealth` registry
        (``None`` when the health plane is off)."""
        return self.service.health

    @property
    def catalog(self):
        """The shared :class:`~repro.catalog.ReplicaCatalog` (``None``
        when the replica plane is off)."""
        return self.service.catalog

    # ---- observability plane ---------------------------------------------
    def _collect_metrics(self) -> dict:
        """Snapshot-time collector absorbing the legacy per-plane
        counters (ManagerMetrics, bus, tracer, health, catalog) into
        the registry namespace without any write-path changes."""
        m = self.metrics
        out = {
            "manager_submitted_total": m.submitted,
            "manager_completed_total": m.completed,
            "manager_cancelled_total": m.cancelled,
            "manager_pauses_total": m.pauses,
            "manager_resumes_total": m.resumes,
            "manager_exports_total": m.exports,
            "manager_imports_total": m.imports,
            "manager_health_deferrals_total": m.health_deferrals,
            "manager_peak_active": m.peak_active,
            "bus_events_published_total": self.bus.published,
            "tracer_spans_recorded_total": self.tracer.spans_recorded,
            "tracer_spans_dropped_total": self.tracer.spans_dropped,
        }
        health = self.service.health
        if health is not None:
            snap = health.snapshot()
            out["health_endpoints"] = len(snap)
            out["health_breakers_open"] = sum(
                1 for s in snap.values() if s["state"] != "closed")
            out["health_denials_total"] = sum(
                s["denials"] for s in snap.values())
        catalog = self.service.catalog
        if catalog is not None:
            for k, v in catalog.stats().items():
                if isinstance(v, (int, float)):
                    out[f"catalog_{k}"] = v
        return out

    def scrape(self) -> str:
        """Prometheus-flavoured text of every fleet metric (native
        instruments + absorbed per-plane counters)."""
        return self.registry.scrape()

    # ---- service plane: mutation signal + event publication --------------
    def _touch_locked(self, etype: str | None = None,
                      task: TransferTask | None = None, **data) -> None:
        """Record one queue mutation (caller holds the lock): bump the
        digest generation (etag), invalidate the cached snapshot, wake
        every condition-variable waiter (``wait_all``), and publish the
        lifecycle event on the bus."""
        self._generation += 1
        self._digest_cache = None
        self._cv.notify_all()
        if etype is not None and task is not None:
            self.bus.publish(etype, task_id=task.task_id,
                             data=data or None, site_id=self.site_id)

    def _wire_task(self, task: TransferTask) -> None:
        """Point the task's emit hook at this bus, so the data plane's
        progress ticks stream to subscribers without knowing about the
        manager."""
        bus, site, tid = self.bus, self.site_id, task.task_id
        task._emit = lambda etype, data=None: bus.publish(
            etype, task_id=tid, data=data, site_id=site)

    # ---- submission ------------------------------------------------------
    def submit(self, src: Endpoint | None = None, dst: Endpoint | None = None,
               options: TransferOptions | None = None, *,
               task_id: str | None = None, tenant: str | None = None,
               priority: int = 0,
               candidates: list[RouteCandidate] | None = None,
               n_files: int = 0, nbytes: int = 0,
               sync: bool = False) -> TransferTask:
        """Enqueue one transfer.  Either a concrete ``(src, dst)`` pair
        or ``candidates`` (Advisor-routed) must be given.  ``tenant``
        defaults to the credential identity behind the source endpoint;
        lower ``priority`` runs earlier within a tenant's turn.
        ``n_files``/``nbytes`` are workload hints for route prediction
        (estimated by expanding the source when omitted)."""
        if candidates:
            src, dst, options, route_name, predicted, (n_files, nbytes) = \
                self._choose_route(candidates, options, n_files, nbytes)
        elif src is None or dst is None:
            raise ValueError("submit needs src+dst or candidates")
        else:
            route_name, predicted = "", 0.0
        options = options or TransferOptions()
        task = self.service.make_task(src, dst, task_id)
        if tenant is None:
            tenant = self.service.creds.identity(src.resolved_id())
        task.stats.tenant = tenant
        task.stats.route = route_name
        task.stats.predicted_seconds = predicted
        task.stats.site = self.site_id
        task.stats.origin_site = self.site_id
        task.trace_id = f"trace-{task.task_id}"
        with self._lock:
            if self._shutdown:
                raise RuntimeError("manager is shut down")
            sub = _Submission(task, src, dst, options, tenant, priority,
                              next(self._seq), route_name=route_name,
                              n_files_hint=n_files, nbytes_hint=nbytes,
                              predict_gen=self._refit_gen.get(route_name, 0))
            self._wire_task(task)
            self._enqueue_locked(sub)
            self.metrics.submitted += 1
            self._touch_locked("queued", task, tenant=tenant,
                               priority=priority)
        self._pump()
        if sync:
            task.wait()
        return task

    def _enqueue_locked(self, sub: _Submission) -> None:
        heap = self._queues.setdefault(sub.tenant, [])
        heapq.heappush(heap, (sub.priority, sub.seq, sub))
        sub.queued_seq = sub.seq
        sub.enqueued_at = self.service.clock.virtual_elapsed
        if sub.tenant not in self._rr:
            self._rr.append(sub.tenant)
        self._queued[sub.task.task_id] = sub
        self._all[sub.task.task_id] = sub

    # ---- advisor routing -------------------------------------------------
    def _choose_route(self, candidates, options, n_files, nbytes):
        """Pick the candidate route the fitted models predict fastest.
        Each candidate is ranked against its OWN source tree (replicas
        may differ in shape — one side may already be coalesced into few
        large objects); concurrency and the coalesce threshold are then
        sized from the winner."""
        if self.advisor is None:
            raise ValueError("candidate routing needs an advisor")
        estimates: dict[tuple, tuple[int, int]] = {}  # shared-src cache
        best = None
        for cand in candidates:
            for route in self.advisor.routes:
                if route.name == cand.name:
                    break
            else:
                raise ValueError(f"no advisor route named {cand.name!r}")
            if n_files:
                workload = (n_files, nbytes)
            else:
                key = (id(cand.src.connector), cand.src.path)
                if key not in estimates:
                    estimates[key] = self._estimate_workload(cand.src)
                workload = estimates[key]
            catalog = self.service.catalog
            replica_bytes = 0 if catalog is None else catalog.held_bytes_at(
                (cand.dst.resolved_id(),), cand.src.resolved_id(),
                cand.src.path)
            _, cc, predicted = Advisor([route]).best(
                *workload, replica_bytes=replica_bytes)
            health = self.service.health
            if health is not None and health.denied(cand.src.resolved_id(),
                                                    cand.dst.resolved_id()):
                # score around open breakers: a huge (not infinite)
                # penalty keeps a healthy replica winning whenever one
                # exists, while an all-unhealthy candidate set still
                # places somewhere instead of erroring
                predicted *= 1e6
            if best is None or predicted < best[3]:
                best = (cand, route, cc, predicted, workload)
        cand, route, cc, predicted, workload = best
        # copy before tuning: the caller may share one TransferOptions
        # across submissions, and the advisor's knobs are per-task
        options = replace(options) if options is not None \
            else TransferOptions()
        options.concurrency = max(1, min(cc, route.max_concurrency))
        options.coalesce_threshold = self.advisor.coalesce_threshold(route)
        return cand.src, cand.dst, options, route.name, predicted, workload

    def _estimate_workload(self, src: Endpoint) -> tuple[int, int]:
        """(n_files, nbytes) by expanding the source prefix — the same
        walk ``_execute`` will do, done early so the Advisor can place
        the task before it runs."""
        release = None
        if self.sessions is not None:
            session = self.sessions.acquire(src)
            release = lambda: self.sessions.release(src, session)
        else:
            session = src.connector.start(
                self.service.creds.lookup(src.resolved_id()))
            release = lambda: src.connector.destroy(session)
        try:
            info = src.connector.stat(session, src.path)
            if not info.is_dir:
                return 1, info.size
            n = total = 0
            for fi in iter_files(src.connector, session, src.path):
                n += 1
                total += fi.size
            return max(n, 1), total
        finally:
            release()

    # ---- scheduling ------------------------------------------------------
    def _eligible_locked(self, sub: _Submission) -> bool:
        if self.per_endpoint_cap is None:
            return True
        return all(self._active_eps.get(ep_id, 0) < self.per_endpoint_cap
                   for ep_id in sub.ep_ids)

    def _pick_locked(self, ignore_health: bool = False) -> _Submission | None:
        """Next runnable submission: tenants rotate round-robin; within
        a tenant, lowest (priority, seq) whose endpoints are under cap
        and (when the health plane is on) have no open breaker.

        The heaps use lazy deletion: pause/cancel (and a pick itself)
        clear ``sub.queued_seq`` instead of scanning + re-heapifying, so
        a pick is O(log n) pops — tombstones fall out here, and entries
        popped while their endpoints were at cap (or breaker-denied)
        are pushed back.  (The old sorted(heap) + heap.remove + heapify
        pick was O(n log n) each, O(n^2 log n) to drain a fleet-sized
        queue.)"""
        if len(self._running) >= self.max_workers:
            return None
        health = None if ignore_health else self.service.health
        for _ in range(len(self._rr)):
            tenant = self._rr.pop(0)
            self._rr.append(tenant)
            heap = self._queues.get(tenant)
            if not heap:
                continue
            picked = None
            deferred = []
            while heap:
                item = heapq.heappop(heap)
                sub = item[2]
                if sub.queued_seq != item[1]:
                    continue  # tombstone: dequeued or re-queued since
                if not self._eligible_locked(sub):
                    deferred.append(item)  # at cap: stays queued
                    continue
                if health is not None and health.denied(*sub.ep_ids):
                    # an endpoint breaker is open: don't burn a worker
                    # slot fast-failing — leave it queued; completions
                    # (and the _pump liveness fallback) re-pick it
                    self.metrics.health_deferrals += 1
                    deferred.append(item)
                    continue
                sub.queued_seq = None
                picked = sub
                break
            for item in deferred:
                heapq.heappush(heap, item)
            if picked is not None:
                return picked
        return None

    def _activate_locked(self, sub: _Submission) -> None:
        tid = sub.task.task_id
        self._queued.pop(tid, None)
        # claim idleness here, not in the worker thread: a pause landing
        # between dispatch and the run loop's own clear must not let
        # wait_idle() return before the run loop has reacted
        sub.task._idle.clear()
        self._running[tid] = sub
        for ep_id in sub.ep_ids:
            n = self._active_eps.get(ep_id, 0) + 1
            self._active_eps[ep_id] = n
            peak = self.metrics.peak_by_endpoint
            peak[ep_id] = max(peak.get(ep_id, 0), n)
        self.metrics.peak_active = max(self.metrics.peak_active,
                                       len(self._running))
        by_tenant = self.metrics.dispatches_by_tenant
        by_tenant[sub.tenant] = by_tenant.get(sub.tenant, 0) + 1
        self.metrics.dispatch_log.append((sub.tenant, tid))
        # queue time was waited out, not slept through: a retroactive
        # span (visible in exports, charges nothing) + a histogram point
        now = self.service.clock.virtual_elapsed
        self.tracer.record("queue-wait", "queue", sub.enqueued_at, now,
                           trace_id=sub.task.trace_id, task_id=tid,
                           tenant=sub.tenant)
        self._queue_wait.observe(max(0.0, now - sub.enqueued_at),
                                 site=self.site_id, tenant=sub.tenant)
        self._touch_locked("dispatched", sub.task, tenant=sub.tenant)

    def _pump(self) -> None:
        """Dispatch every runnable submission to a worker thread."""
        with self._lock:
            if self._shutdown:
                return
            while True:
                sub = self._pick_locked()
                if sub is None and not self._running \
                        and self.service.health is not None:
                    # liveness backstop: with everything health-deferred
                    # and nothing running, no completion will ever pump
                    # again — admit one denied submission anyway and let
                    # the data plane's admit() gate pace it (fast-fail +
                    # breaker retry_after), instead of wedging the queue
                    sub = self._pick_locked(ignore_health=True)
                if sub is None:
                    return
                self._activate_locked(sub)
                threading.Thread(target=self._run_one, args=(sub,),  # lint: disable=R002(the worker IS the charge boundary — _run establishes charge_to with the task id itself)
                                 daemon=True).start()

    @contextmanager
    def _pooled_sessions(self, src: Endpoint, dst: Endpoint):
        s_src = self.sessions.acquire(src)
        try:
            s_dst = self.sessions.acquire(dst)
            try:
                yield s_src, s_dst
            finally:
                self.sessions.release(dst, s_dst)
        finally:
            self.sessions.release(src, s_src)

    def _run_one(self, sub: _Submission) -> None:
        # per-task charge accounting: the run attributes every model-time
        # charge (across all the threads it fans out into) to this task,
        # so the delta is exact even with max_workers > 1 — concurrent
        # tasks partition the shared clock instead of each observing all
        # of it
        clock = self.service.clock
        tid = sub.task.task_id
        c0 = clock.charged(tid)
        t0 = self.tracer.category_seconds(tid)
        scope = self._pooled_sessions if self.sessions is not None else None
        try:
            self.service._run(sub.task, sub.src, sub.dst, sub.options,
                              session_scope=scope)
        finally:
            # the span-category delta mirrors the charge delta exactly:
            # both are fed by the same Clock.sleep calls, so the
            # time_budget decomposition cannot drift from the total
            t1 = self.tracer.category_seconds(tid)
            spans = {cat: secs - t0.get(cat, 0.0)
                     for cat, secs in t1.items()
                     if secs - t0.get(cat, 0.0) > 0.0}
            self._on_done(sub, clock.charged(tid) - c0, spans)

    def _on_done(self, sub: _Submission, model_seconds: float,
                 span_seconds: dict | None = None) -> None:
        task = sub.task
        refit_due: str | None = None
        with self._lock:
            tid = task.task_id
            self._running.pop(tid, None)
            for ep_id in sub.ep_ids:
                n = self._active_eps.get(ep_id, 0) - 1
                if n > 0:
                    self._active_eps[ep_id] = n
                else:
                    self._active_eps.pop(ep_id, None)
            task.stats.actual_model_seconds += model_seconds
            for cat, secs in (span_seconds or {}).items():
                ss = task.stats.span_seconds
                ss[cat] = ss.get(cat, 0.0) + secs
            if task.status == TransferTask.PAUSED:
                self.metrics.pauses += 1
                if sub.resume_pending:
                    # a resume raced the drain: straight back to the queue
                    sub.resume_pending = False
                    task._pause_req.clear()
                    task.status = TransferTask.PENDING
                    task.stats.resumes += 1
                    self.metrics.resumes += 1
                    sub.seq = next(self._seq)
                    self._enqueue_locked(sub)
                    etype = "resumed"
                else:
                    self._paused[tid] = sub
                    etype = "paused"
            elif task.status == TransferTask.CANCELLED:
                self.metrics.cancelled += 1
                self.service.clock.forget(tid)
                self.tracer.forget(tid)
                etype = "cancelled"
            else:
                self.metrics.completed += 1
                self.service.clock.forget(tid)
                self.tracer.forget(tid)
                etype = "done" if task.status == TransferTask.SUCCEEDED \
                    else "failed"
                if task.status == TransferTask.SUCCEEDED and sub.route_name:
                    route = sub.route_name
                    self._history.setdefault(
                        route, deque(maxlen=self.history_limit)).append(
                        (task.stats.files_total, task.stats.bytes_total,
                         task.stats.actual_model_seconds))
                    self.metrics.prediction_log.append(
                        (route, sub.predict_gen,
                         task.stats.predicted_seconds,
                         task.stats.actual_model_seconds))
                    if self.refit_every:
                        n = self._since_refit.get(route, 0) + 1
                        if n >= self.refit_every:
                            # reset under the lock: a sibling completion
                            # must not schedule a second refit
                            self._since_refit[route] = 0
                            refit_due = route
                        else:
                            self._since_refit[route] = n
            self._touch_locked(etype, task, status=task.status)
        if etype in ("done", "failed", "cancelled"):
            self._tasks_total.inc(site=self.site_id, tenant=sub.tenant,
                                  status=task.status)
            self._task_seconds.observe(task.stats.actual_model_seconds,
                                       site=self.site_id,
                                       status=task.status)
            if self.metrics_every:
                n = self.metrics.completed + self.metrics.cancelled
                if n % self.metrics_every == 0:
                    # periodic registry snapshot on the event stream, so
                    # subscribers scrape metrics off the bus they already
                    # watch (outside the manager lock: collectors take
                    # plane locks of their own)
                    self.bus.publish("metrics",
                                     data=self.registry.snapshot(),
                                     site_id=self.site_id)
        if refit_due is not None:
            self._auto_refit(refit_due)
        self._pump()

    def _auto_refit(self, route_name: str) -> None:
        """One turn of the closed loop: refit the route from its recent
        observations, then push the refreshed model's knobs into every
        still-queued submission on that route so the in-flight fleet
        converges without resubmission."""
        model = self.refit_route(route_name)
        with self._lock:
            if model is None:
                return
            gen = self._refit_gen.get(route_name, 0) + 1
            self._refit_gen[route_name] = gen
            refits = self.metrics.refits
            refits[route_name] = refits.get(route_name, 0) + 1
            route = next((r for r in self.advisor.routes
                          if r.name == route_name), None)
            if route is None:
                return
            adv = Advisor([route])
            threshold = self.advisor.coalesce_threshold(route)
            for sub in self._queued.values():
                if sub.route_name != route_name:
                    continue
                _, cc, predicted = adv.best(
                    max(1, sub.n_files_hint), sub.nbytes_hint)
                sub.options.concurrency = max(
                    1, min(cc, route.max_concurrency))
                sub.options.coalesce_threshold = threshold
                sub.task.stats.predicted_seconds = predicted
                sub.predict_gen = gen

    # ---- lifecycle -------------------------------------------------------
    def get(self, task_id: str) -> TransferTask:
        return self.service.get(task_id)

    def pause(self, task_id: str) -> bool:
        """Request a pause.  A queued task pauses immediately; a running
        task checkpoints its in-flight files through the MarkerStore and
        goes PAUSED once its run loop drains (``task.wait_idle()``)."""
        with self._lock:
            sub = self._queued.pop(task_id, None)
            if sub is not None:
                sub.queued_seq = None  # tombstone its heap entry
                sub.task.status = TransferTask.PAUSED
                self._paused[task_id] = sub
                self.metrics.pauses += 1
                self._touch_locked("paused", sub.task, while_queued=True)
                return True
            sub = self._running.get(task_id)
            if sub is not None and not sub.task._done.is_set():
                sub.task.request_pause()
                return True
        return False

    def resume(self, task_id: str) -> bool:
        """Re-queue a paused task; restart markers re-open only the
        holes, so completed ranges are never re-sent."""
        with self._lock:
            sub = self._paused.pop(task_id, None)
            if sub is None:
                # the pause may still be draining its run loop: cancel
                # the request and let _on_done re-queue on drain
                run_sub = self._running.get(task_id)
                if run_sub is not None \
                        and run_sub.task._pause_req.is_set() \
                        and not run_sub.task._done.is_set():
                    run_sub.resume_pending = True
                    return True
                return False
            task = sub.task
            task._pause_req.clear()
            task.status = TransferTask.PENDING
            task.stats.resumes += 1
            self.metrics.resumes += 1
            sub.seq = next(self._seq)  # back of the tenant's FIFO
            self._enqueue_locked(sub)
            self._touch_locked("resumed", task)
        self._pump()
        return True

    def cancel(self, task_id: str) -> bool:
        with self._lock:
            sub = self._queued.pop(task_id, None) \
                or self._paused.pop(task_id, None)
            if sub is not None:
                sub.queued_seq = None  # tombstone its heap entry
                sub.task.request_cancel()
                self.service.markers.clear(task_id)
                self.metrics.cancelled += 1
                sub.task._finish(TransferTask.CANCELLED)
                # a paused task may have accumulated charges in earlier
                # runs; this is its terminal state, so drop its tally
                self.service.clock.forget(task_id)
                self._touch_locked("cancelled", sub.task)
                return True
            sub = self._running.get(task_id)
            if sub is not None:
                sub.task.request_cancel()
                return True
        return False

    def wait(self, task_id: str, timeout: float | None = None) -> bool:
        return self.service.get(task_id).wait(timeout)

    def _drained_locked(self) -> bool:
        """True when no task is pending: everything in ``_all`` is
        either finished or filed into the paused set."""
        return all(s.task._done.is_set() or tid in self._paused
                   for tid, s in self._all.items())

    def wait_all(self, timeout: float | None = None) -> bool:
        """Wait until every non-paused task has finished.

        Event-driven: blocks on the manager condition variable that
        every queue mutation (completion, pause filing, export, ...)
        notifies via :meth:`_touch_locked` — the same signal StatusBus
        subscribers ride.  The old implementation re-polled a pending
        snapshot every 20 ms of wall time (and only ever waited on
        ``pending[0]``); completion latency is now one ``notify``."""
        with self._cv:
            return self._cv.wait_for(self._drained_locked, timeout)

    def shutdown(self, wait: bool = True,
                 timeout: float | None = None) -> None:
        """Stop dispatching, optionally drain running tasks, and close
        the shared sessions."""
        if wait:
            self.wait_all(timeout)
        with self._lock:
            self._shutdown = True
            # backstop for tasks that never reached a terminal _on_done
            # (left paused, still running at a no-wait shutdown): their
            # charge tallies die with the fleet
            for tid in self._all:
                self.service.clock.forget(tid)
        if self.sessions is not None:
            self.sessions.close_all()

    # ---- federation: live-task travel + queue-state digests --------------
    def export_state(self, task_id: str) -> dict | None:
        """Serialize a queued or paused task for travel to a peer site.

        Removes the task from this control plane: its heap entry is
        tombstoned, its marker state (hole map + per-range digests) is
        folded into the payload and cleared locally, and the local
        handle finishes ``HANDED_OFF`` so waiters unblock.  Charge
        accounting travels too — ``actual_model_seconds`` accrued here
        rides in the payload and the importing site resumes the sum, so
        per-task model time stays exact across control planes.

        Returns ``None`` for a running or finished task (pause and wait
        for the drain first — the coordinator does)."""
        with self._lock:
            sub = self._queued.pop(task_id, None)
            state = "queued"
            if sub is None:
                sub = self._paused.pop(task_id, None)
                state = "paused"
            if sub is None:
                return None
            sub.queued_seq = None  # tombstone any live heap entry
            self._all.pop(task_id, None)
            self.metrics.exports += 1
            # notify inside the locked pop: wait_all's predicate stops
            # consulting this task the moment it leaves _all, and the
            # HANDED_OFF finish below runs outside the lock
            self._touch_locked("handed_off", sub.task, state=state)
        st = sub.task.stats
        payload = {
            "version": 1,
            "task_id": task_id,
            "state": state,
            "tenant": sub.tenant,
            "priority": sub.priority,
            "origin_site": st.origin_site or self.site_id,
            "trace_id": sub.task.trace_id,
            "src": {"endpoint_id": sub.src.resolved_id(),
                    "path": sub.src.path},
            "dst": {"endpoint_id": sub.dst.resolved_id(),
                    "path": sub.dst.path},
            "options": asdict(sub.options),
            "route": sub.route_name,
            "n_files": sub.n_files_hint,
            "nbytes": sub.nbytes_hint,
            "stats": {"predicted_seconds": st.predicted_seconds,
                      "actual_model_seconds": st.actual_model_seconds,
                      "resumes": st.resumes,
                      "span_seconds": dict(st.span_seconds)},
            "markers": self.service.markers.export_state(task_id),
            # replica hints: where verified copies of this source
            # already live, so the adopting site's catalog can satisfy
            # the task by replica reads (hints are re-validated there)
            "replicas": (self.service.catalog.export_hints(
                sub.src.resolved_id(), sub.src.path)
                if self.service.catalog is not None else []),
        }
        self.service.markers.clear(task_id)
        self.service.clock.forget(task_id)
        self.tracer.forget(task_id)
        sub.task._finish(TransferTask.HANDED_OFF)
        return payload

    def import_state(self, payload: dict, src: Endpoint,
                     dst: Endpoint) -> TransferTask:
        """Adopt a task serialized by a peer's :meth:`export_state`.

        ``src``/``dst`` are this site's resolutions of the payload's
        endpoint ids (connectors cannot travel; endpoint ownership maps
        can).  The traveled marker state is installed first, so a
        paused task resumes re-sending only its holes; carried stats
        keep tenant/site attribution and the charge-accounted model
        seconds accrued elsewhere."""
        fields = TransferOptions.__dataclass_fields__
        options = TransferOptions(**{k: v
                                     for k, v in payload.get("options",
                                                             {}).items()
                                     if k in fields})
        carried = payload.get("stats", {})
        task = self.service.make_task(src, dst, payload["task_id"])
        task.stats.tenant = payload.get("tenant", "")
        task.stats.route = payload.get("route", "")
        task.stats.site = self.site_id
        task.stats.origin_site = payload.get("origin_site", "")
        task.stats.predicted_seconds = carried.get("predicted_seconds", 0.0)
        task.stats.actual_model_seconds = \
            carried.get("actual_model_seconds", 0.0)
        task.stats.resumes = carried.get("resumes", 0)
        task.stats.span_seconds = dict(carried.get("span_seconds", {}))
        # the trace id travels: spans on this site stitch into the same
        # timeline the task accrued at its origin
        task.trace_id = payload.get("trace_id") \
            or f"trace-{task.task_id}"
        if payload.get("state") == "cancelled":
            # terminal on arrival: registered for observability only —
            # and its markers are NOT installed (nothing would ever
            # clear them, and a later same-id submission must not
            # inherit a cancelled task's hole map)
            task.request_cancel()
            task._finish(TransferTask.CANCELLED)
            return task
        markers = payload.get("markers")
        if markers and markers.get("files"):
            self.service.markers.import_state(task.task_id, markers)
        if self.service.catalog is not None:
            for hint in payload.get("replicas", []) or []:
                self.service.catalog.merge_hint(hint)
        with self._lock:
            if self._shutdown:
                raise RuntimeError("manager is shut down")
            sub = _Submission(task, src, dst, options,
                              payload.get("tenant", "anonymous"),
                              payload.get("priority", 0), next(self._seq),
                              route_name=payload.get("route", ""),
                              n_files_hint=payload.get("n_files", 0),
                              nbytes_hint=payload.get("nbytes", 0))
            if payload.get("state") == "paused":
                # adopting a paused task IS its resume
                task.stats.resumes += 1
                self.metrics.resumes += 1
            self._wire_task(task)
            self._enqueue_locked(sub)
            self.metrics.submitted += 1
            self.metrics.imports += 1
            self._touch_locked("queued", task, imported=True)
        self._pump()
        return task

    def settled(self, task_id: str) -> bool:
        """True once no run loop (or its completion bookkeeping) holds
        the task — it is queued, paused, or finished, so exporting it
        or tearing the manager down cannot race its charge accounting."""
        with self._lock:
            return task_id not in self._running

    def digest(self, fresh: bool = False) -> dict:
        """Queue-state snapshot a federation coordinator exchanges
        between sites: depth, in-flight bytes, and per-endpoint
        saturation — plus a monotonic ``etag`` (the queue-state
        generation).

        While no queue mutation has happened since the last call the
        cached snapshot is returned as-is, so heartbeating an unchanged
        fleet costs ~0 (a dict lookup; ``metrics.digest_hits`` counts
        these).  ``fresh=True`` forces a recompute — the pre-etag cost,
        kept as the benchmark baseline.  In-flight byte counts only
        advance *across* generations; within one, progress freshness is
        the StatusBus event stream's job, not the digest's.

        Saturation: ``active/cap`` per endpoint when a cap is set.  An
        uncapped manager used to report ``0.0`` for every endpoint —
        least-loaded and rebalance placement saw a fully-busy uncapped
        site as idle — so it now falls back to a busy-based signal,
        ``min(1, active/worker_budget)``."""
        with self._lock:
            snap = self._digest_cache
            if snap is not None and not fresh \
                    and snap["etag"] == self._generation:
                self.metrics.digest_hits += 1
                return snap
            in_flight = sum(
                max(0, s.task.stats.bytes_total - s.task.stats.bytes_done)
                for s in self._running.values())
            cap = self.per_endpoint_cap
            budget = max(1, self.max_workers)
            saturation = {ep: (n / cap if cap
                               else min(1.0, n / budget))
                          for ep, n in self._active_eps.items()}
            health = self.service.health
            catalog = self.service.catalog
            snap = {"site_id": self.site_id,
                    "queued": len(self._queued),
                    "running": len(self._running),
                    "paused": len(self._paused),
                    "in_flight_bytes": in_flight,
                    "saturation": saturation,
                    "unavailable_endpoints":
                        sorted(health.unavailable()) if health is not None
                        else [],
                    # replica plane: stats + per-source held-bytes map so
                    # a federation coordinator can score replica hits.
                    # Rides the queue-state etag: completions (the only
                    # durable publishes that matter for placement) always
                    # mutate the queue, so freshness tracks the cache.
                    "catalog": ({"stats": catalog.stats(),
                                 "sources": catalog.source_summary()}
                                if catalog is not None else {}),
                    "etag": self._generation}
            self._digest_cache = snap
            self.metrics.digest_misses += 1
            # a recompute IS the periodic digest delta: stream it, so
            # subscribers track queue state without calling digest()
            self.bus.publish("digest", data=snap, site_id=self.site_id)
            return snap

    # ---- observability / online refit -----------------------------------
    def counts(self) -> dict:
        with self._lock:
            return {"queued": len(self._queued),
                    "running": len(self._running),
                    "paused": len(self._paused),
                    "active_by_endpoint": dict(self._active_eps)}

    def observations(self, route_name: str) -> list[tuple[int, int, float]]:
        with self._lock:
            return list(self._history.get(route_name, []))

    def prediction_error(self, route_name: str | None = None,
                         generation: int | None = None,
                         min_generation: int | None = None) -> float | None:
        """Median relative prediction error ``|predicted - actual| /
        actual`` over the recorded prediction log, optionally filtered
        by route and by refit generation (``generation=0`` is the seed
        model; ``min_generation=1`` is everything predicted after at
        least one online refit).  ``None`` when nothing matches — the
        refit loop's convergence is judged by this shrinking."""
        with self._lock:
            rows = [(p, a) for r, g, p, a in self.metrics.prediction_log
                    if (route_name is None or r == route_name)
                    and (generation is None or g == generation)
                    and (min_generation is None or g >= min_generation)]
        if not rows:
            return None
        return statistics.median(
            abs(p - a) / max(a, 1e-9) for p, a in rows)

    def refit_route(self, route_name: str, min_points: int = 3):
        """Refit one advisor route from recorded (n_files, seconds)
        observations — the paper's §5 regression, rerun on live traffic
        instead of a benchmark sweep.  Observations are charge-accounted
        per task (see :meth:`_run_one`), so they are exact even when the
        fleet recorded them with ``max_workers > 1``; the bounded
        per-route ring (``history_limit``) ages stale traffic out.
        Called automatically every ``refit_every`` completions per route,
        and still callable on demand.  Returns the new
        :class:`~repro.core.perfmodel.PerfModel`, or ``None`` when there
        are too few (or degenerate) points."""
        if self.advisor is None:
            return None
        pts = self.observations(route_name)
        if len(pts) < max(2, min_points):
            return None
        route = next((r for r in self.advisor.routes
                      if r.name == route_name), None)
        if route is None:
            return None
        n_files = [p[0] for p in pts]
        seconds = [p[2] for p in pts]
        bytes_mean = int(sum(p[1] for p in pts) / len(pts))
        try:
            model = fit_perf_model(route_name, n_files, seconds, bytes_mean,
                                   s0=route.model.s0)
        except ValueError:  # degenerate xs (all same file count)
            return None
        route.model = model
        return model
