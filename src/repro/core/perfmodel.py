"""Performance-model-based evaluation (paper §5).

The paper's model for transferring N files totaling B bytes with
concurrency one:

    T = N * t0 + B / R + S0                      (Eq. 4)

is fit by ordinary least squares over (N, T) observations at fixed B
(Eq. 3), giving ``beta = t0`` (per-file overhead) and
``alpha = B/R + S0`` (network-efficiency intercept).  The startup cost
S0 is resolved separately from single-file size sweeps:

    T = B * t_u + S0                             (Eq. 6)

Pearson's rho (Eq. 5) validates the linearity assumption (the paper's
Table 1 shows rho ~ 0.99 everywhere).  The fitted models feed a
*transfer advisor* that predicts transfer time per route and picks
placement/concurrency — the paper's §8 best practices, automated.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


# ---------------------------------------------------------------------------
# statistics (Eqs. 3 and 5) — closed-form, no deps
# ---------------------------------------------------------------------------
def fit_linear(xs, ys) -> tuple[float, float]:
    """OLS fit y = alpha + beta * x; returns (alpha, beta)."""
    n = len(xs)
    if n != len(ys) or n < 2:
        raise ValueError("need >= 2 paired observations")
    mx = sum(xs) / n
    my = sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    if sxx == 0:
        raise ValueError("degenerate x values")
    sxy = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    beta = sxy / sxx
    alpha = my - beta * mx
    return alpha, beta


def pearson(xs, ys) -> float:
    """Pearson correlation coefficient rho(x, y) (Eq. 5)."""
    n = len(xs)
    mx = sum(xs) / n
    my = sum(ys) / n
    cov = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    sx = math.sqrt(sum((x - mx) ** 2 for x in xs))
    sy = math.sqrt(sum((y - my) ** 2 for y in ys))
    if sx == 0 or sy == 0:
        return 0.0
    return cov / (sx * sy)


def r_squared(xs, ys, alpha: float, beta: float) -> float:
    my = sum(ys) / len(ys)
    ss_res = sum((y - (alpha + beta * x)) ** 2 for x, y in zip(xs, ys))
    ss_tot = sum((y - my) ** 2 for y in ys)
    return 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0


# ---------------------------------------------------------------------------
# the model (Eq. 4 / Eq. 6)
# ---------------------------------------------------------------------------
@dataclass
class PerfModel:
    """Fitted T = N*t0 + B/R + S0 for one (route, direction, B)."""

    route: str                  # e.g. "s3/conn-cloud/upload"
    t0: float                   # per-file overhead (s/file)
    alpha: float                # intercept = B/R + S0 at the fit's B
    bytes_total: int            # B used during fitting
    s0: float = 0.0             # startup cost if separately resolved
    rho: float = 0.0            # Pearson over the fit data
    r2: float = 0.0

    @property
    def throughput(self) -> float:
        """Effective single-stream network rate R implied by alpha."""
        denom = self.alpha - self.s0
        return self.bytes_total / denom if denom > 0 else float("inf")

    def predict(self, n_files: int, nbytes: int, concurrency: int = 1) -> float:
        """Predicted seconds.  Concurrency overlaps per-file overhead
        across cc slots (paper §5.3.2: 'the influence of per-file
        overhead can be alleviated by transferring many files
        concurrently')."""
        cc = max(1, concurrency)
        return (n_files * self.t0) / cc + nbytes / self.throughput + self.s0


def fit_perf_model(route: str, n_files: list[int], seconds: list[float],
                   bytes_total: int, s0: float = 0.0) -> PerfModel:
    """Regression analysis of §5.2: fixed total size, varying file count."""
    alpha, beta = fit_linear(n_files, seconds)
    return PerfModel(route=route, t0=max(beta, 0.0), alpha=alpha,
                     bytes_total=bytes_total, s0=s0,
                     rho=pearson(n_files, seconds),
                     r2=r_squared(n_files, seconds, alpha, beta))


def fit_startup_cost(sizes_bytes: list[int], seconds: list[float]) -> tuple[float, float]:
    """Eq. 6: T = B * t_u + S0 over single-file transfers.
    Returns (s0, t_u)."""
    alpha, beta = fit_linear(sizes_bytes, seconds)
    return max(alpha, 0.0), beta


# ---------------------------------------------------------------------------
# the advisor (paper §8, automated)
# ---------------------------------------------------------------------------
@dataclass
class Route:
    name: str
    model: PerfModel
    max_concurrency: int = 16
    cost_per_gb_egress: float = 0.0  # §8.2 cost minimization


@dataclass
class Advisor:
    """Chooses route + concurrency for a workload of (n_files, bytes).

    This closes the paper's loop: instead of exhaustively benchmarking
    every (storage, placement, concurrency) cell, fit the model once per
    route and *predict* — then pick the argmin.  Used by the checkpoint
    replicator to size its transfers.
    """

    routes: list[Route] = field(default_factory=list)

    def add(self, route: Route) -> None:
        self.routes.append(route)

    def best(self, n_files: int, nbytes: int,
             objective: str = "throughput",
             replica_bytes: int = 0) -> tuple[Route, int, float]:
        """Returns (route, concurrency, predicted_seconds).

        ``replica_bytes`` — bytes a replica catalog already holds near
        the route's destination — are subtracted from the wire term
        (and from billable egress): a cataloged range is a local
        replica read, not a source read.  Per-file overhead and startup
        cost stay — the control-channel work per file happens either
        way (Eq. 4's ``N*t0 + S0`` terms are not about bytes)."""
        if not self.routes:
            raise ValueError("no routes registered")
        wire_bytes = max(0, nbytes - max(0, replica_bytes))
        best = None
        for r in self.routes:
            for cc in _cc_ladder(r.max_concurrency):
                t = r.model.predict(n_files, wire_bytes, cc)
                cost = t if objective == "throughput" else (
                    t + r.cost_per_gb_egress * wire_bytes / 1e9)
                if best is None or cost < best[3]:
                    best = (r, cc, t, cost)
        return best[0], best[1], best[2]

    def coalesce_advice(self, n_files: int, nbytes: int,
                        route: Route | None = None) -> int:
        """How many objects should a dataset of `nbytes` be split into so
        per-file overhead stays under ~5% of transfer time?  (the §8
        'datasets with big files are more friendly' rule, made
        quantitative).  Returns the recommended file count."""
        r = route or self.routes[0]
        wire = nbytes / r.model.throughput
        if r.model.t0 <= 0:
            return n_files
        budget = 0.05 * wire
        return max(1, min(n_files, int(budget / r.model.t0) or 1))

    def coalesce_threshold(self, route: Route | None = None) -> int:
        """Size ``TransferOptions.coalesce_threshold`` from a fitted
        model: a file is overhead-dominated — and worth coalescing into
        a pipelined batch — when its wire time is below the per-file
        overhead, i.e. ``size < t0 * R`` (Eq. 4 with N=1).  Returns the
        break-even size in bytes (0 when the route has no measurable
        per-file overhead, which disables batching)."""
        r = route or self.routes[0]
        if r.model.t0 <= 0 or not math.isfinite(r.model.throughput):
            return 0
        return int(r.model.t0 * r.model.throughput)


def _cc_ladder(max_cc: int) -> list[int]:
    # cc=1 is always a candidate: a route advertising max_concurrency<1
    # must still be rankable, or Advisor.best would silently skip it
    out, cc = [], 1
    while cc <= max(1, max_cc):
        out.append(cc)
        cc *= 2
    return out
