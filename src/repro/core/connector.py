"""The Connector abstraction (paper §3).

A Connector gives a managed data-transfer application uniform access to
one kind of storage system.  The interface reproduces the paper's
function set:

  interface functions (implemented by the Connector author):
    Start / Destroy / Stat / Command / Send / Recv / SetCredential

  helper functions (implemented by the application, handed to the
  Connector as an :class:`AppChannel`):
    read / write / get_concurrency / get_blocksize / get_read_range /
    bytes_written / finished

``Send`` reads data from the underlying storage system and writes it to
the application (download path); ``Recv`` reads from the application and
writes to storage (upload path).  The Connector author never talks to
the network — only to the AppChannel — exactly as in the paper: "This
API provides functions for reading and writing data to and from the
network.  The Connector author is not expected to know the details of
the application."

Bulk data plane (many-small-files regime, paper §5.3.2/§8)
----------------------------------------------------------
``send_batch`` / ``recv_batch`` move a *group* of files through one
call so a Connector can amortize per-file costs the per-file API cannot:
request pipelining on a persistent connection, grouped API admission,
and a reused session-level worker pool instead of a thread per file per
attempt.  The application hands over a ``channel_factory(path)`` that
returns the :class:`AppChannel` for each path (or ``None`` to skip it).
Per-file failures are *contained*: a batch implementation reports a
file's error through ``channel.finished(error)`` and keeps going, so
one bad file cannot abort its batch-mates.  The default implementation
simply falls back to per-file ``send``/``recv``, so every Connector
supports the bulk API from day one.
"""

from __future__ import annotations

import threading
from abc import ABC, abstractmethod
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator, Sequence

from .clock import bind_charge_owner
from .errors import SessionClosed


@dataclass(frozen=True)
class StatInfo:
    """Result of ``Stat`` (paper Fig. 2: mode/nlink/uid/gid/size/times)."""

    name: str
    size: int
    mtime: float
    is_dir: bool = False
    mode: int = 0o644
    nlink: int = 1
    uid: int = 0
    gid: int = 0
    etag: str | None = None  # object stores carry an etag / generation


@dataclass(frozen=True)
class ByteRange:
    """Half-open [offset, offset+length) byte range.

    ``get_read_range`` hands these to a Connector to support restart
    ("holey" transfers) and partial transfers (paper §3).
    """

    offset: int
    length: int

    @property
    def end(self) -> int:
        return self.offset + self.length


class AppChannel(ABC):
    """Application-side helper API handed to Send/Recv (paper §3)."""

    # -- data plane -----------------------------------------------------
    @abstractmethod
    def write(self, offset: int, data: bytes) -> None:
        """Connector -> application (used by Send). May arrive
        out-of-order across ranges; the application reassembles."""

    @abstractmethod
    def read(self, offset: int, length: int) -> bytes:
        """Application -> connector (used by Recv)."""

    # -- transfer-management hints ---------------------------------------
    @abstractmethod
    def get_concurrency(self) -> int:
        """How many outstanding reads/writes the Connector should keep in
        flight (paper: matches the number of parallel streams)."""

    @abstractmethod
    def get_blocksize(self) -> int:
        """Buffer size for each read/write exchange."""

    @abstractmethod
    def get_read_range(self) -> ByteRange | None:
        """Next byte range the application still needs, or None when the
        file is fully claimed.  Supports restart markers + holey
        transfers."""

    # -- progress / completion ------------------------------------------
    @abstractmethod
    def bytes_written(self, offset: int, length: int) -> None:
        """Connector calls this after each successful write to *storage*
        so the application can emit performance and restart markers."""

    def finished(self, error: Exception | None = None) -> None:  # optional
        """Connector signals completion of the Send/Recv operation."""


@dataclass
class Credential:
    """Opaque credential registered out-of-band (paper Fig. 3: creds go
    client -> GCS manager, never through the hosted service)."""

    scheme: str  # e.g. "local-user", "s3-keypair", "oauth2-token"
    data: dict = field(default_factory=dict)


class Session:
    """Per-access state threaded through all interface calls (paper:
    'Start ... set internal state that will be threaded through to all
    other function calls associated with this session')."""

    def __init__(self, connector: "Connector", credential: Credential | None):
        self.connector = connector
        self.credential = credential
        self.closed = False
        self.state: dict = {}
        self._lock = threading.Lock()

    def check(self) -> None:
        if self.closed:
            raise SessionClosed(f"session on {self.connector.name} is closed")

    def worker_pool(self, size: int) -> ThreadPoolExecutor:
        """Session-level worker pool reused by every batch operation on
        this session (instead of a thread per file per attempt).  Sized
        on first use; shut down by ``Connector.destroy``."""
        with self._lock:
            self.check()
            pool = self.state.get("_batch_pool")
            if pool is None:
                pool = ThreadPoolExecutor(
                    max_workers=max(1, size),
                    thread_name_prefix=f"{self.connector.name}-batch")
                self.state["_batch_pool"] = pool
            return pool

    # context-manager sugar
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.connector.destroy(self)


class Connector(ABC):
    """The pluggable storage interface (paper §3, Fig. 1).

    Implementations translate these calls into the native API of one
    storage system (POSIX syscalls, S3-style REST, Drive RPCs, ...).
    """

    #: human-readable storage-system name, e.g. "aws-s3"
    name: str = "abstract"
    #: credential scheme expected by SetCredential
    credential_scheme: str | None = None

    # -- lifecycle -------------------------------------------------------
    def start(self, credential: Credential | None = None) -> Session:
        session = Session(self, credential)
        self.set_credential(session, credential)
        self._start(session)
        return session

    def _start(self, session: Session) -> None:  # override for setup
        pass

    def destroy(self, session: Session) -> None:
        with session._lock:  # serialize against worker_pool creation
            pool = session.state.pop("_batch_pool", None)
            session.closed = True
            session.state.clear()
        if pool is not None:
            pool.shutdown(wait=True)

    def set_credential(self, session: Session, credential: Credential | None) -> None:
        """Validate/install a credential for this session.  Default
        accepts anything; cloud connectors override (paper Fig. 3)."""
        session.credential = credential

    # -- metadata --------------------------------------------------------
    @abstractmethod
    def stat(self, session: Session, path: str) -> StatInfo:
        ...

    @abstractmethod
    def listdir(self, session: Session, path: str) -> Sequence[StatInfo]:
        """Directory/prefix expansion — the transfer service uses this to
        expand recursive transfers (paper §2.2)."""

    @abstractmethod
    def command(self, session: Session, op: str, path: str, **kw) -> None:
        """Simple succeed/fail operations: mkdir, delete, rename (paper:
        'directory or object creation and permission changes')."""

    # -- data ------------------------------------------------------------
    @abstractmethod
    def send(self, session: Session, path: str, channel: AppChannel) -> None:
        """Read ``path`` from storage, write to the application."""

    @abstractmethod
    def recv(self, session: Session, path: str, channel: AppChannel) -> None:
        """Read from the application, write to storage at ``path``."""

    # -- bulk data plane --------------------------------------------------
    def send_batch(self, session: Session, paths: Sequence[str],
                   channel_factory: Callable[[str], AppChannel | None]) -> None:
        """Bulk ``send``: move every path through the data plane in one
        call.  ``channel_factory(path)`` returns the AppChannel for each
        path (``None`` skips it).  Per-file failures are contained —
        reported through ``channel.finished(error)`` — so one bad file
        never aborts the rest of the batch.  Default: per-file fallback;
        Connectors override to amortize per-file costs natively."""
        for path in paths:
            channel = channel_factory(path)
            if channel is None:
                continue
            try:
                self.send(session, path, channel)
            except Exception as e:
                channel.finished(e)

    def recv_batch(self, session: Session, paths: Sequence[str],
                   channel_factory: Callable[[str], AppChannel | None]) -> None:
        """Bulk ``recv`` — see :meth:`send_batch` for the contract."""
        for path in paths:
            channel = channel_factory(path)
            if channel is None:
                continue
            try:
                self.recv(session, path, channel)
            except Exception as e:
                channel.finished(e)

    #: worker-pool width for native batch implementations
    BATCH_POOL_SIZE = 8

    def _dispatch_batch(self, session: Session, paths: Sequence[str],
                        channel_factory, one,
                        pool_size: int | None = None) -> None:
        """Submit-and-collect loop shared by native batch paths: one
        ``one(path, channel)`` task per file on the session's pool.
        ``one`` must contain its own errors (report them through
        ``channel.finished``), so ``fut.result()`` never raises for a
        single bad file."""
        pool = session.worker_pool(pool_size or self.BATCH_POOL_SIZE)
        # the session pool is shared by every task on this session, so
        # the submitting task's charge owner is captured per work item —
        # a pool thread charges whichever task's file it is moving
        run = bind_charge_owner(one)
        futures = []
        for path in paths:
            channel = channel_factory(path)
            if channel is None:
                continue
            futures.append(pool.submit(run, path, channel))
        for fut in futures:
            fut.result()

    # -- optional capabilities -------------------------------------------
    def checksum(self, session: Session, path: str, algorithm: str) -> str:
        """Server-side checksum if the storage supports it; default reads
        through ``send`` (costing a re-read — the integrity-check cost
        the paper measures in §7)."""
        from .integrity import hasher  # local import to avoid cycle

        h = hasher(algorithm)
        sink = _ChecksumChannel(h, self.preferred_blocksize())
        self.send(session, path, sink)
        return h.hexdigest()

    def preferred_blocksize(self) -> int:
        return 1 << 20

    def supports_ranged_read(self) -> bool:
        return True

    # -- identity ----------------------------------------------------------
    def __repr__(self) -> str:  # pragma: no cover
        return f"<Connector {self.name}>"


class _ChecksumChannel(AppChannel):
    """Minimal AppChannel that folds Send output into a hash.

    Ranges are claimed sequentially; writes may still land out of order,
    so buffer and fold in order.
    """

    def __init__(self, h, blocksize: int):
        self._h = h
        self._bs = blocksize
        self._next_claim = 0
        self._fold_at = 0
        self._pending: dict[int, bytes] = {}
        self._size: int | None = None
        self._lock = threading.Lock()

    def set_size(self, size: int) -> None:
        self._size = size

    def write(self, offset: int, data: bytes) -> None:
        with self._lock:
            self._pending[offset] = data
            while self._fold_at in self._pending:
                chunk = self._pending.pop(self._fold_at)
                self._h.update(chunk)
                self._fold_at += len(chunk)

    def read(self, offset: int, length: int) -> bytes:
        raise NotImplementedError("checksum channel is read-only")

    def get_concurrency(self) -> int:
        return 1

    def get_blocksize(self) -> int:
        return self._bs

    def get_read_range(self) -> ByteRange | None:
        with self._lock:
            if self._size is not None and self._next_claim >= self._size:
                return None
            length = self._bs
            if self._size is not None:
                length = min(length, self._size - self._next_claim)
            rng = ByteRange(self._next_claim, length)
            self._next_claim += length
            return rng

    def bytes_written(self, offset: int, length: int) -> None:
        pass


def iter_files(connector: Connector, session: Session, path: str) -> Iterator[StatInfo]:
    """Recursive expansion of a directory/prefix into files, the way the
    managed service expands a folder transfer (paper §2.2)."""
    info = connector.stat(session, path)
    if not info.is_dir:
        yield info
        return
    stack: list[str] = [path]
    while stack:
        d = stack.pop()
        for child in connector.listdir(session, d):
            if child.is_dir:
                stack.append(child.name)
            else:
                yield child
