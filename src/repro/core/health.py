"""Shared endpoint-health plane: circuit breakers + retry budgets.

The paper's managed-transfer story (§4, "automatic retries and
fault-tolerant capabilities") retries each file independently, which is
correct for isolated faults but pathological when an *endpoint* is sick:
a fleet of N tasks each burns ``max_retries`` exponential-backoff
attempts against the same dying storage — an O(N·max_retries) retry
storm against infrastructure that production transfer fabrics detect
and route around automatically (Globus service enhancements,
arXiv:2503.22981).  :class:`EndpointHealth` is the shared registry that
makes endpoint sickness a first-class, fleet-wide signal:

* **EWMA error rate** per endpoint over the model clock: every attempt
  outcome (success or blamed failure) folds into an exponentially
  weighted moving average, so the signal tracks recent behaviour and
  ages out history.

* **Three-state circuit breaker** per endpoint, driven by that EWMA:

  - ``closed``    — normal operation; failures accumulate evidence.
  - ``open``      — error rate crossed ``error_threshold`` (with at
    least ``min_samples`` observations): every attempt is denied
    *locally* with :class:`~repro.core.errors.EndpointUnavailable`
    (a fast-fail — no storage op, no exponential backoff sleep) until
    ``cooldown`` model seconds elapse.
  - ``half-open`` — cooldown elapsed: exactly ONE probe attempt at a
    time is admitted (and charged to the retry budget, so probing a
    dead endpoint is budget-bounded too).  ``probe_successes``
    consecutive successful probes close the breaker with a fresh
    evidence window; a failed probe re-opens it with a fresh cooldown.

* **Token-bucket retry budget** per endpoint, shared across *all*
  tasks: a retry (attempt > 1) or a half-open probe must take a token
  from the blamed endpoint's bucket before it may touch storage.  The
  bucket refills at ``retry_budget_rate`` tokens per model second up to
  ``retry_budget_capacity``, so aggregate retries against a sick
  endpoint are O(budget) regardless of fleet size — not
  O(N·max_retries).

Everything is timed on the model :class:`~repro.core.clock.Clock`
(``virtual_elapsed`` advances under every ``time_scale``, including the
pure-accounting 0), so breaker transitions and budget refills are
wall-clock-free and reproducible; :attr:`EndpointHealth.transitions`
records ``(model_time, endpoint, old_state, new_state)`` for tests to
assert exact sequences.

The plane is **opt-in**: a :class:`~repro.core.transfer.TransferService`
built without ``health=`` behaves exactly as before.  When present it is
consulted at three layers — the data plane's per-attempt retry loop
(:meth:`admit` / :meth:`settle`), the control plane's dispatch and
advisor routing (:meth:`available`), and the federation plane's digest
stream (:meth:`unavailable`, exported through
``TransferManager.digest()``).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

from .clock import Clock, DEFAULT_CLOCK
from .errors import EndpointUnavailable
from ..obs.trace import NULL_TRACER

#: breaker states
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"


@dataclass
class HealthConfig:
    """Knobs for the health plane.  Defaults suit chaos-test scale
    (model seconds are small); production-shaped sweeps tune them via
    benchmarks/bench_resilience.py."""

    #: EWMA error rate at/above which a closed breaker opens
    error_threshold: float = 0.5
    #: EWMA smoothing: weight of the newest observation
    ewma_alpha: float = 0.4
    #: observations required before the threshold can trip (a single
    #: unlucky first attempt must not open a fresh endpoint)
    min_samples: int = 3
    #: model seconds an open breaker denies everything before half-open
    cooldown: float = 1.0
    #: consecutive successful probes that close a half-open breaker
    probe_successes: int = 1
    #: retry-budget refill, tokens per model second (0 = no refill:
    #: the capacity is the hard lifetime budget)
    retry_budget_rate: float = 1.0
    #: retry-budget burst size, tokens
    retry_budget_capacity: float = 8.0


class _EpState:
    """Per-endpoint mutable state; guarded by the registry lock."""

    __slots__ = ("ep", "state", "ewma", "samples", "opened_at", "probing",
                 "probe_ok", "tokens", "vlast", "entered_at")

    def __init__(self, ep: str, capacity: float):
        self.ep = ep
        self.state = CLOSED
        self.ewma = 0.0
        self.samples = 0
        self.opened_at = 0.0
        self.probing = 0       # probe attempts currently in flight (≤ 1)
        self.probe_ok = 0      # consecutive successful probes
        self.tokens = capacity
        self.vlast = 0.0
        self.entered_at = 0.0  # model time the current breaker state began


class _Ticket:
    """One admitted attempt: which endpoints it touches and which
    half-open probes it holds.  ``settle``/``release`` are idempotent
    through the flags, so the data plane can release probe slots in a
    ``finally`` without double-counting outcomes."""

    __slots__ = ("eps", "probe_eps", "settled", "released")

    def __init__(self, eps: tuple[str, ...], probe_eps: tuple[str, ...]):
        self.eps = eps
        self.probe_eps = probe_eps
        self.settled = False
        self.released = False

    @property
    def probe(self) -> bool:
        return bool(self.probe_eps)


class EndpointHealth:
    """Fleet-shared endpoint breaker + retry-budget registry.

    One instance is shared by a :class:`TransferService`, its
    :class:`TransferManager`, and (via digests) a federation
    coordinator; all methods are thread-safe under one registry lock.
    Endpoints are keyed by resolved endpoint id
    (:meth:`Endpoint.resolved_id`)."""

    def __init__(self, config: HealthConfig | None = None,
                 clock: Clock | None = None, tracer=None):
        self.config = config or HealthConfig()
        self.clock = clock or DEFAULT_CLOCK
        #: observability: breaker state windows are recorded as
        #: retroactive (charge-free) trace spans; the TransferManager
        #: swaps in its live tracer when it shares this registry
        self.tracer = tracer or NULL_TRACER
        #: (model_time, endpoint, old_state, new_state) in commit order
        self.transitions: list[tuple[float, str, str, str]] = []
        #: fast-fails denied per endpoint (observability)
        self.denials: dict[str, int] = {}
        self._eps: dict[str, _EpState] = {}
        self._lock = threading.Lock()

    # ---- internals (call under self._lock) -------------------------------
    def _ep(self, ep: str) -> _EpState:
        s = self._eps.get(ep)
        if s is None:
            s = _EpState(ep, self.config.retry_budget_capacity)
            self._eps[ep] = s
        return s

    def _refill(self, s: _EpState, now: float) -> None:
        cfg = self.config
        if cfg.retry_budget_rate > 0 and now > s.vlast:
            s.tokens = min(cfg.retry_budget_capacity,
                           s.tokens + (now - s.vlast) * cfg.retry_budget_rate)
        s.vlast = max(s.vlast, now)

    def _move(self, s: _EpState, new: str, now: float) -> None:
        self.transitions.append((now, s.ep, s.state, new))
        # the window just closed (e.g. the whole "open" cooldown) becomes
        # a retroactive span: visible in trace exports, charges nothing
        self.tracer.record(f"breaker-{s.state}", "health",
                           s.entered_at, now, endpoint=s.ep, to=new)
        s.entered_at = now
        s.state = new

    def _deny(self, ep: str, retry_after: float, reason: str,
              msg: str) -> EndpointUnavailable:
        self.denials[ep] = self.denials.get(ep, 0) + 1
        return EndpointUnavailable(msg, retry_after=max(retry_after, 1e-3),
                                   endpoint_id=ep, reason=reason)

    def _open_denial(self, s: _EpState, now: float) -> EndpointUnavailable | None:
        """Denial for an endpoint whose breaker is open and cooling."""
        if s.state != OPEN:
            return None
        remaining = s.opened_at + self.config.cooldown - now
        if remaining <= 0:
            return None
        return self._deny(s.ep, remaining, "breaker-open",
                          f"endpoint {s.ep!r} breaker open "
                          f"({remaining:.3f}s model cooldown remaining)")

    # ---- data-plane gate -------------------------------------------------
    def admit(self, *eps: str, retrying: bool = False,
              blame: tuple[str, ...] | None = None) -> _Ticket:
        """Gate one transfer attempt touching ``eps``.

        Checks every endpoint's breaker and (for retries and probes) its
        retry budget, then commits atomically: either the attempt is
        admitted on ALL endpoints and a :class:`_Ticket` is returned, or
        nothing is mutated and :class:`EndpointUnavailable` is raised —
        the fast-fail that replaces sleeping through exponential
        backoff.  ``blame`` restricts whose budget a retry charges (the
        endpoint the previous failure was attributed to); ``None``
        charges every endpoint of the attempt."""
        cfg = self.config
        with self._lock:
            now = self.clock.virtual_elapsed
            states = [self._ep(e) for e in eps]
            need: dict[str, tuple[bool, float]] = {}  # ep -> (probe, tokens)
            for s in states:
                self._refill(s, now)
                probe = False
                denial = self._open_denial(s, now)
                if denial is not None:
                    raise denial
                if s.state == OPEN:
                    # cooldown elapsed: this attempt becomes the probe
                    probe = True
                elif s.state == HALF_OPEN:
                    if s.probing >= 1:
                        raise self._deny(
                            s.ep, cfg.cooldown, "probe-in-flight",
                            f"endpoint {s.ep!r} half-open with a probe "
                            f"already in flight")
                    probe = True
                charged = probe or (retrying
                                    and (blame is None or s.ep in blame))
                need[s.ep] = (probe, 1.0 if charged else 0.0)
            for s in states:
                _, tokens = need[s.ep]
                if tokens > s.tokens:
                    wait = ((tokens - s.tokens) / cfg.retry_budget_rate
                            if cfg.retry_budget_rate > 0 else cfg.cooldown)
                    raise self._deny(
                        s.ep, wait, "retry-budget",
                        f"endpoint {s.ep!r} retry budget exhausted "
                        f"({s.tokens:.2f} tokens)")
            # all gates passed: commit
            probe_eps = []
            for s in states:
                probe, tokens = need[s.ep]
                s.tokens -= tokens
                if probe:
                    if s.state == OPEN:
                        self._move(s, HALF_OPEN, now)
                    s.probing += 1
                    probe_eps.append(s.ep)
            return _Ticket(tuple(eps), tuple(probe_eps))

    def settle(self, ticket: _Ticket | None, error: Exception | None = None
               ) -> None:
        """Report one admitted attempt's outcome.  Success folds into
        every endpoint's EWMA; a failure is charged to the blamed
        endpoint (``error.endpoint_id`` when it names one of the
        ticket's endpoints, else all of them).  Idempotent per ticket."""
        if ticket is None or ticket.settled:
            return
        with self._lock:
            ticket.settled = True
            now = self.clock.virtual_elapsed
            if not ticket.released:
                ticket.released = True
                for ep in ticket.probe_eps:
                    st = self._eps.get(ep)
                    if st is not None:
                        st.probing = max(0, st.probing - 1)
            if error is None:
                self._record_locked(ticket.eps, False, now)
            else:
                self._record_locked(self._blamed(ticket.eps, error),
                                    True, now)

    def release(self, ticket: _Ticket | None) -> None:
        """Free a ticket's probe slots without judging the outcome —
        the data plane's ``finally`` backstop for attempts that exit
        through a non-transient path (interrupt, permanent error)."""
        if ticket is None or ticket.settled or ticket.released:
            return
        with self._lock:
            if ticket.released:
                return
            ticket.released = True
            for ep in ticket.probe_eps:
                st = self._eps.get(ep)
                if st is not None:
                    st.probing = max(0, st.probing - 1)

    # ---- ticket-free outcome reporting (batch path, external probes) -----
    def record_success(self, *eps: str) -> None:
        with self._lock:
            self._record_locked(tuple(eps), False, self.clock.virtual_elapsed)

    def record_failure(self, *eps: str, error: Exception | None = None
                       ) -> None:
        with self._lock:
            blamed = self._blamed(tuple(eps), error)
            self._record_locked(blamed, True, self.clock.virtual_elapsed)

    @staticmethod
    def _blamed(eps: tuple[str, ...],
                error: Exception | None) -> tuple[str, ...]:
        ep = getattr(error, "endpoint_id", "")
        return (ep,) if ep and ep in eps else eps

    def _record_locked(self, eps: tuple[str, ...], failed: bool,
                       now: float) -> None:
        cfg = self.config
        for ep in eps:
            s = self._ep(ep)
            s.samples += 1
            s.ewma = (1.0 - cfg.ewma_alpha) * s.ewma \
                + (cfg.ewma_alpha if failed else 0.0)
            if failed:
                if s.state == HALF_OPEN:
                    # the probe failed: back to open, fresh cooldown
                    s.probe_ok = 0
                    s.opened_at = now
                    self._move(s, OPEN, now)
                elif s.state == CLOSED and s.samples >= cfg.min_samples \
                        and s.ewma >= cfg.error_threshold:
                    s.opened_at = now
                    self._move(s, OPEN, now)
            else:
                if s.state == HALF_OPEN:
                    s.probe_ok += 1
                    if s.probe_ok >= cfg.probe_successes:
                        # recovered: fresh evidence window, so the next
                        # open again requires min_samples of new proof
                        s.ewma = 0.0
                        s.samples = 0
                        s.probe_ok = 0
                        self._move(s, CLOSED, now)

    # ---- control-plane queries (never mutate breaker state) --------------
    def available(self, ep: str) -> bool:
        """True when an attempt against ``ep`` would not be denied by
        its breaker: closed, half-open with a free probe slot, or open
        with the cooldown elapsed (the attempt would be the probe).
        Used by dispatch/routing; never transitions state."""
        with self._lock:
            s = self._eps.get(ep)
            if s is None:
                return True
            now = self.clock.virtual_elapsed
            if self._open_would_deny(s, now):
                return False
            if s.state == HALF_OPEN and s.probing >= 1:
                return False
            return True

    def _open_would_deny(self, s: _EpState, now: float) -> bool:
        return s.state == OPEN \
            and (s.opened_at + self.config.cooldown - now) > 0

    def denied(self, *eps: str) -> EndpointUnavailable | None:
        """Non-mutating breaker check over several endpoints: the
        denial an :meth:`admit` would raise right now on breaker state
        alone (budget excluded — a denied caller is expected to route
        to the per-attempt path, which does the budgeted admit)."""
        with self._lock:
            now = self.clock.virtual_elapsed
            for ep in eps:
                s = self._eps.get(ep)
                if s is None:
                    continue
                denial = self._open_denial(s, now)
                if denial is not None:
                    return denial
        return None

    def state(self, ep: str) -> str:
        with self._lock:
            s = self._eps.get(ep)
            return s.state if s is not None else CLOSED

    def error_rate(self, ep: str) -> float:
        with self._lock:
            s = self._eps.get(ep)
            return s.ewma if s is not None else 0.0

    def unavailable(self) -> list[str]:
        """Endpoint ids an attempt would currently be denied on — the
        health summary a site exports in its federation digest."""
        with self._lock:
            now = self.clock.virtual_elapsed
            return sorted(
                s.ep for s in self._eps.values()
                if self._open_would_deny(s, now)
                or (s.state == HALF_OPEN and s.probing >= 1))

    def transition_names(self, ep: str) -> list[str]:
        """This endpoint's breaker transitions as ``"old->new"`` strings
        in commit order — the deterministic sequence tests assert."""
        with self._lock:
            return [f"{old}->{new}" for _, e, old, new in self.transitions
                    if e == ep]

    def snapshot(self) -> dict[str, dict]:
        with self._lock:
            now = self.clock.virtual_elapsed
            out = {}
            for ep, s in self._eps.items():
                self._refill(s, now)
                out[ep] = {"state": s.state, "error_rate": round(s.ewma, 6),
                           "samples": s.samples,
                           "tokens": round(s.tokens, 6),
                           "denials": self.denials.get(ep, 0)}
            return out
