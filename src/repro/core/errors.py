"""Error taxonomy for the Connector data interface.

The paper (§2.2, §4) distinguishes transient storage-API failures (rate
limits / call quotas on Google Drive and Box, flaky WAN links) that the
managed transfer service must retry automatically, from permanent errors
(missing object, bad credential) that must surface to the client on the
control channel.
"""

from __future__ import annotations


class ConnectorError(Exception):
    """Base class for all connector-layer errors."""


class PermanentError(ConnectorError):
    """Non-retryable: surfaced to the control channel immediately."""


class TransientError(ConnectorError):
    """Retryable: the transfer service retries with backoff (paper §4,
    'automatic retries and fault-tolerant capabilities')."""

    def __init__(self, msg: str = "", retry_after: float = 0.0):
        super().__init__(msg)
        self.retry_after = retry_after


class RateLimitError(TransientError):
    """Storage API call-quota exceeded (Google Drive / Box, paper §4)."""


class FaultInjected(TransientError):
    """Deterministic fault injected by a test/benchmark profile."""


class TruncatedStream(TransientError):
    """A data stream ended before the planned byte count and the source
    still reports the full size: the stream was cut (connection died,
    proxy fault, ...), not the file shrunk.  Retryable — the next
    attempt re-claims the remaining holes."""


class NotFound(PermanentError):
    pass


class AlreadyExists(PermanentError):
    pass


class AuthError(PermanentError):
    """Credential missing/invalid (paper Fig. 3 auth flow)."""


class IntegrityError(ConnectorError):
    """End-to-end checksum mismatch (paper §7). Retryable at file scope:
    the transfer service re-sends the file a bounded number of times."""


class SessionClosed(PermanentError):
    pass
