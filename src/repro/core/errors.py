"""Error taxonomy for the Connector data interface.

The paper (§2.2, §4) distinguishes transient storage-API failures (rate
limits / call quotas on Google Drive and Box, flaky WAN links) that the
managed transfer service must retry automatically, from permanent errors
(missing object, bad credential) that must surface to the client on the
control channel.

Breaker / fast-fail taxonomy (health plane, :mod:`repro.core.health`)
---------------------------------------------------------------------
Per-endpoint circuit breakers add a third failure mode: an attempt can
be denied *locally*, before any storage op, because the endpoint's
recent error rate opened its breaker or its shared retry budget ran
dry.  That denial is :class:`EndpointUnavailable` — still a
:class:`TransientError` (the retry loop handles it), but with fast-fail
semantics: no storage was touched, so the loop sleeps only the breaker's
``retry_after`` hint (model seconds until the breaker may half-open or
the budget refills) instead of exponential backoff.  It is counted
distinctly in ``TaskStats.retries_by_kind``, alongside the
``"HalfOpenProbe"`` pseudo-kind for attempts admitted as half-open
probes — so a fault schedule, the breaker's denials, and its probes are
all separately observable on a task.
"""

from __future__ import annotations


class ConnectorError(Exception):
    """Base class for all connector-layer errors."""


class PermanentError(ConnectorError):
    """Non-retryable: surfaced to the control channel immediately."""


class TransientError(ConnectorError):
    """Retryable: the transfer service retries with backoff (paper §4,
    'automatic retries and fault-tolerant capabilities')."""

    def __init__(self, msg: str = "", retry_after: float = 0.0):
        super().__init__(msg)
        self.retry_after = retry_after


class RateLimitError(TransientError):
    """Storage API call-quota exceeded (Google Drive / Box, paper §4)."""


class EndpointUnavailable(TransientError):
    """Fast-fail from the health plane: the endpoint's circuit breaker
    is open (``reason="breaker-open"``), a half-open probe is already in
    flight (``"probe-in-flight"``), or the endpoint's shared retry
    budget is exhausted (``"retry-budget"``).  The attempt was denied
    locally — no storage op happened.  ``retry_after`` carries the model
    seconds until the condition may clear."""

    def __init__(self, msg: str = "", retry_after: float = 0.0,
                 endpoint_id: str = "", reason: str = ""):
        super().__init__(msg, retry_after)
        self.endpoint_id = endpoint_id
        self.reason = reason


class FaultInjected(TransientError):
    """Deterministic fault injected by a test/benchmark profile."""


class TruncatedStream(TransientError):
    """A data stream ended before the planned byte count and the source
    still reports the full size: the stream was cut (connection died,
    proxy fault, ...), not the file shrunk.  Retryable — the next
    attempt re-claims the remaining holes."""


class NotFound(PermanentError):
    pass


class AlreadyExists(PermanentError):
    pass


class AuthError(PermanentError):
    """Credential missing/invalid (paper Fig. 3 auth flow)."""


class IntegrityError(ConnectorError):
    """End-to-end checksum mismatch (paper §7). Retryable at file scope:
    the transfer service re-sends the file a bounded number of times."""


class SessionClosed(PermanentError):
    pass
