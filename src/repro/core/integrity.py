"""End-to-end integrity checking (paper §7).

"A client can verify transmission integrity by having a file read and a
checksum computed at the source before transmission and then reread and
a second checksum computed at the destination."

Algorithms:
  * sha256 / md5  — hashlib-backed, used for byte-stream transfers.
  * crc32c-ish    — zlib.crc32 wrapped in the same interface (fast path).
  * fletcher-jax  — the TPU-adapted blocked Fletcher checksum for
    *on-device* arrays (see ``repro.kernels.checksum``); used by the
    checkpoint layer so the source-side checksum happens on the
    accelerator before D2H.
"""

from __future__ import annotations

import hashlib
import zlib


class _Crc32:
    name = "crc32"

    def __init__(self):
        self._v = 0

    def update(self, data: bytes) -> None:
        self._v = zlib.crc32(data, self._v)

    def hexdigest(self) -> str:
        return f"{self._v & 0xFFFFFFFF:08x}"


class _Fletcher64:
    """Pure-python reference of the blocked Fletcher checksum; matches
    ``repro.kernels.checksum.ref`` on little-endian uint32 words (tail
    zero-padded)."""

    name = "fletcher64"
    MOD = (1 << 32) - 1

    def __init__(self):
        self._a = 0
        self._b = 0
        self._tail = b""

    def update(self, data: bytes) -> None:
        data = self._tail + data
        n = len(data) // 4 * 4
        self._tail = data[n:]
        a, b = self._a, self._b
        for i in range(0, n, 4):
            w = int.from_bytes(data[i : i + 4], "little")
            a = (a + w) % self.MOD
            b = (b + a) % self.MOD
        self._a, self._b = a, b

    def hexdigest(self) -> str:
        a, b = self._a, self._b
        if self._tail:
            w = int.from_bytes(self._tail.ljust(4, b"\0"), "little")
            a = (a + w) % self.MOD
            b = (b + a) % self.MOD
        return f"{b:08x}{a:08x}"


class _LaneSum32:
    """Byte-stream twin of the TPU lanesum32 kernel
    (``repro.kernels.checksum``): little-endian uint32 words, a = sum w,
    b = sum (i+1)*w, both mod 2^32.  Lets the host side verify a digest
    that was computed on-device."""

    name = "lanesum32"
    MASK = 0xFFFFFFFF

    def __init__(self):
        self._a = 0
        self._b = 0
        self._i = 0  # 0-based word index
        self._tail = b""

    def _fold_words(self, data: bytes) -> None:
        import numpy as np
        w = np.frombuffer(data, dtype="<u4").astype(np.uint64)
        n = w.size
        if n == 0:
            return
        idx = (np.arange(self._i + 1, self._i + n + 1, dtype=np.uint64)
               & self.MASK)
        self._a = (self._a + int(w.sum() % (1 << 32))) & self.MASK
        self._b = (self._b + int((w * idx % (1 << 32)).sum() % (1 << 32))) \
            & self.MASK
        self._i += n

    def update(self, data: bytes) -> None:
        data = self._tail + data
        n = len(data) // 4 * 4
        self._tail = data[n:]
        self._fold_words(data[:n])

    def hexdigest(self) -> str:
        a, b, i = self._a, self._b, self._i
        if self._tail:
            w = int.from_bytes(self._tail.ljust(4, b"\0"), "little")
            a = (a + w) & self.MASK
            b = (b + ((i + 1) & self.MASK) * w) & self.MASK
        return f"{b:08x}{a:08x}"


def hasher(algorithm: str):
    if algorithm in ("sha256", "md5", "sha1"):
        return hashlib.new(algorithm)
    if algorithm == "crc32":
        return _Crc32()
    if algorithm == "fletcher64":
        return _Fletcher64()
    if algorithm == "lanesum32":
        return _LaneSum32()
    raise ValueError(f"unknown checksum algorithm: {algorithm}")


def checksum_bytes(data: bytes, algorithm: str = "sha256") -> str:
    h = hasher(algorithm)
    h.update(data)
    return h.hexdigest()
