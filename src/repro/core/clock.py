"""Scaled clock + emulated network links.

This container is offline, so the WAN/cloud environment of the paper is
emulated deterministically: every latency/bandwidth constant is expressed
in *model seconds* and multiplied by a global ``time_scale`` before any
real sleep happens.  ``time_scale=0`` turns all waits into pure
accounting (used by unit tests); benchmarks use a small positive scale so
that measured wall-clock times are dominated by the modeled terms.

The link model reproduces the phenomena the paper measures:

* per-API-call round-trip latency  -> per-file overhead ``t0`` (Eq. 4)
* per-stream vs aggregate bandwidth -> throughput-vs-concurrency curves
  (Figs. 13-17): rate = min(per_stream, aggregate / active_streams)
* local contention                  -> slight decline past saturation
  ("aggregated throughput first increases ... and eventually drops
  slowly, because of local contention", §6)
"""

from __future__ import annotations

import functools
import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field


def _env_scale() -> float:
    return float(os.environ.get("REPRO_TIME_SCALE", "0.0"))


# --------------------------------------------------------------------------
# sanctioned wall-clock escape hatch
# --------------------------------------------------------------------------
# Model time only advances while charged work runs, so a *model*
# deadline can never fire against a wedged real thread — harnesses and
# cv-slicing loops that bound REAL threads (scenario kill windows, the
# coordinator's caller-facing wait_all timeout) genuinely need the wall
# clock.  They get it from these two helpers and nowhere else: the
# contract linter (rule R001, ``python -m repro.lint``) bans direct
# ``time.time/monotonic/sleep`` outside this module, so every wall
# read in the stack is greppable as wall_now/wall_sleep and auditable
# here.  Neither helper charges model time; code that does model-visible
# waiting must go through ``Clock.sleep`` under a bound charge owner.


def wall_now() -> float:
    """Monotonic *wall* seconds — for bounding real threads that may
    wedge, never for stamping model-visible state."""
    return time.monotonic()


def wall_sleep(seconds: float) -> None:
    """Real sleep — for harness polls between wall_now() checks."""
    time.sleep(seconds)


# --------------------------------------------------------------------------
# charge attribution
# --------------------------------------------------------------------------
#: thread-local charge owner, shared by every Clock instance so one task
#: keeps a single identity across the service clock, link clocks, and
#: fault-schedule clocks
_attribution = threading.local()


def current_charge_owner() -> str | None:
    """The owner (task id) the current thread charges model time to."""
    return getattr(_attribution, "owner", None)


def current_trace_context():
    """The observability span context active on this thread, or ``None``.

    Opaque to the clock: :mod:`repro.obs` installs a context object via
    :func:`trace_context`, and :meth:`Clock.sleep` calls its ``charge``
    hook so every model-second lands on the innermost open span.  The
    clock never imports ``obs`` — the coupling is one duck-typed method.
    """
    return getattr(_attribution, "trace", None)


@contextmanager
def charge_to(owner: str | None):
    """Attribute every model-time charge made by this thread (latency,
    bandwidth, backoff, injected delay) to ``owner`` for the duration of
    the block.  Nests: the previous owner is restored on exit."""
    prev = getattr(_attribution, "owner", None)
    _attribution.owner = owner
    try:
        yield
    finally:
        _attribution.owner = prev


def _swap_trace_context(ctx):
    """Install ``ctx`` as the thread's span context and return the
    previous one.  The raw form of :func:`trace_context` for the span
    enter/exit hot path, where a generator context manager per span is
    measurable fleet overhead; callers MUST restore the returned
    previous context themselves."""
    prev = getattr(_attribution, "trace", None)
    _attribution.trace = ctx
    return prev


@contextmanager
def trace_context(ctx):
    """Make ``ctx`` the thread's active span context for the duration of
    the block (the tracing sibling of :func:`charge_to`).  Nests: the
    previous context is restored on exit."""
    prev = getattr(_attribution, "trace", None)
    _attribution.trace = ctx
    try:
        yield
    finally:
        _attribution.trace = prev


def bind_charge_owner(fn):
    """Capture the *calling* thread's charge owner — and its active span
    context — and re-establish both in whichever thread eventually runs
    ``fn``.  This is how attribution crosses thread boundaries: per-task
    worker threads, sender threads, connector stream pools, and —
    critically — session-level batch pools that are shared across tasks
    (the owner is captured per submitted work item, not per pool
    thread).  Spans opened on the far side of the boundary therefore
    attach to the same task timeline as the submitting thread's."""
    owner = current_charge_owner()
    trace = current_trace_context()
    if owner is None and trace is None:
        return fn

    @functools.wraps(fn)
    def bound(*args, **kwargs):
        with charge_to(owner), trace_context(trace):
            return fn(*args, **kwargs)

    return bound


class Clock:
    """Monotonic clock whose sleeps are scaled; also keeps *virtual*
    elapsed accounting so tests with scale=0 can still assert on modeled
    time.

    Sub-millisecond scaled sleeps are batched per thread (a "sleep
    debt") so emulation fidelity survives small scales — Python's
    ``time.sleep`` has ~0.1 ms of overhead that would otherwise swamp
    the modeled latencies.
    """

    MIN_REAL_SLEEP = 1e-3

    def __init__(self, scale: float | None = None):
        self.scale = _env_scale() if scale is None else scale
        self._virtual = 0.0
        self._lock = threading.Lock()
        self._debt = threading.local()
        #: owner -> model seconds charged while that owner was current
        self._charges: dict[str, float] = {}

    def sleep(self, model_seconds: float) -> None:
        if model_seconds <= 0:
            return
        owner = current_charge_owner()
        with self._lock:
            self._virtual += model_seconds
            if owner is not None:
                self._charges[owner] = \
                    self._charges.get(owner, 0.0) + model_seconds
        trace = getattr(_attribution, "trace", None)
        if trace is not None:
            # outside self._lock: the span context takes its own lock
            trace.charge(model_seconds)
        if self.scale <= 0:
            return
        real = model_seconds * self.scale
        debt = getattr(self._debt, "v", 0.0) + real
        if debt >= self.MIN_REAL_SLEEP:
            self._debt.v = 0.0
            time.sleep(debt)
        else:
            self._debt.v = debt

    @property
    def virtual_elapsed(self) -> float:
        return self._virtual

    def charged(self, owner: str) -> float:
        """Model seconds charged to ``owner`` on this clock.  Unlike
        ``virtual_elapsed`` (which every concurrent task inflates), this
        is exact per task: concurrent tasks partition the clock's total
        instead of each observing all of it."""
        with self._lock:
            return self._charges.get(owner, 0.0)

    def forget(self, owner: str) -> None:
        """Drop a finished owner's tally so the charge table stays
        bounded over a long-lived fleet."""
        with self._lock:
            self._charges.pop(owner, None)

    def now(self) -> float:
        return time.monotonic()


DEFAULT_CLOCK = Clock()


@dataclass
class TokenBucket:
    """API call-quota model (Google Drive / Box, paper §4).

    ``rate`` calls per model-second, burst ``capacity``.  When empty,
    raises through the caller as a RateLimitError with a retry hint.
    """

    rate: float
    capacity: float
    clock: Clock = field(default_factory=lambda: DEFAULT_CLOCK)

    def __post_init__(self):
        self._tokens = self.capacity
        self._last = time.monotonic()
        self._vlast = 0.0
        self._lock = threading.Lock()

    def try_acquire(self, n: float = 1.0) -> float:
        """Returns 0.0 on success, else model-seconds to wait."""
        with self._lock:
            now = time.monotonic()
            if self.clock.scale > 0:
                elapsed_model = (now - self._last) / self.clock.scale
            else:
                # Pure-accounting mode: refill from virtual clock.
                elapsed_model = self.clock.virtual_elapsed - self._vlast
            self._last = now
            self._vlast = self.clock.virtual_elapsed
            self._tokens = min(self.capacity, self._tokens + elapsed_model * self.rate)
            if self._tokens >= n:
                self._tokens -= n
                return 0.0
            return (n - self._tokens) / self.rate


@dataclass
class Link:
    """A network hop.  Bandwidths in model-bytes per model-second.

    ``transmit`` charges time in chunks so the effective per-stream rate
    reacts to how many streams are concurrently active (the paper's
    concurrency behaviour).
    """

    name: str
    rtt: float  # model seconds, one round trip
    per_stream_bw: float  # B/s a single TCP stream can carry
    aggregate_bw: float  # B/s the whole link can carry
    contention: float = 0.015  # fractional agg-bw loss per stream past knee
    chunk: int = 1 << 21
    clock: Clock = field(default_factory=lambda: DEFAULT_CLOCK)

    def __post_init__(self):
        self._active = 0
        self._lock = threading.Lock()

    def round_trip(self, n: int = 1) -> None:
        self.clock.sleep(self.rtt * n)

    def _per_stream_rate(self) -> float:
        with self._lock:
            act = max(1, self._active)
        knee = max(1.0, self.aggregate_bw / self.per_stream_bw)
        agg = self.aggregate_bw
        if act > knee:
            agg *= max(0.3, 1.0 - self.contention * (act - knee))
        return min(self.per_stream_bw, agg / act)

    def transmit(self, nbytes: int, streams: int = 1) -> None:
        """Move ``nbytes`` using ``streams`` parallel TCP streams (the
        GridFTP parallelism / SDK multipart knob).  Fair-shares the
        aggregate among all active streams on the link."""
        if nbytes <= 0:
            return
        streams = max(1, streams)
        with self._lock:
            self._active += streams
        try:
            left = nbytes
            while left > 0:
                step = min(left, self.chunk * streams)
                self.clock.sleep(step / (streams * self._per_stream_rate()))
                left -= step
        finally:
            with self._lock:
                self._active -= streams


#: A zero-cost link (co-located processes).
def loopback(clock: Clock | None = None) -> Link:
    return Link("loopback", rtt=0.0, per_stream_bw=float("inf"),
                aggregate_bw=float("inf"), clock=clock or DEFAULT_CLOCK)
