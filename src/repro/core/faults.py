"""Composable, seed-deterministic fault-injection schedules.

The paper's claim for the Connector abstraction is *managed* transfer —
"error handling and end-to-end integrity" (§2, §4) — which is only
credible if the retry/backoff, marker-resume, and integrity-repair
machinery is exercised systematically rather than by a handful of
hand-written failure cases.  A :class:`FaultSchedule` is a declarative
plan of failures that a :class:`~repro.connectors.faultproxy.FaultProxyConnector`
(or an emulated :class:`~repro.connectors.cloud.CloudStorage`) replays
against live traffic:

* ``transient``     — retryable :class:`FaultInjected` on matching ops
* ``rate_limit``    — :class:`RateLimitError` storms with ``retry_after``
* ``session_drop``  — :class:`SessionClosed` mid-op (connection died)
* ``latency``       — injected delay on the model :class:`Clock` (never
  the wall clock: ``REPRO_TIME_SCALE=0`` keeps it pure accounting)
* ``bit_flip``      — corrupt one byte of a data block flowing into
  storage, which only end-to-end integrity checking (§7) can catch
* ``truncate``      — cut a data stream after K bytes, so the file lands
  short and the service must detect + re-send the hole
* ``error``         — any custom exception factory

Determinism
-----------
Every decision is a pure function of ``(seed, rule, op, path, k)`` where
``k`` is the per-stream match counter, so the injected fault *set* is
reproducible run-to-run even when the transfer service drives files from
a thread pool: each file's op sequence is deterministic, and counters
default to ``scope="path"`` (one stream per ``(rule, op, path)``).
``scope="global"`` counts across all paths — deterministic only under
``concurrency=1``.  Probabilistic rules draw from a hash, not a shared
RNG stream, for the same reason.

Every firing is recorded as a :class:`FaultEvent`, so tests can assert
``task.stats.faults_retried`` against ``schedule.count("transient")``.
"""

from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass
from fnmatch import fnmatchcase
from typing import Callable

from .clock import Clock, DEFAULT_CLOCK
from .errors import FaultInjected, RateLimitError, SessionClosed

#: rule kinds applied at op admission (may raise / sleep)
CONTROL_KINDS = ("transient", "rate_limit", "session_drop", "latency", "error")
#: rule kinds applied inside a data stream (mutate / cut blocks)
DATA_KINDS = ("bit_flip", "truncate")


@dataclass(frozen=True)
class FaultEvent:
    """One injected fault, as it actually fired."""

    kind: str
    op: str
    path: str
    index: int          # the match counter value that fired (1-based)
    detail: str = ""

    def signature(self) -> tuple:
        return (self.kind, self.op, self.path, self.index, self.detail)


@dataclass
class FaultRule:
    """One line of a schedule.  Matching ops are counted per stream
    (``scope="path"``: one counter per ``(rule, op, path)``); the rule
    fires on counter values inside its window:

      ``at``     first 1-based match index that fires
      ``times``  how many firings (None = unlimited)
      ``every``  fire every k-th match at/after ``at`` (storms/beats)
      ``prob``   seeded per-match probability gate on top of the window
    """

    kind: str
    op: str = "*"
    path: str = "*"
    at: int = 1
    times: int | None = 1
    every: int | None = None
    prob: float | None = None
    scope: str = "path"           # "path" | "global"
    delay: float = 0.0            # latency: model seconds
    retry_after: float = 0.0      # rate_limit hint
    after_bytes: int = 0          # truncate: bytes delivered before cut
    flip_offset: int | None = None  # bit_flip: absolute byte offset (None
    #                                 = midpoint of the first block)
    error: Callable[[str, str], Exception] | None = None  # kind="error"

    def matches(self, op: str, path: str) -> bool:
        return fnmatchcase(op, self.op) and fnmatchcase(path, self.path)

    def in_window(self, k: int) -> bool:
        if k < self.at:
            return False
        if self.every:
            if (k - self.at) % self.every != 0:
                return False
            return self.times is None or (k - self.at) // self.every < self.times
        return self.times is None or k < self.at + self.times


class StreamFaults:
    """Per-attempt data-plane directives for one file stream.

    Handed out by :meth:`FaultSchedule.data_plan` when a connector opens
    a data stream; :meth:`filter` is applied to every block flowing into
    storage and implements ``truncate`` (returns ``b""`` = end of
    stream) and ``bit_flip`` (corrupts one byte)."""

    def __init__(self, schedule: "FaultSchedule", op: str, path: str,
                 truncate_after: int | None, flips: list[FaultRule]):
        self._schedule = schedule
        self._op = op
        self._path = path
        self._truncate_after = truncate_after
        self._flips = list(flips)
        self._delivered = 0
        self._cut_logged = False
        self._lock = threading.Lock()

    @property
    def active(self) -> bool:
        return self._truncate_after is not None or bool(self._flips)

    def filter(self, offset: int, data: bytes) -> bytes:
        """Apply this attempt's directives to one block (may shorten it,
        corrupt one byte, or end the stream by returning ``b""``)."""
        if not data or not self.active:
            return data
        with self._lock:
            if self._truncate_after is not None:
                remaining = self._truncate_after - self._delivered
                if remaining <= 0:
                    self._log_cut()
                    return b""
                if len(data) > remaining:
                    data = data[:remaining]
                    self._log_cut()
            if self._flips and data:
                rule = self._flips[0]
                pos = None
                if rule.flip_offset is None:
                    pos = len(data) // 2
                elif offset <= rule.flip_offset < offset + len(data):
                    pos = rule.flip_offset - offset
                if pos is not None:
                    self._flips.pop(0)
                    mutated = bytearray(data)
                    mutated[pos] ^= 0xFF
                    data = bytes(mutated)
                    self._schedule._log(FaultEvent(
                        "bit_flip", self._op, self._path, 1,
                        f"offset={offset + pos}"))
            self._delivered += len(data)
        return data

    def _log_cut(self) -> None:
        if not self._cut_logged:
            self._cut_logged = True
            self._schedule._log(FaultEvent(
                "truncate", self._op, self._path, 1,
                f"after={self._truncate_after}"))


class FaultSchedule:
    """A composable plan of failures.  Builder methods append rules and
    return ``self``::

        sched = (FaultSchedule(seed=7)
                 .transient(op="send", at=2)               # 2nd send fails
                 .rate_limit(op="put*", at=3, times=5,     # quota storm
                             retry_after=0.25)
                 .bit_flip(path="*.bin")                   # needs integrity
                 .session_drop(op="recv_batch")            # drop mid-batch
                 .truncate(after_bytes=4096, op="recv")    # short write
                 .latency(op="stat", delay=0.5, times=None))
    """

    def __init__(self, rules: list[FaultRule] | None = None, seed: int = 0,
                 clock: Clock | None = None):
        self.rules: list[FaultRule] = list(rules or [])
        self.seed = seed
        self.clock = clock
        self.events: list[FaultEvent] = []
        self._counts: dict[tuple, int] = {}
        self._lock = threading.Lock()

    # -- builder ---------------------------------------------------------
    def add(self, rule: FaultRule) -> "FaultSchedule":
        self.rules.append(rule)
        return self

    def transient(self, op: str = "*", path: str = "*", **kw) -> "FaultSchedule":
        return self.add(FaultRule("transient", op=op, path=path, **kw))

    def rate_limit(self, op: str = "*", path: str = "*",
                   retry_after: float = 0.1, **kw) -> "FaultSchedule":
        return self.add(FaultRule("rate_limit", op=op, path=path,
                                  retry_after=retry_after, **kw))

    def session_drop(self, op: str = "*", path: str = "*", **kw) -> "FaultSchedule":
        return self.add(FaultRule("session_drop", op=op, path=path, **kw))

    def latency(self, op: str = "*", path: str = "*", delay: float = 0.05,
                **kw) -> "FaultSchedule":
        return self.add(FaultRule("latency", op=op, path=path, delay=delay, **kw))

    def bit_flip(self, op: str = "recv*", path: str = "*",
                 flip_offset: int | None = None, **kw) -> "FaultSchedule":
        return self.add(FaultRule("bit_flip", op=op, path=path,
                                  flip_offset=flip_offset, **kw))

    def truncate(self, after_bytes: int, op: str = "recv*", path: str = "*",
                 **kw) -> "FaultSchedule":
        return self.add(FaultRule("truncate", op=op, path=path,
                                  after_bytes=after_bytes, **kw))

    def fail_with(self, error: Callable[[str, str], Exception],
                  op: str = "*", path: str = "*", **kw) -> "FaultSchedule":
        return self.add(FaultRule("error", op=op, path=path, error=error, **kw))

    # -- endpoint-degradation profiles (health-plane scenarios) ----------
    def dead_endpoint(self, op: str = "*", path: str = "*",
                      **kw) -> "FaultSchedule":
        """Permanent endpoint death: every matching op fails transiently,
        forever.  Each firing is one attempt that actually *reached* the
        endpoint, so ``count("transient")`` measures the aggregate
        attempt pressure a retry policy (or a circuit breaker's retry
        budget) allowed through."""
        return self.transient(op=op, path=path, times=None, **kw)

    def brownout(self, times: int, op: str = "*", path: str = "*",
                 **kw) -> "FaultSchedule":
        """A bounded degradation window: the first ``times`` matching
        ops — counted globally across all paths — fail transiently, then
        the endpoint recovers.  The *total* number of injected failures
        is exactly ``times`` under any thread schedule (the counter is
        locked); which paths absorb them may vary, so assert on breaker
        transitions and outcome totals, not per-path event order."""
        return self.transient(op=op, path=path, times=times,
                              scope="global", **kw)

    # -- engine ----------------------------------------------------------
    def _bump(self, i: int, rule: FaultRule, op: str, path: str) -> int:
        key = (i,) if rule.scope == "global" else (i, op, path)
        with self._lock:
            k = self._counts.get(key, 0) + 1
            self._counts[key] = k
        return k

    def _draw(self, i: int, op: str, path: str, k: int) -> float:
        """Deterministic uniform [0,1) from (seed, rule, stream, k) —
        thread-schedule independent, unlike a shared RNG stream."""
        basis = f"{self.seed}|{i}|{op}|{path}|{k}".encode()
        h = hashlib.sha1(basis).digest()
        return int.from_bytes(h[:8], "big") / 2.0 ** 64

    def _fires(self, i: int, rule: FaultRule, op: str, path: str, k: int) -> bool:
        if not rule.in_window(k):
            return False
        if rule.prob is not None:
            return self._draw(i, op, path, k) < rule.prob
        return True

    def _log(self, event: FaultEvent) -> None:
        with self._lock:
            self.events.append(event)

    def check(self, op: str, path: str = "") -> None:
        """Admit one control-plane op.  May sleep (latency, on the model
        clock) or raise (transient / rate-limit / session-drop / custom).
        Data-plane kinds are ignored here — see :meth:`data_plan`."""
        for i, rule in enumerate(self.rules):
            if rule.kind in DATA_KINDS or not rule.matches(op, path):
                continue
            k = self._bump(i, rule, op, path)
            if not self._fires(i, rule, op, path, k):
                continue
            if rule.kind == "latency":
                self._log(FaultEvent("latency", op, path, k,
                                     f"delay={rule.delay}"))
                (self.clock or DEFAULT_CLOCK).sleep(rule.delay)
                continue
            if rule.kind == "transient":
                self._log(FaultEvent("transient", op, path, k))
                raise FaultInjected(f"injected transient on {op} {path}#{k}",
                                    retry_after=rule.retry_after)
            if rule.kind == "rate_limit":
                self._log(FaultEvent("rate_limit", op, path, k,
                                     f"retry_after={rule.retry_after}"))
                raise RateLimitError(
                    f"injected rate limit on {op} {path}#{k}",
                    retry_after=rule.retry_after)
            if rule.kind == "session_drop":
                self._log(FaultEvent("session_drop", op, path, k))
                raise SessionClosed(f"injected session drop on {op} {path}#{k}")
            if rule.kind == "error":
                self._log(FaultEvent("error", op, path, k))
                raise rule.error(op, path)

    def data_plan(self, op: str, path: str) -> StreamFaults:
        """Open one data stream (= one transfer attempt for one file):
        consumes a match from every data rule and returns the attempt's
        :class:`StreamFaults`.  A rule with ``at=1, times=1`` therefore
        faults the *first* attempt per file and lets the retry pass."""
        truncate_after: int | None = None
        flips: list[FaultRule] = []
        for i, rule in enumerate(self.rules):
            if rule.kind not in DATA_KINDS or not rule.matches(op, path):
                continue
            k = self._bump(i, rule, op, path)
            if not self._fires(i, rule, op, path, k):
                continue
            if rule.kind == "truncate":
                ta = rule.after_bytes
                truncate_after = ta if truncate_after is None \
                    else min(truncate_after, ta)
            else:
                flips.append(rule)
        return StreamFaults(self, op, path, truncate_after, flips)

    # -- observability ---------------------------------------------------
    def count(self, kind: str | None = None, op: str | None = None) -> int:
        with self._lock:
            return sum(1 for e in self.events
                       if (kind is None or e.kind == kind)
                       and (op is None or fnmatchcase(e.op, op)))

    def sorted_events(self) -> list[tuple]:
        """Thread-order-independent event log (for run-to-run compares)."""
        with self._lock:
            return sorted(e.signature() for e in self.events)

    def reset(self) -> None:
        """Clear counters + events so the same schedule replays fresh."""
        with self._lock:
            self.events.clear()
            self._counts.clear()

    def __repr__(self) -> str:  # pragma: no cover
        kinds = ",".join(r.kind for r in self.rules) or "empty"
        return f"<FaultSchedule seed={self.seed} [{kinds}] " \
               f"{len(self.events)} fired>"
